//! Transport-equivalence test layer (determinism contracts 5 and 6,
//! docs/determinism.md): every order-exchange transport — synchronous
//! inline dispatch, in-process channel workers, loopback TCP sockets —
//! must produce **bit-identical** CD-GraB epoch orders for the same
//! gradient stream and topology schedule, and transport failures must
//! surface as typed boundary errors, never hangs or partial
//! coordinator state. Contract 6 adds the elastic layer: an elastic
//! coordinator with frozen weights is bit-equal to the static
//! topology, any weight schedule (including mid-run shard-count
//! changes) still emits valid permutations, and — under the
//! `fault-injection` feature (the CI `chaos` job) — injected drops,
//! duplicates, delays, and mid-epoch disconnects all surface at the
//! boundary, with the elastic coordinator re-planning over the
//! survivors after a link loss.
//!
//! These tests need no artifacts (they run on synthetic gradient
//! streams) but do open real loopback sockets; CI runs this target
//! under a timeout guard so a hung socket fails fast.

use std::io::Write;
use std::net::TcpListener;

use grab::ordering::transport::codec;
use grab::ordering::{
    stream_static_epoch, OrderPolicy, PairBalance, ShardedOrder,
};
use grab::util::prop::{self, assert_permutation, gen};
use grab::util::ser::{
    encode_frame, read_frame, write_frame, FrameKind, FRAME_HEADER_LEN,
};

fn feed_epoch(p: &mut dyn OrderPolicy, vs: &[Vec<f32>], block: usize) {
    let mut flat = Vec::new();
    // Epoch-agnostic policies only in this suite, so index 0 is exact.
    stream_static_epoch(p, 0, vs, &mut flat, block);
}

#[test]
fn loopback_tcp_matches_channel_and_sync_orders() {
    // The tentpole property: for W in {1, 2, 4} over random
    // n/d/block/depth, loopback-TCP ≡ async-mpsc ≡ sync epoch orders
    // across multiple epochs. At W = 1 the chain extends through the
    // existing gate to unsharded PairBalance, so socket CD-GraB is
    // pinned all the way down to the single-threaded reference.
    prop::forall("tcp == channel == sync sharded orders", 8, |rng| {
        let n = 1 + rng.gen_range(60) as usize;
        let d = 1 + rng.gen_range(6) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let depth = 1 + rng.gen_range(4) as usize;
        let vs = gen::vec_set(rng, n, d);
        for w in [1usize, 2, 4] {
            let mut strided = ShardedOrder::new(n, d, w);
            let mut channel = ShardedOrder::new_async(n, d, w, depth);
            let mut socket = ShardedOrder::new_tcp_loopback(n, d, w)
                .map_err(|e| format!("loopback spawn: {e}"))?;
            let mut pair = PairBalance::new(n, d);
            for epoch in 0..3 {
                feed_epoch(&mut strided, &vs, b);
                feed_epoch(&mut channel, &vs, b);
                feed_epoch(&mut socket, &vs, b);
                feed_epoch(&mut pair, &vs, b);
                let want = strided.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                if channel.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "channel != sync at w={w} epoch={epoch} \
                         n={n} d={d} b={b} depth={depth}"
                    ));
                }
                if socket.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "tcp != sync at w={w} epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
                if w == 1 && pair.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "w=1 sharded != PairBalance at epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn static_stream_reservoir_chains_into_the_transport_gate() {
    // Contract 9 meets contract 5: a *static* sliding reservoir (full,
    // no membership events) over channel links must equal the bare
    // sharded coordinator — which the gate above pins to sync and TCP
    // — for W in {1, 2, 4}, chaining the streaming layer down to the
    // single-threaded PairBalance reference.
    use grab::ordering::stream::StreamOrder;
    prop::forall("static stream == sharded sync orders", 6, |rng| {
        let n = 1 + rng.gen_range(48) as usize;
        let d = 1 + rng.gen_range(5) as usize;
        let b = 1 + rng.gen_range(8) as usize;
        let vs = gen::vec_set(rng, n, d);
        let units: Vec<u64> = (0..n as u64).collect();
        for w in [1usize, 2, 4] {
            let mut sync = ShardedOrder::new(n, d, w);
            let mut res =
                StreamOrder::sharded_channel(n, d, &units, w, 2);
            for epoch in 0..3 {
                feed_epoch(&mut sync, &vs, b);
                res.run_window(
                    &mut |unit, out| {
                        out.copy_from_slice(&vs[unit as usize])
                    },
                    b,
                );
                let want = sync.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                if res.epoch_order(epoch + 1) != want.as_slice() {
                    return Err(format!(
                        "static stream != sync sharded at w={w} \
                         epoch={epoch} n={n} d={d} b={b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn elastic_frozen_weights_match_static_topology_exactly() {
    // Determinism contract 6, frozen half: an elastic coordinator whose
    // per-epoch schedule never changes is bit-identical to the static
    // weighted topology — over the channel transport AND loopback TCP,
    // for W in {1, 2, 4}, chained to unsharded PairBalance at W = 1
    // (equal weights there, so the W=1 gate still applies).
    prop::forall("elastic frozen == static (channel+tcp)", 6, |rng| {
        let n = 1 + rng.gen_range(48) as usize;
        let d = 1 + rng.gen_range(5) as usize;
        let b = 1 + rng.gen_range(8) as usize;
        let depth = 1 + rng.gen_range(3) as usize;
        let vs = gen::vec_set(rng, n, d);
        for w in [1usize, 2, 4] {
            let weights: Vec<u64> = if w == 1 {
                vec![1]
            } else {
                (0..w).map(|_| 1 + rng.gen_range(3)).collect()
            };
            let schedule = vec![weights.clone()];
            let mut static_ch =
                ShardedOrder::new_async_weighted(n, d, &weights, depth);
            let mut elastic_ch =
                ShardedOrder::new_scheduled(n, d, &schedule, depth);
            let mut static_tcp =
                ShardedOrder::new_tcp_loopback_weighted(n, d, &weights)
                    .map_err(|e| format!("loopback spawn: {e}"))?;
            let mut elastic_tcp =
                ShardedOrder::new_tcp_loopback_scheduled(n, d, &schedule)
                    .map_err(|e| format!("loopback spawn: {e}"))?;
            let mut pair = PairBalance::new(n, d);
            for epoch in 0..3 {
                feed_epoch(&mut static_ch, &vs, b);
                feed_epoch(&mut elastic_ch, &vs, b);
                feed_epoch(&mut static_tcp, &vs, b);
                feed_epoch(&mut elastic_tcp, &vs, b);
                feed_epoch(&mut pair, &vs, b);
                let want = static_ch.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                for (label, got) in [
                    ("elastic-channel", elastic_ch.epoch_order(0)),
                    ("static-tcp", static_tcp.epoch_order(0)),
                    ("elastic-tcp", elastic_tcp.epoch_order(0)),
                ] {
                    if got != want.as_slice() {
                        return Err(format!(
                            "{label} != static channel at w={w} \
                             epoch={epoch} n={n} d={d} b={b} \
                             weights={weights:?}"
                        ));
                    }
                }
                if w == 1 && pair.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "w=1 weighted != PairBalance at epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
            }
            // Frozen means frozen: no re-plan happened anywhere.
            if elastic_ch.topology().generation != 0
                || elastic_tcp.topology().generation != 0
            {
                return Err("frozen schedule re-planned".to_string());
            }
        }
        Ok(())
    });
}

#[test]
fn scheduled_shard_shrink_over_tcp_replans_and_replays() {
    // Contract 6, elastic half: a W=4 -> 3 mid-run topology change over
    // loopback TCP re-plans at the boundary (fresh Hellos at a bumped
    // generation), keeps every epoch a valid permutation of all n
    // units, and replays bit-for-bit from the same schedule.
    let n = 41;
    let d = 3;
    let vs = gen::vec_set(&mut grab::util::rng::Rng::new(13), n, d);
    let schedule = vec![
        vec![1u64, 1, 1, 1],
        vec![1u64, 1, 1, 1],
        vec![1u64, 1, 1],
    ];
    let mut orders = Vec::new();
    let mut p = ShardedOrder::new_tcp_loopback_scheduled(n, d, &schedule)
        .expect("loopback spawn");
    for _ in 0..4 {
        assert_permutation(p.epoch_order(0)).unwrap();
        orders.push(p.epoch_order(0).to_vec());
        feed_epoch(&mut p, &vs, 5);
    }
    assert_eq!(p.num_shards(), 3, "shrink must have landed");
    assert_eq!(p.topology().generation, 1, "exactly one re-plan");
    let log = ShardedOrder::topology_log(&p);
    assert_eq!(log[1].num_shards(), 4);
    assert_eq!(log[2].num_shards(), 3);
    // Replay over a fresh loopback pool: identical orders every epoch.
    let mut q = ShardedOrder::new_tcp_loopback_scheduled(n, d, &schedule)
        .expect("loopback spawn");
    for want in &orders {
        assert_eq!(q.epoch_order(0), want.as_slice());
        feed_epoch(&mut q, &vs, 5);
    }
}

#[test]
fn tcp_transport_handles_more_shards_than_units() {
    let d = 3;
    let mut rng = grab::util::rng::Rng::new(2);
    let vs = gen::vec_set(&mut rng, 3, d);
    let mut p = ShardedOrder::new_tcp_loopback(3, d, 8).unwrap();
    for _ in 0..2 {
        assert_permutation(p.epoch_order(0)).unwrap();
        feed_epoch(&mut p, &vs, 2);
    }
    assert_permutation(p.epoch_order(0)).unwrap();
}

#[test]
fn tcp_coordinator_reports_wire_traffic() {
    let d = 4;
    let n = 16;
    let mut rng = grab::util::rng::Rng::new(5);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_loopback(n, d, 2).unwrap();
    feed_epoch(&mut p, &vs, 4);
    let stats = p.transport_stats();
    assert_eq!(stats.transport, "tcp");
    assert_eq!(stats.per_shard.len(), 2);
    let total = stats.total();
    assert!(total.tx_bytes > 0, "no bytes shipped to workers");
    assert!(total.rx_bytes > 0, "no report bytes received");
    assert_eq!(total.stalls, 0, "tcp links do not count queue stalls");
}

#[test]
fn peer_disconnect_mid_epoch_surfaces_at_epoch_boundary() {
    // A worker that vanishes mid-epoch must not hang the coordinator or
    // kill it mid-stream: the failure surfaces at the epoch boundary
    // (the drain barrier), exactly like a worker panic does on the
    // channel transport.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        // Handshake properly, then die before the first epoch ends.
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        let mut scratch = Vec::new();
        write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
            .unwrap();
        drop(stream);
    });
    let n = 8;
    let d = 2;
    let mut rng = grab::util::rng::Rng::new(7);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_connect(
        &addr.to_string(), n, d, 1,
    )
    .unwrap();
    server.join().unwrap();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || feed_epoch(&mut p, &vs, 4), // ends with epoch_end
    ))
    .expect_err("dead peer must surface at the boundary");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string payload>".to_string());
    assert!(
        msg.contains("failed mid-epoch"),
        "unexpected boundary payload: {msg}"
    );
}

#[test]
fn corrupt_report_fails_at_boundary_with_a_typed_wire_error() {
    // A worker that answers the epoch boundary with a corrupted frame:
    // the coordinator must reject it via the checksum (typed WireError,
    // no partial order state) and raise at the boundary.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let n = 6;
    let d = 2;
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        let mut scratch = Vec::new();
        write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
            .unwrap();
        // Consume the epoch's traffic up to the boundary signal.
        loop {
            match read_frame(&mut stream, &mut buf) {
                Ok(FrameKind::EpochEnd) => break,
                Ok(_) => continue,
                Err(e) => panic!("server read: {e}"),
            }
        }
        // Reply with a report whose payload is flipped post-checksum.
        let order: Vec<usize> = (0..n).collect();
        let mut payload = Vec::new();
        codec::encode_report(&order, 64, &mut payload);
        let mut frame = Vec::new();
        encode_frame(FrameKind::Report, &payload, &mut frame);
        let last = frame.len() - 1;
        frame[last] ^= 0x40; // corrupt one payload byte
        stream.write_all(&frame).unwrap();
    });
    let mut rng = grab::util::rng::Rng::new(9);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_connect(
        &addr.to_string(), n, d, 1,
    )
    .unwrap();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || feed_epoch(&mut p, &vs, 3),
    ))
    .expect_err("corrupt report must fail the boundary");
    server.join().unwrap();
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string payload>".to_string());
    assert!(
        msg.contains("checksum") || msg.contains("wire error"),
        "boundary error should carry the wire diagnosis: {msg}"
    );
}

#[test]
fn handshake_failures_are_typed_errors_not_hangs() {
    // A peer that slams the door: construction fails with a typed
    // handshake error and leaves nothing half-open.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        drop(stream);
    });
    let err = ShardedOrder::new_tcp_connect(&addr.to_string(), 8, 2, 1)
        .expect_err("handshake must fail");
    assert!(
        err.to_string().contains("handshake"),
        "expected a handshake error, got: {err:#}"
    );
    server.join().unwrap();
}

// ---------------------------------------------------------------------
// Fault-injection suite (the CI `chaos` job): compiled only with
// `--features fault-injection`, run under the job's hard timeout so
// any hang is a fast failure.
// ---------------------------------------------------------------------

#[cfg(feature = "fault-injection")]
mod chaos {
    use super::*;
    use grab::ordering::topology::{Topology, WeightSource};
    use grab::ordering::transport::fault::{FaultPlan, FaultTransport};
    use grab::ordering::transport::{
        spawn_channel_shards, tcp, ChannelTransport, Relink,
        ShardTransport, TransportError,
    };

    /// Drive one full epoch of `n` rows through a raw link in 2-row
    /// blocks and return the boundary outcome.
    fn drive_link_epoch(
        link: &mut dyn ShardTransport,
        n: usize,
        d: usize,
    ) -> Result<Vec<usize>, TransportError> {
        let mut sent = 0usize;
        while sent < n {
            let rows = 2.min(n - sent);
            let Some(mut scratch) = link.acquire() else {
                break; // dead link: fall through to the boundary
            };
            for r in 0..rows {
                let row: Vec<f32> = (0..d)
                    .map(|j| ((sent + r) * d + j) as f32 - 3.0)
                    .collect();
                scratch.push_row(&row);
            }
            let _ = link.send_block(scratch);
            sent += rows;
        }
        let _ = link.end_epoch();
        link.recv_report().map(|r| r.order)
    }

    #[test]
    fn dropped_block_over_tcp_surfaces_as_typed_boundary_error() {
        // A silently dropped block means the worker sees a short
        // epoch: it must reject at EpochEnd and the coordinator side
        // must get a typed error — no hang, no bogus report.
        let addr = tcp::spawn_loopback(1).unwrap();
        let (n, d) = (8, 2);
        let inner = tcp::connect(addr, n, d, 0, tcp::default_read_timeout()).unwrap();
        let mut link = FaultTransport::new(
            Box::new(inner),
            FaultPlan::drop_block(1),
        );
        let err = drive_link_epoch(&mut link, n, d)
            .expect_err("short epoch must be rejected");
        let msg = err.to_string();
        assert!(!msg.is_empty(), "typed error expected, got: {msg}");
        assert!(
            link.injected().iter().any(|f| f.contains("drop")),
            "the drop was never injected: {:?}",
            link.injected()
        );
    }

    #[test]
    fn duplicated_block_over_tcp_surfaces_as_typed_boundary_error() {
        // A duplicated block overflows the worker's epoch row budget:
        // typed rejection, never a silent double-balance.
        let addr = tcp::spawn_loopback(1).unwrap();
        let (n, d) = (8, 2);
        let inner = tcp::connect(addr, n, d, 0, tcp::default_read_timeout()).unwrap();
        let mut link = FaultTransport::new(
            Box::new(inner),
            FaultPlan::duplicate_block(3),
        );
        let err = drive_link_epoch(&mut link, n, d)
            .expect_err("overflowing epoch must be rejected");
        assert!(!err.to_string().is_empty());
        assert!(link
            .injected()
            .iter()
            .any(|f| f.contains("duplicate")));
    }

    #[test]
    fn seeded_drop_schedules_always_surface_at_the_boundary() {
        // Chaos sweep: across seeds, a seeded drop index anywhere in
        // the epoch must surface as a typed error (the schedule is
        // pure in the seed, so any failure here reproduces exactly).
        for seed in 0..6u64 {
            let plan = FaultPlan::seeded(seed, 4);
            let drop_at = plan.drop_blocks[0];
            let addr = tcp::spawn_loopback(1).unwrap();
            let (n, d) = (8, 3);
            let inner = tcp::connect(addr, n, d, 0, tcp::default_read_timeout()).unwrap();
            let mut link = FaultTransport::new(
                Box::new(inner),
                FaultPlan::drop_block(drop_at),
            );
            drive_link_epoch(&mut link, n, d).expect_err(
                "seeded drop must produce a typed boundary error",
            );
        }
    }

    #[test]
    fn delayed_blocks_do_not_change_the_report() {
        // A delay is a benign fault: the link stays order-preserving,
        // so the worker's report must equal an unfaulted twin's.
        let (n, d) = (6, 2);
        let mut plain: Box<dyn ShardTransport> =
            Box::new(ChannelTransport::spawn(n, d, 2));
        let mut delayed = FaultTransport::new(
            Box::new(ChannelTransport::spawn(n, d, 2)),
            FaultPlan {
                delay_blocks: vec![(0, 3), (2, 2)],
                ..FaultPlan::default()
            },
        );
        let a = drive_link_epoch(plain.as_mut(), n, d).unwrap();
        let b = drive_link_epoch(&mut delayed, n, d).unwrap();
        assert_eq!(a, b, "delay changed the epoch report");
        assert_eq!(delayed.injected().len(), 2);
    }

    #[test]
    fn dropped_block_on_channel_worker_panics_at_the_boundary() {
        // The in-process channel worker's short-epoch guard: dropped
        // rows surface as the worker's own boundary panic (re-raised
        // by recv_report), not a silently partial order.
        let mut p = {
            let n = 12;
            let d = 2;
            let links: Vec<Box<dyn ShardTransport>> = vec![
                Box::new(ChannelTransport::spawn(6, d, 2)),
                Box::new(FaultTransport::new(
                    Box::new(ChannelTransport::spawn(6, d, 2)),
                    FaultPlan::drop_block(0),
                )),
            ];
            ShardedOrder::from_links(
                n,
                d,
                Topology::equal(n, 2),
                links,
                "channel",
                None,
            )
        };
        let vs = gen::vec_set(&mut grab::util::rng::Rng::new(3), 12, 2);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                feed_epoch(&mut p, &vs, 4); // ends with epoch_end
            }),
        )
        .expect_err("short epoch must panic at the boundary");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string payload>".to_string());
        assert!(
            msg.contains("epoch ended after"),
            "unexpected payload: {msg}"
        );
    }

    #[test]
    fn elastic_coordinator_survives_injected_disconnect_and_replans() {
        // The headline chaos case: one of four channel links is killed
        // mid-epoch. The elastic coordinator must finish the epoch,
        // surface the loss at the boundary, re-plan the next epoch
        // over the three survivors, and keep emitting valid
        // permutations of all n units — no hang, no partial state.
        let n = 24;
        let d = 2;
        let depth = 2;
        let mut links: Vec<Box<dyn ShardTransport>> =
            spawn_channel_shards(
                &Topology::equal(n, 4).sizes,
                d,
                depth,
            );
        // Wrap shard 2 with a mid-epoch disconnect.
        let victim = links.remove(2);
        links.insert(
            2,
            Box::new(FaultTransport::new(
                victim,
                FaultPlan::disconnect_before(1),
            )),
        );
        let relink: Relink = Box::new(move |sizes, _gen| {
            Ok(spawn_channel_shards(sizes, d, depth))
        });
        let planner =
            grab::ordering::topology::ElasticPlanner::new(4);
        let mut p = ShardedOrder::from_links(
            n,
            d,
            Topology::equal(n, 4),
            links,
            "channel",
            Some((WeightSource::Measured(planner), relink)),
        );
        let vs = gen::vec_set(&mut grab::util::rng::Rng::new(7), n, d);
        for epoch in 0..3 {
            assert_permutation(p.epoch_order(0)).unwrap();
            feed_epoch(&mut p, &vs, 4);
            if epoch == 0 {
                assert_eq!(
                    p.num_shards(),
                    3,
                    "lost shard must be dropped from the plan"
                );
                assert!(p.topology().generation >= 1);
            }
        }
        assert_permutation(p.epoch_order(0)).unwrap();
        let log = ShardedOrder::topology_log(&p);
        assert_eq!(log[0].num_shards(), 4);
        assert_eq!(log[1].num_shards(), 3);
    }
}

#[test]
fn oversized_frame_header_from_peer_is_rejected() {
    // A worker answering with a length prefix beyond the protocol cap:
    // the coordinator must reject the header before trying to read (or
    // allocate) the declared payload.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        // Hand-build an "ack" whose header declares ~4 GiB of payload.
        let mut frame = Vec::new();
        encode_frame(FrameKind::Ack, &[], &mut frame);
        frame[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        stream.write_all(&frame).unwrap();
        assert_eq!(frame.len(), FRAME_HEADER_LEN);
    });
    let err = ShardedOrder::new_tcp_connect(&addr.to_string(), 4, 2, 1)
        .expect_err("oversized header must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("handshake"),
        "expected handshake-stage rejection, got: {msg}"
    );
    server.join().unwrap();
}
