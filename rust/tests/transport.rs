//! Transport-equivalence test layer (determinism contract 5,
//! docs/determinism.md): every order-exchange transport — synchronous
//! inline dispatch, in-process channel workers, loopback TCP sockets —
//! must produce **bit-identical** CD-GraB epoch orders for the same
//! gradient stream, and transport failures must surface as typed
//! boundary errors, never hangs or partial coordinator state.
//!
//! These tests need no artifacts (they run on synthetic gradient
//! streams) but do open real loopback sockets; CI runs this target
//! under a timeout guard so a hung socket fails fast.

use std::io::Write;
use std::net::TcpListener;

use grab::ordering::transport::codec;
use grab::ordering::{
    stream_static_epoch, OrderPolicy, PairBalance, ShardedOrder,
};
use grab::util::prop::{self, assert_permutation, gen};
use grab::util::ser::{
    encode_frame, read_frame, write_frame, FrameKind, FRAME_HEADER_LEN,
};

fn feed_epoch(p: &mut dyn OrderPolicy, vs: &[Vec<f32>], block: usize) {
    let mut flat = Vec::new();
    stream_static_epoch(p, vs, &mut flat, block);
}

#[test]
fn loopback_tcp_matches_channel_and_sync_orders() {
    // The tentpole property: for W in {1, 2, 4} over random
    // n/d/block/depth, loopback-TCP ≡ async-mpsc ≡ sync epoch orders
    // across multiple epochs. At W = 1 the chain extends through the
    // existing gate to unsharded PairBalance, so socket CD-GraB is
    // pinned all the way down to the single-threaded reference.
    prop::forall("tcp == channel == sync sharded orders", 8, |rng| {
        let n = 1 + rng.gen_range(60) as usize;
        let d = 1 + rng.gen_range(6) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let depth = 1 + rng.gen_range(4) as usize;
        let vs = gen::vec_set(rng, n, d);
        for w in [1usize, 2, 4] {
            let mut strided = ShardedOrder::new(n, d, w);
            let mut channel = ShardedOrder::new_async(n, d, w, depth);
            let mut socket = ShardedOrder::new_tcp_loopback(n, d, w)
                .map_err(|e| format!("loopback spawn: {e}"))?;
            let mut pair = PairBalance::new(n, d);
            for epoch in 0..3 {
                feed_epoch(&mut strided, &vs, b);
                feed_epoch(&mut channel, &vs, b);
                feed_epoch(&mut socket, &vs, b);
                feed_epoch(&mut pair, &vs, b);
                let want = strided.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                if channel.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "channel != sync at w={w} epoch={epoch} \
                         n={n} d={d} b={b} depth={depth}"
                    ));
                }
                if socket.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "tcp != sync at w={w} epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
                if w == 1 && pair.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "w=1 sharded != PairBalance at epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn tcp_transport_handles_more_shards_than_units() {
    let d = 3;
    let mut rng = grab::util::rng::Rng::new(2);
    let vs = gen::vec_set(&mut rng, 3, d);
    let mut p = ShardedOrder::new_tcp_loopback(3, d, 8).unwrap();
    for _ in 0..2 {
        assert_permutation(p.epoch_order(0)).unwrap();
        feed_epoch(&mut p, &vs, 2);
    }
    assert_permutation(p.epoch_order(0)).unwrap();
}

#[test]
fn tcp_coordinator_reports_wire_traffic() {
    let d = 4;
    let n = 16;
    let mut rng = grab::util::rng::Rng::new(5);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_loopback(n, d, 2).unwrap();
    feed_epoch(&mut p, &vs, 4);
    let stats = p.transport_stats();
    assert_eq!(stats.transport, "tcp");
    assert_eq!(stats.per_shard.len(), 2);
    let total = stats.total();
    assert!(total.tx_bytes > 0, "no bytes shipped to workers");
    assert!(total.rx_bytes > 0, "no report bytes received");
    assert_eq!(total.stalls, 0, "tcp links do not count queue stalls");
}

#[test]
fn peer_disconnect_mid_epoch_surfaces_at_epoch_boundary() {
    // A worker that vanishes mid-epoch must not hang the coordinator or
    // kill it mid-stream: the failure surfaces at the epoch boundary
    // (the drain barrier), exactly like a worker panic does on the
    // channel transport.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        // Handshake properly, then die before the first epoch ends.
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        let mut scratch = Vec::new();
        write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
            .unwrap();
        drop(stream);
    });
    let n = 8;
    let d = 2;
    let mut rng = grab::util::rng::Rng::new(7);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_connect(
        &addr.to_string(), n, d, 1,
    )
    .unwrap();
    server.join().unwrap();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || feed_epoch(&mut p, &vs, 4), // ends with epoch_end
    ))
    .expect_err("dead peer must surface at the boundary");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string payload>".to_string());
    assert!(
        msg.contains("failed mid-epoch"),
        "unexpected boundary payload: {msg}"
    );
}

#[test]
fn corrupt_report_fails_at_boundary_with_a_typed_wire_error() {
    // A worker that answers the epoch boundary with a corrupted frame:
    // the coordinator must reject it via the checksum (typed WireError,
    // no partial order state) and raise at the boundary.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let n = 6;
    let d = 2;
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        let mut scratch = Vec::new();
        write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
            .unwrap();
        // Consume the epoch's traffic up to the boundary signal.
        loop {
            match read_frame(&mut stream, &mut buf) {
                Ok(FrameKind::EpochEnd) => break,
                Ok(_) => continue,
                Err(e) => panic!("server read: {e}"),
            }
        }
        // Reply with a report whose payload is flipped post-checksum.
        let order: Vec<usize> = (0..n).collect();
        let mut payload = Vec::new();
        codec::encode_report(&order, 64, &mut payload);
        let mut frame = Vec::new();
        encode_frame(FrameKind::Report, &payload, &mut frame);
        let last = frame.len() - 1;
        frame[last] ^= 0x40; // corrupt one payload byte
        stream.write_all(&frame).unwrap();
    });
    let mut rng = grab::util::rng::Rng::new(9);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut p = ShardedOrder::new_tcp_connect(
        &addr.to_string(), n, d, 1,
    )
    .unwrap();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || feed_epoch(&mut p, &vs, 3),
    ))
    .expect_err("corrupt report must fail the boundary");
    server.join().unwrap();
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string payload>".to_string());
    assert!(
        msg.contains("checksum") || msg.contains("wire error"),
        "boundary error should carry the wire diagnosis: {msg}"
    );
}

#[test]
fn handshake_failures_are_typed_errors_not_hangs() {
    // A peer that slams the door: construction fails with a typed
    // handshake error and leaves nothing half-open.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        drop(stream);
    });
    let err = ShardedOrder::new_tcp_connect(&addr.to_string(), 8, 2, 1)
        .expect_err("handshake must fail");
    assert!(
        err.to_string().contains("handshake"),
        "expected a handshake error, got: {err:#}"
    );
    server.join().unwrap();
}

#[test]
fn oversized_frame_header_from_peer_is_rejected() {
    // A worker answering with a length prefix beyond the protocol cap:
    // the coordinator must reject the header before trying to read (or
    // allocate) the declared payload.
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut buf = Vec::new();
        assert_eq!(
            read_frame(&mut stream, &mut buf).unwrap(),
            FrameKind::Hello
        );
        // Hand-build an "ack" whose header declares ~4 GiB of payload.
        let mut frame = Vec::new();
        encode_frame(FrameKind::Ack, &[], &mut frame);
        frame[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        stream.write_all(&frame).unwrap();
        assert_eq!(frame.len(), FRAME_HEADER_LEN);
    });
    let err = ShardedOrder::new_tcp_connect(&addr.to_string(), 4, 2, 1)
        .expect_err("oversized header must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("handshake"),
        "expected handshake-stage rejection, got: {msg}"
    );
    server.join().unwrap();
}
