//! Order-service layer tests: control-plane endpoint contracts, the
//! worker registration lifecycle, drain semantics, and the service
//! smoke — a daemon job's per-epoch orders must be bit-equal to the
//! in-process synchronous coordinator at the same parameters
//! (docs/determinism.md contract 5 over the registered-worker path).
//!
//! Everything runs in-process on port 0: the daemon is an
//! [`OrderService`] handle, the "remote" workers are threads running
//! the same `run_registered_worker` loop that `grab exp cdgrab
//! --register` runs, and the control plane is exercised through the
//! same `service::http` client the `--service` mode uses. The
//! two-*process* version of the same chain is the CI `service` job.

use std::time::{Duration, Instant};

use grab::exp::cdgrab::CdGrabConfig;
use grab::ordering::stream::{DriftPlan, StreamOrder};
use grab::ordering::transport::tcp;
use grab::ordering::{OrderPolicy, ShardedOrder};
use grab::service::http;
use grab::service::{
    order_hash, JobKind, JobSpec, OrderService, ServeConfig,
};
use grab::util::prop::gen;
use grab::util::rng::Rng;
use grab::util::ser::Json;
use grab::util::testdir::TestDir;

/// An in-process daemon on ephemeral ports.
fn start_service() -> OrderService {
    OrderService::start(&ServeConfig {
        register_addr: "127.0.0.1:0".to_string(),
        http_addr: "127.0.0.1:0".to_string(),
        read_timeout_secs: 30,
    })
    .expect("daemon starts on port 0")
}

/// Spawn `count` registered-worker threads against `register_addr`.
fn spawn_workers(
    register_addr: &str,
    count: usize,
) -> Vec<std::thread::JoinHandle<anyhow::Result<()>>> {
    (0..count)
        .map(|_| {
            let addr = register_addr.to_string();
            std::thread::spawn(move || {
                tcp::run_registered_worker(
                    &addr,
                    Duration::from_secs(10),
                )
            })
        })
        .collect()
}

/// Poll `/health` until `workers_available` reaches `want`.
fn wait_for_workers(http_addr: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (status, body) = http::get(http_addr, "/health").unwrap();
        assert_eq!(status, 200);
        let v = Json::parse(&body).unwrap();
        let have = v
            .get("workers_available")
            .unwrap()
            .as_usize()
            .unwrap();
        if have >= want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "only {have}/{want} workers registered before the deadline"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Poll `/jobs/<id>` until it leaves `running`; panics on the deadline.
fn wait_for_job(http_addr: &str, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        assert!(
            Instant::now() < deadline,
            "job {id} still running at the deadline"
        );
        std::thread::sleep(Duration::from_millis(50));
        let (status, body) =
            http::get(http_addr, &format!("/jobs/{id}")).unwrap();
        assert_eq!(status, 200, "GET /jobs/{id}: {body}");
        let v = Json::parse(&body).unwrap();
        if v.get("status").unwrap().as_str().unwrap() != "running" {
            return v;
        }
    }
}

/// Pull one metric value out of a `/metrics` scrape.
fn metric(http_addr: &str, name: &str) -> u64 {
    let (status, text) = http::get(http_addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse().unwrap();
            }
        }
    }
    panic!("metric {name} missing from exposition:\n{text}");
}

#[test]
fn control_plane_endpoint_contracts() {
    let service = start_service();
    let addr = service.http_addr();

    // Health: empty daemon, not draining.
    let (status, body) = http::get(&addr, "/health").unwrap();
    assert_eq!(status, 200);
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(
        v.get("workers_available").unwrap().as_usize().unwrap(),
        0
    );
    assert_eq!(v.get("generation").unwrap().as_usize().unwrap(), 1);

    // Metrics: parseable exposition with the gauges at zero.
    assert_eq!(metric(&addr, "grab_workers_available"), 0);
    assert_eq!(metric(&addr, "grab_jobs_submitted_total"), 0);
    assert_eq!(metric(&addr, "grab_draining"), 0);

    // Unknown route → 404; wrong method → 405; garbage body → 400.
    let (status, _) = http::get(&addr, "/nope").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http::post(&addr, "/health", "").unwrap();
    assert_eq!(status, 405);
    let (status, body) =
        http::post(&addr, "/jobs", "not json").unwrap();
    assert_eq!(status, 400, "{body}");

    // A well-formed job with no workers is refused, and the refusal
    // burns no job id.
    let spec = JobSpec {
        kind: JobKind::CdGrab,
        n: 64,
        d: 4,
        epochs: 1,
        block: 8,
        shards: 1,
        seed: 0,
        admit_rate: 0,
    };
    let (status, body) =
        http::post(&addr, "/jobs", &spec.to_json().to_string()).unwrap();
    assert_eq!(status, 409, "{body}");
    assert_eq!(metric(&addr, "grab_jobs_submitted_total"), 0);

    // Spec validation happens before leasing: zero shards is a 400.
    let (status, body) = http::post(
        &addr,
        "/jobs",
        "{\"n\":64,\"d\":4,\"epochs\":1,\"block\":8,\"shards\":0,\"seed\":0}",
    )
    .unwrap();
    assert_eq!(status, 400, "{body}");

    service.shutdown();
}

#[test]
fn workers_register_lease_and_drain_cleanly() {
    let service = start_service();
    let addr = service.http_addr();
    let workers = spawn_workers(&service.register_addr(), 2);
    wait_for_workers(&addr, 2);

    assert_eq!(metric(&addr, "grab_registrations_total"), 2);
    assert_eq!(metric(&addr, "grab_workers_available"), 2);
    assert_eq!(metric(&addr, "grab_workers_leased"), 0);

    // Shutdown = drain: idle sockets close between sessions and the
    // workers exit 0, exactly like SIGTERM on the real daemon.
    service.shutdown();
    for w in workers {
        w.join()
            .expect("worker thread exits")
            .expect("worker exits cleanly after a drain");
    }
}

#[test]
fn daemon_job_is_bit_equal_to_the_in_process_coordinator() {
    let service = start_service();
    let addr = service.http_addr();
    let workers = spawn_workers(&service.register_addr(), 2);
    wait_for_workers(&addr, 2);

    let spec = JobSpec {
        kind: JobKind::CdGrab,
        n: 256,
        d: 16,
        epochs: 3,
        block: 32,
        shards: 2,
        seed: 7,
        admit_rate: 0,
    };
    let (status, body) =
        http::post(&addr, "/jobs", &spec.to_json().to_string()).unwrap();
    assert_eq!(status, 202, "{body}");
    let job_id =
        Json::parse(&body).unwrap().get("job").unwrap().as_usize().unwrap()
            as u64;
    assert_eq!(job_id, 0);

    let job = wait_for_job(&addr, job_id);
    assert_eq!(
        job.get("status").unwrap().as_str().unwrap(),
        "done",
        "{job:?}"
    );
    let daemon_hashes: Vec<u32> = job
        .get("epoch_hashes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u32)
        .collect();
    let job_tx = job.get("tx_bytes").unwrap().as_f64().unwrap() as u64;
    let job_rx = job.get("rx_bytes").unwrap().as_f64().unwrap() as u64;
    assert!(job_tx > 0 && job_rx > 0, "job moved no bytes");

    // The contract-5 gate: same (n, d, block, W, seed) through the
    // in-process synchronous coordinator.
    let mut rng = Rng::new(spec.seed);
    let vs = gen::vec_set(&mut rng, spec.n, spec.d);
    let mut flat = vec![0.0f32; spec.n * spec.d];
    let mut policy = ShardedOrder::new(spec.n, spec.d, spec.shards);
    let mut local_hashes = Vec::new();
    for epoch in 0..spec.epochs {
        grab::ordering::stream_static_epoch(
            &mut policy,
            epoch,
            &vs,
            &mut flat,
            spec.block,
        );
        local_hashes.push(order_hash(policy.epoch_order(epoch + 1)));
    }
    assert_eq!(
        daemon_hashes, local_hashes,
        "daemon orders diverge from the in-process coordinator"
    );

    // One lease = one session: the daemon closed both sockets at the
    // job boundary and the workers re-registered fresh.
    wait_for_workers(&addr, 2);
    assert_eq!(metric(&addr, "grab_registrations_total"), 4);

    // The exported transport counters are exactly this job's totals.
    assert_eq!(metric(&addr, "grab_jobs_completed_total"), 1);
    assert_eq!(metric(&addr, "grab_jobs_failed_total"), 0);
    assert_eq!(
        metric(&addr, "grab_transport_tx_bytes_total"),
        job_tx
    );
    assert_eq!(
        metric(&addr, "grab_transport_rx_bytes_total"),
        job_rx
    );
    assert_eq!(
        metric(&addr, "grab_job_epochs_total"),
        spec.epochs as u64
    );

    service.shutdown();
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

/// A `stream` daemon job over real leased TCP links must replay
/// bit-for-bit against an in-process channel-backed reservoir driving
/// the identical frozen `DriftPlan::steady` schedule — determinism
/// contract 9 (docs/determinism.md) carried over the registered-worker
/// path — and the per-window reservoir counters must land in both the
/// job record and `/metrics`.
#[test]
fn stream_job_is_bit_equal_to_an_in_process_reservoir() {
    let service = start_service();
    let addr = service.http_addr();
    let workers = spawn_workers(&service.register_addr(), 2);
    wait_for_workers(&addr, 2);

    let spec = JobSpec {
        kind: JobKind::Stream,
        n: 96,
        d: 8,
        epochs: 4,
        block: 16,
        shards: 2,
        seed: 11,
        admit_rate: 3,
    };
    let (status, body) =
        http::post(&addr, "/jobs", &spec.to_json().to_string()).unwrap();
    assert_eq!(status, 202, "{body}");
    let job_id =
        Json::parse(&body).unwrap().get("job").unwrap().as_usize().unwrap()
            as u64;

    let job = wait_for_job(&addr, job_id);
    assert_eq!(
        job.get("status").unwrap().as_str().unwrap(),
        "done",
        "{job:?}"
    );
    assert_eq!(job.get("kind").unwrap().as_str().unwrap(), "stream");
    let daemon_hashes: Vec<u32> = job
        .get("epoch_hashes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u32)
        .collect();
    let daemon_herd: Vec<f64> = job
        .get("herd_inf")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(daemon_hashes.len(), spec.epochs);

    // The count-neutral steady schedule on a full reservoir: every
    // window admits `admit_rate` fresh units and FIFO-evicts as many,
    // so the fixed leased links never re-link.
    let windows = job.get("windows").unwrap().as_f64().unwrap() as u64;
    let admits = job.get("admits").unwrap().as_f64().unwrap() as u64;
    let evictions =
        job.get("evictions").unwrap().as_f64().unwrap() as u64;
    let replans = job.get("replans").unwrap().as_f64().unwrap() as u64;
    assert_eq!(windows, spec.epochs as u64);
    assert_eq!(admits, (spec.epochs * spec.admit_rate) as u64);
    assert_eq!(evictions, admits, "steady churn is count-neutral");
    assert_eq!(replans, 0, "fixed links must never re-link");

    // The contract-9 gate: an in-process channel-backed reservoir
    // replaying the identical frozen schedule.
    let units: Vec<u64> = (0..spec.n as u64).collect();
    let mut local = StreamOrder::sharded_channel(
        spec.n,
        spec.d,
        &units,
        spec.shards,
        2,
    );
    let drift = DriftPlan::steady(spec.seed, spec.admit_rate);
    let mut next_unit = spec.n as u64;
    let mut local_hashes = Vec::new();
    let mut local_herd = Vec::new();
    for window in 0..spec.epochs {
        local.drive_window(&drift, &mut next_unit, spec.block);
        local_hashes.push(order_hash(local.epoch_order(window + 1)));
        local_herd.push(local.stats().last_window_inf as f64);
    }
    assert_eq!(
        daemon_hashes, local_hashes,
        "daemon reservoir orders diverge from the in-process replay"
    );
    for (w, (a, b)) in
        daemon_herd.iter().zip(local_herd.iter()).enumerate()
    {
        assert!(
            (a - b).abs() <= 1e-6 * b.abs().max(1.0),
            "window {w} herding bound diverges: daemon {a} vs local {b}"
        );
    }

    // Reservoir counters surface in the exposition too.
    assert_eq!(
        metric(&addr, "grab_stream_windows_total"),
        spec.epochs as u64
    );
    assert_eq!(metric(&addr, "grab_stream_admits_total"), admits);
    assert_eq!(metric(&addr, "grab_stream_evictions_total"), evictions);
    assert_eq!(
        metric(&addr, "grab_job_epochs_total"),
        spec.epochs as u64
    );

    // Spec validation: admit_rate is stream-only and capacity-bounded.
    let (status, body) = http::post(
        &addr,
        "/jobs",
        "{\"n\":64,\"d\":4,\"epochs\":1,\"block\":8,\"shards\":1,\
         \"seed\":0,\"admit_rate\":2}",
    )
    .unwrap();
    assert_eq!(status, 400, "{body}");
    let (status, body) = http::post(
        &addr,
        "/jobs",
        "{\"kind\":\"stream\",\"n\":64,\"d\":4,\"epochs\":1,\
         \"block\":8,\"shards\":1,\"seed\":0,\"admit_rate\":65}",
    )
    .unwrap();
    assert_eq!(status, 400, "{body}");

    service.shutdown();
    for w in workers {
        w.join().unwrap().unwrap();
    }
}

#[test]
fn drain_refuses_new_registrations_and_jobs() {
    let service = start_service();
    let addr = service.http_addr();

    let (status, body) = http::post(&addr, "/drain", "").unwrap();
    assert_eq!(status, 200, "{body}");

    let (_, body) = http::get(&addr, "/health").unwrap();
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "draining");
    assert_eq!(metric(&addr, "grab_draining"), 1);

    // New work is refused with a 503.
    let spec = JobSpec {
        kind: JobKind::CdGrab,
        n: 64,
        d: 4,
        epochs: 1,
        block: 8,
        shards: 1,
        seed: 0,
        admit_rate: 0,
    };
    let (status, body) =
        http::post(&addr, "/jobs", &spec.to_json().to_string()).unwrap();
    assert_eq!(status, 503, "{body}");

    // A draining daemon turns registrations away (the worker's dial
    // succeeds, the lease never comes).
    let refused = tcp::register_with_daemon(
        &service.register_addr(),
        "late-worker",
        Duration::from_secs(5),
    );
    assert!(refused.is_err(), "draining daemon must refuse to lease");
    assert!(metric(&addr, "grab_registrations_refused_total") >= 1);

    service.shutdown();
}

/// The `--service` client end-to-end: submit, poll, verify against the
/// local reference, write the CSV — the same code path the CI smoke
/// drives across two real processes.
#[test]
fn service_client_gates_the_daemon_and_writes_the_csv() {
    let service = start_service();
    let workers = spawn_workers(&service.register_addr(), 2);
    wait_for_workers(&service.http_addr(), 2);

    let cfg = CdGrabConfig {
        n: 256,
        d: 16,
        epochs: 3,
        block: 32,
        ..CdGrabConfig::small()
    };
    let dir = TestDir::new("service-client");
    grab::service::client::run_job_against_daemon(
        &service.http_addr(),
        &cfg,
        dir.path(),
    )
    .expect("client verifies the daemon against the local reference");
    let csv = std::fs::read_to_string(dir.path().join("service_job.csv"))
        .expect("client wrote service_job.csv");
    assert_eq!(csv.lines().count(), cfg.epochs + 1, "header + one row/epoch");
    assert!(csv.starts_with("epoch,daemon_hash,local_hash"));

    service.shutdown();
    for w in workers {
        w.join().unwrap().unwrap();
    }
}
