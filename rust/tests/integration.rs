//! Integration tests across runtime + trainer + pipeline + ordering.
//!
//! These need `artifacts/` (run `make artifacts`); if absent they skip
//! (keeps `cargo test` usable before the python toolchain has run).

use grab::config::{BalancerKind, OrderingKind, Task, TrainConfig};
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::tensor;
use grab::train::Trainer;
use grab::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::open("artifacts").expect("runtime"))
}

fn tiny_cfg(task: Task, ordering: OrderingKind) -> TrainConfig {
    let mut cfg = TrainConfig::for_task(task);
    cfg.ordering = ordering;
    cfg.epochs = 2;
    cfg.n_examples = 128;
    cfg.n_eval = 256; // >= largest eval batch
    cfg.seed = 1;
    cfg
}

#[test]
fn manifest_covers_all_tasks() {
    let Some(rt) = runtime() else { return };
    for task in [Task::Mnist, Task::Cifar, Task::Wiki, Task::Glue] {
        let entry = rt.manifest.model(task.model_name()).unwrap();
        assert!(entry.dim > 0);
        assert!(entry.batch > 0);
        let params = rt.init_params(task.model_name()).unwrap();
        assert_eq!(params.len(), entry.dim);
        assert!(params.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn grad_executor_outputs_are_sane() {
    let Some(rt) = runtime() else { return };
    let exec = rt.grad_executor("logreg").unwrap();
    let b = exec.batch();
    let d = exec.dim();
    let params = rt.init_params("logreg").unwrap();
    let mut rng = Rng::new(0);
    let x: Vec<f32> = (0..b * 784).map(|_| rng.f32()).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.gen_range(10) as i32).collect();
    let mut losses = Vec::new();
    let mut grads = Vec::new();
    exec.run(&params, &x, &[], &y, &mut losses, &mut grads).unwrap();
    assert_eq!(losses.len(), b);
    assert_eq!(grads.len(), b * d);
    assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
    assert!(grads.iter().all(|g| g.is_finite()));
    // At uniform-ish init, CE loss should be near ln(10).
    let mean = losses.iter().sum::<f32>() / b as f32;
    assert!((mean - 10f32.ln()).abs() < 1.0, "mean loss {mean}");
}

#[test]
fn mean_per_example_grad_descends_loss() {
    // One SGD step along the mean per-example gradient must reduce the
    // eval loss on the same batch (cross-checks L2 grads against the
    // eval artifact — two independent HLO programs).
    let Some(rt) = runtime() else { return };
    let gexec = rt.grad_executor("logreg").unwrap();
    let eexec = rt.eval_executor("logreg").unwrap();
    let b = gexec.batch();
    let e = eexec.batch();
    assert_eq!(e % b, 0);
    let d = gexec.dim();
    let mut params = rt.init_params("logreg").unwrap();
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..e * 784).map(|_| rng.f32()).collect();
    let y: Vec<i32> = (0..e).map(|_| rng.gen_range(10) as i32).collect();

    let (loss0, _) = eexec.run(&params, &x, &[], &y).unwrap();

    // Accumulate mean grad over the eval batch using the grad artifact.
    let mut mean = vec![0.0f32; d];
    let mut losses = Vec::new();
    let mut grads = Vec::new();
    for chunk in 0..e / b {
        let xs = &x[chunk * b * 784..(chunk + 1) * b * 784];
        let ys = &y[chunk * b..(chunk + 1) * b];
        gexec
            .run(&params, xs, &[], ys, &mut losses, &mut grads)
            .unwrap();
        for i in 0..b {
            tensor::axpy(
                1.0 / e as f32,
                &grads[i * d..(i + 1) * d],
                &mut mean,
            );
        }
    }
    tensor::axpy(-0.05, &mean.clone(), &mut params); // small SGD step
    let (loss1, _) = eexec.run(&params, &x, &[], &y).unwrap();
    assert!(
        loss1 < loss0,
        "gradient step must descend: {loss0} -> {loss1}"
    );
}

#[test]
fn all_orderings_train_mnist() {
    let Some(rt) = runtime() else { return };
    for ordering in [
        OrderingKind::RandomReshuffle,
        OrderingKind::ShuffleOnce,
        OrderingKind::FlipFlop,
        OrderingKind::GreedyOrdering,
        OrderingKind::GraB,
        OrderingKind::OneStepGraB,
        OrderingKind::PairBalance,
        OrderingKind::ShardedPairBalance,
        OrderingKind::Sequential,
    ] {
        let cfg = tiny_cfg(Task::Mnist, ordering);
        let mut t = Trainer::new(cfg, &rt, None).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.epochs.len(), 2, "{ordering:?}");
        assert!(
            r.epochs.iter().all(|m| m.train_loss.is_finite()),
            "{ordering:?}"
        );
        // Every epoch visits every unit exactly once.
        let mut order = r.final_order.clone();
        order.sort_unstable();
        assert_eq!(order, (0..128).collect::<Vec<_>>(), "{ordering:?}");
    }
}

#[test]
fn retrain_from_grab_replays_order() {
    let Some(rt) = runtime() else { return };
    let mut t =
        Trainer::new(tiny_cfg(Task::Mnist, OrderingKind::GraB), &rt, None)
            .unwrap();
    let source = t.run().unwrap();
    let cfg = tiny_cfg(Task::Mnist, OrderingKind::RetrainFromGraB);
    let mut t2 =
        Trainer::new(cfg, &rt, Some(source.final_order.clone())).unwrap();
    let r = t2.run().unwrap();
    assert_eq!(r.final_order, source.final_order);
}

#[test]
fn pipeline_matches_sync_exactly() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    cfg.epochs = 3;
    cfg.n_examples = 256;
    let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
    let sr = sync.run().unwrap();
    let mut pipe = PipelineTrainer::new(cfg, &rt).unwrap();
    let pr = pipe.run().unwrap();
    assert_eq!(sr.epochs.len(), pr.epochs.len());
    for (a, b) in sr.epochs.iter().zip(&pr.epochs) {
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-9,
            "epoch {} sync {} vs pipeline {}",
            a.epoch,
            a.train_loss,
            b.train_loss
        );
    }
    assert_eq!(sr.final_order, pr.final_order);
}

#[test]
fn pipeline_matches_sync_epoch_orders_at_every_boundary() {
    // The block-API equivalence gate: Trainer and PipelineTrainer must
    // produce byte-identical GraB orders at EVERY epoch boundary, not
    // just the last one — both stream the same [valid × d] GradBlocks
    // through the same policy code.
    let Some(rt) = runtime() else { return };
    for epochs in 1..=3 {
        let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
        cfg.epochs = epochs;
        cfg.n_examples = 192;
        let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
        let sr = sync.run().unwrap();
        let mut pipe = PipelineTrainer::new(cfg, &rt).unwrap();
        let pr = pipe.run().unwrap();
        assert_eq!(
            sr.final_order, pr.final_order,
            "order diverged at epoch boundary {epochs}"
        );
    }
}

#[test]
fn sharded_pair_balance_trains_and_matches_w1() {
    // CD-GraB end-to-end: the sharded policy trains, and W=1 sharding
    // is byte-identical to unsharded PairBalance through the full
    // trainer data path.
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
    cfg.num_shards = 1;
    let mut sharded = Trainer::new(cfg, &rt, None).unwrap();
    let shr = sharded.run().unwrap();

    let cfg = tiny_cfg(Task::Mnist, OrderingKind::PairBalance);
    let mut plain = Trainer::new(cfg, &rt, None).unwrap();
    let plr = plain.run().unwrap();
    assert_eq!(shr.final_order, plr.final_order);
    for (a, b) in shr.epochs.iter().zip(&plr.epochs) {
        assert!((a.train_loss - b.train_loss).abs() < 1e-9);
    }

    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
    cfg.num_shards = 4;
    let mut wide = Trainer::new(cfg, &rt, None).unwrap();
    let wr = wide.run().unwrap();
    let mut order = wr.final_order;
    order.sort_unstable();
    assert_eq!(order, (0..128).collect::<Vec<_>>());
}

#[test]
fn async_sharded_trainer_matches_sync_sharded() {
    // The async coordinator through the full trainer data path: worker
    // threads + bounded queues must reproduce the synchronous sharded
    // run bit for bit (same losses, same final order).
    let Some(rt) = runtime() else { return };
    for shards in [1usize, 4] {
        let mut cfg =
            tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
        cfg.num_shards = shards;
        let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
        let sr = sync.run().unwrap();

        cfg.async_shards = true;
        cfg.shard_queue_depth = 2;
        let mut asynch = Trainer::new(cfg, &rt, None).unwrap();
        let ar = asynch.run().unwrap();
        assert_eq!(sr.final_order, ar.final_order, "shards={shards}");
        for (a, b) in sr.epochs.iter().zip(&ar.epochs) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 1e-9,
                "shards={shards} epoch {}: {} vs {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
    }
}

#[test]
fn tcp_sharded_trainer_matches_sync_sharded() {
    // The socket transport through the full trainer data path: shard
    // balancers behind loopback-TCP framing must reproduce the
    // synchronous sharded run bit for bit (same losses, same final
    // order) — determinism contract 5 at trainer level.
    let Some(rt) = runtime() else { return };
    for shards in [1usize, 4] {
        let mut cfg =
            tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
        cfg.num_shards = shards;
        let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
        let sr = sync.run().unwrap();

        cfg.shard_transport = grab::config::TransportKind::Tcp;
        let mut tcp = Trainer::new(cfg, &rt, None).unwrap();
        let tr = tcp.run().unwrap();
        assert_eq!(sr.final_order, tr.final_order, "shards={shards}");
        for (a, b) in sr.epochs.iter().zip(&tr.epochs) {
            assert!(
                (a.train_loss - b.train_loss).abs() < 1e-9,
                "shards={shards} epoch {}: {} vs {}",
                a.epoch,
                a.train_loss,
                b.train_loss
            );
        }
        // The transported run must report link traffic; the sync run
        // reports comparable all-zero counters.
        let stats = tr.transport.expect("tcp run reports link stats");
        assert_eq!(stats.transport, "tcp");
        assert!(stats.total().tx_bytes > 0);
        let sync_stats = sr.transport.expect("sync run reports stats");
        assert_eq!(sync_stats.total().tx_bytes, 0);
    }
}

#[test]
fn elastic_scheduled_shard_loss_replays_with_recorded_topology() {
    // Contract 6 at trainer level: a mid-run W=4 -> 3 topology change
    // (the recorded shape of a shard loss) still yields valid
    // permutations every epoch, and re-running with the same recorded
    // schedule reproduces the run bit for bit — losses and orders, the
    // herding columns included by implication.
    let Some(rt) = runtime() else { return };
    let d = rt.manifest.model("logreg").unwrap().dim;
    let schedule = vec![
        vec![1u64, 1, 1, 1],
        vec![1u64, 1, 1, 1],
        vec![1u64, 1, 1],
    ];
    let run = |schedule: &[Vec<u64>]| {
        let mut cfg =
            tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
        cfg.epochs = 4;
        let mut t = Trainer::new(cfg, &rt, None).unwrap();
        t.policy = Box::new(grab::ordering::ShardedOrder::new_scheduled(
            128, d, schedule, 2,
        ));
        t.run().unwrap()
    };
    let a = run(&schedule);
    let b = run(&schedule);
    // Valid permutation after the shrink, and identical on replay.
    let mut sorted = a.final_order.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..128).collect::<Vec<_>>());
    assert_eq!(a.final_order, b.final_order, "replay diverged");
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert!(
            (ea.train_loss - eb.train_loss).abs() < 1e-9,
            "epoch {}: {} vs {}",
            ea.epoch,
            ea.train_loss,
            eb.train_loss
        );
    }
    // The recorded topology shows the shrink at the right boundary.
    let log = a.topology.expect("sharded run records its topology");
    assert_eq!(log[0].num_shards(), 4);
    assert_eq!(log[1].num_shards(), 4);
    assert_eq!(log[2].num_shards(), 3);
    assert_eq!(log[2].generation, 1);
}

#[test]
fn weighted_sharded_trainer_matches_across_transports() {
    // Static weighted topology (1:1:2) through the full trainer: the
    // channel-async and strided dispatch paths must agree bit for bit,
    // and the topology log must surface through TrainResult.
    let Some(rt) = runtime() else { return };
    let weights = vec![1u64, 1, 2];
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::ShardedPairBalance);
    cfg.num_shards = 3;
    cfg.shard_weights = Some(weights.clone());
    let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
    let sr = sync.run().unwrap();

    cfg.async_shards = true;
    cfg.shard_queue_depth = 2;
    let mut asynch = Trainer::new(cfg, &rt, None).unwrap();
    let ar = asynch.run().unwrap();
    assert_eq!(sr.final_order, ar.final_order);
    for (a, b) in sr.epochs.iter().zip(&ar.epochs) {
        assert!((a.train_loss - b.train_loss).abs() < 1e-9);
    }
    let log = sr.topology.expect("weighted run records its topology");
    assert_eq!(log[0].weights, weights);
    assert_eq!(log[0].sizes.iter().sum::<usize>(), 128);
}

#[test]
fn grab_observe_via_kernel_matches_native() {
    // The Pallas/HLO balance artifact and the native hot path must agree
    // sign-for-sign on a realistic gradient stream.
    let Some(rt) = runtime() else { return };
    let kernel = rt.balance_executor(1024).unwrap();
    let d = 1024;
    let mut rng = Rng::new(3);
    let m: Vec<f32> = (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();
    let mut s_native = vec![0.0f32; d];
    let mut s_kernel = vec![0.0f32; d];
    for _ in 0..64 {
        let g: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        let eps_native =
            if tensor::dot_centered(&s_native, &g, &m) < 0.0 {
                1.0
            } else {
                -1.0
            };
        tensor::axpy_centered(eps_native, &g, &m, &mut s_native);
        let eps_kernel = kernel.step(&mut s_kernel, &m, &g).unwrap();
        assert_eq!(eps_native, eps_kernel);
    }
    let dev = s_native
        .iter()
        .zip(&s_kernel)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(dev < 1e-3, "state deviation {dev}");
}

#[test]
fn walk_balancer_trains_too() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    cfg.balancer = BalancerKind::Walk;
    let mut t = Trainer::new(cfg, &rt, None).unwrap();
    let r = t.run().unwrap();
    assert!(r.epochs.iter().all(|m| m.train_loss.is_finite()));
}

#[test]
fn grab_improves_over_rr_on_longer_mnist_run() {
    // The paper's headline, at integration-test scale: same LR, same
    // seed, GraB's final training loss <= RR's after enough epochs.
    let Some(rt) = runtime() else { return };
    let run = |ordering| {
        let mut cfg = TrainConfig::for_task(Task::Mnist);
        cfg.ordering = ordering;
        cfg.epochs = 8;
        cfg.n_examples = 512;
        cfg.n_eval = 256;
        cfg.lr = 0.05;
        cfg.seed = 5;
        let mut t = Trainer::new(cfg, &rt, None).unwrap();
        t.run().unwrap().final_train_loss()
    };
    let rr = run(OrderingKind::RandomReshuffle);
    let grab = run(OrderingKind::GraB);
    // Allow a modest tolerance band: at tiny scale the gap is small but
    // GraB must at least be competitive (paper: strictly faster).
    assert!(
        grab <= rr * 1.10,
        "GraB final loss {grab} much worse than RR {rr}"
    );
}

#[test]
fn sgd_kernel_matches_rust_optimizer() {
    // The fused momentum-SGD Pallas artifact == the rust MomentumSgd,
    // step for step. Skips on manifests predating the sgd artifacts.
    let Some(rt) = runtime() else { return };
    if rt.manifest.sgd.is_empty() {
        eprintln!("skipping: no sgd artifacts (re-run make artifacts)");
        return;
    }
    let d = 1024;
    let sgd = rt.sgd_executor(d).unwrap();
    let mut rng = Rng::new(5);
    let mut p_kernel: Vec<f32> =
        (0..d).map(|_| rng.gauss() as f32).collect();
    let mut v_kernel = vec![0.0f32; d];
    let mut p_rust = p_kernel.clone();
    let mut opt = grab::optim::MomentumSgd::new(d, 0.9, 1e-4);
    for _ in 0..10 {
        let g: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        sgd.step(&mut p_kernel, &mut v_kernel, &g, 0.05, 0.9, 1e-4)
            .unwrap();
        opt.step(&mut p_rust, &g, 0.05);
        let dev = p_kernel
            .iter()
            .zip(&p_rust)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(dev < 1e-4, "params diverged: {dev}");
    }
}

#[test]
fn training_survives_label_noise() {
    // Failure injection: 20% flipped labels must not break training —
    // loss still decreases towards the noisy-label floor.
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    cfg.epochs = 4;
    cfg.n_examples = 256;
    cfg.lr = 0.05;
    let mut t = Trainer::new(cfg, &rt, None).unwrap();
    grab::data::synth::inject_label_noise(&mut t.train_ds, 0.2, 9);
    let r = t.run().unwrap();
    let first = r.epochs.first().unwrap().train_loss;
    let last = r.epochs.last().unwrap().train_loss;
    assert!(last < first, "no progress under label noise: \
             {first} -> {last}");
    assert!(last.is_finite());
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    let mut t = Trainer::new(cfg.clone(), &rt, None).unwrap();
    t.run().unwrap();
    let ckpt = t.snapshot(2);
    let dir = std::env::temp_dir().join("grab_trainer_ckpt");
    let path = dir.join("t.ckpt");
    ckpt.save(&path).unwrap();
    let loaded = grab::train::checkpoint::Checkpoint::load(&path).unwrap();
    let mut t2 = Trainer::new(cfg, &rt, None).unwrap();
    t2.restore(&loaded).unwrap();
    assert_eq!(t.params, t2.params);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn grouped_granularity_trains() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    cfg.group_size = 8;
    let mut t = Trainer::new(cfg, &rt, None).unwrap();
    let r = t.run().unwrap();
    assert!(r.epochs.iter().all(|m| m.train_loss.is_finite()));
    let mut order = r.final_order;
    order.sort_unstable();
    assert_eq!(order, (0..128).collect::<Vec<_>>());
}

#[test]
fn multiworker_pipeline_matches_sync() {
    // 3 grad workers, out-of-order reassembly, window-blocked params:
    // still bit-identical to the sync loop.
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(Task::Mnist, OrderingKind::GraB);
    cfg.epochs = 2;
    cfg.n_examples = 256;
    cfg.accum_steps = 2;
    let mut sync = Trainer::new(cfg.clone(), &rt, None).unwrap();
    let sr = sync.run().unwrap();
    cfg.workers = 3;
    let mut pipe = PipelineTrainer::new(cfg, &rt).unwrap();
    let pr = pipe.run().unwrap();
    for (a, b) in sr.epochs.iter().zip(&pr.epochs) {
        assert!((a.train_loss - b.train_loss).abs() < 1e-9,
                "epoch {}: {} vs {}", a.epoch, a.train_loss, b.train_loss);
    }
    assert_eq!(sr.final_order, pr.final_order);
}
