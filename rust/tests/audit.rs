//! Fixture matrix for the `grab audit` static pass (docs/audit.md).
//!
//! Every rule gets at least a positive fixture (a minimal bad snippet
//! producing exactly the expected `rule @ line`), a negative twin (the
//! compliant rewrite, or the same snippet at an out-of-scope path), and
//! a waiver case. The waiver-hygiene rule `A00` gets its own matrix:
//! malformed, unknown-rule, empty-reason, and stale waivers. Fixtures
//! live in string literals, which the audit lexer blanks before any
//! rule runs — so this file can quote every forbidden pattern without
//! tripping the pass it is testing.
//!
//! The closing test is the self-audit: the shipped tree must come back
//! clean, with zero `S01`/`D01` waivers (those two rules are cheap to
//! satisfy outright, so exemptions are not accepted). This suite is
//! also the semantics contract for `tools/audit_mirror.py`: any rule
//! change must land in a fixture here and in the mirror together.

use grab::audit::{audit_source, run, Finding};

/// Rule ids of the findings, in order.
fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

/// `(rule, line)` pairs of the findings, in order.
fn sites_of(findings: &[Finding]) -> Vec<(&'static str, usize)> {
    findings.iter().map(|f| (f.rule, f.line)).collect()
}

/// Audit a fixture and assert it produced no waivers.
fn check(path: &str, src: &str) -> Vec<Finding> {
    let (findings, waived) = audit_source(path, src);
    assert!(waived.is_empty(), "unexpected waivers: {waived:?}");
    findings
}

// ---------------------------------------------------------------- D01

#[test]
fn d01_flags_partial_cmp_unwrap_and_expect_chains() {
    let src = concat!(
        "fn f(a: f32, b: f32) -> std::cmp::Ordering {\n",
        "    a.partial_cmp(&b).unwrap()\n",
        "}\n",
        "fn g(a: f64, b: f64) -> std::cmp::Ordering {\n",
        "    a.partial_cmp(&b).expect(\"ordered\")\n",
        "}\n",
    );
    let findings = check("src/util/x.rs", src);
    assert_eq!(sites_of(&findings), [("D01", 2), ("D01", 5)]);
}

#[test]
fn d01_follows_the_chain_across_lines() {
    let src = concat!(
        "fn f(a: f32, b: f32) -> std::cmp::Ordering {\n",
        "    a.partial_cmp(&b)\n",
        "        .unwrap()\n",
        "}\n",
    );
    let findings = check("tests/x.rs", src);
    assert_eq!(sites_of(&findings), [("D01", 2)]);
}

#[test]
fn d01_flags_sort_and_min_max_comparators_built_on_partial_cmp() {
    let src = concat!(
        "fn f(v: &mut [f32]) {\n",
        "    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n",
        "    v.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());\n",
        "}\n",
        "fn g(v: &[f32]) -> Option<&f32> {\n",
        "    v.iter().max_by(|a, b| a.partial_cmp(b).unwrap())\n",
        "}\n",
    );
    let findings = check("benches/x.rs", src);
    // The comparator body *also* matches the unwrap-chain pattern, so
    // the sort lines each carry two findings; what matters is that
    // every offending line is reported under D01.
    assert!(findings.iter().all(|f| f.rule == "D01"));
    let mut lines: Vec<usize> = findings.iter().map(|f| f.line).collect();
    lines.dedup();
    assert_eq!(lines, [2, 3, 6]);
}

#[test]
fn d01_stays_silent_on_total_cmp_and_on_sort_by_key() {
    let src = concat!(
        "fn f(v: &mut [f32]) {\n",
        "    v.sort_by(|a, b| a.total_cmp(b));\n",
        "    v.sort_by_key(|x| x.to_bits());\n",
        "}\n",
        "fn g(a: f32, b: f32) -> bool {\n",
        "    a.partial_cmp(&b).is_some()\n",
        "}\n",
    );
    assert!(check("src/herding/x.rs", src).is_empty());
}

#[test]
fn d01_ignores_the_pattern_inside_strings_and_comments() {
    let src = concat!(
        "// a.partial_cmp(&b).unwrap() is exactly what D01 forbids\n",
        "const HINT: &str = \"use total_cmp, not \\\n",
        "    partial_cmp(&b).unwrap()\";\n",
    );
    assert!(check("src/util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- D02

#[test]
fn d02_flags_hash_containers_in_order_relevant_modules() {
    let src = concat!(
        "use std::collections::{HashMap, HashSet};\n",
        "fn f() -> HashMap<u32, u32> {\n",
        "    HashMap::new()\n",
        "}\n",
    );
    let findings = check("src/ordering/x.rs", src);
    assert_eq!(sites_of(&findings), [("D02", 1), ("D02", 1), ("D02", 2), ("D02", 3)]);
}

#[test]
fn d02_is_scoped_to_the_listed_module_trees() {
    let src = "use std::collections::HashMap;\n";
    assert!(check("src/util/x.rs", src).is_empty());
    assert!(check("src/service/x.rs", src).is_empty());
    assert_eq!(rules_of(&check("src/balance/x.rs", src)), ["D02"]);
    assert_eq!(rules_of(&check("src/train/x.rs", src)), ["D02"]);
}

#[test]
fn d02_accepts_btree_containers_everywhere() {
    let src = concat!(
        "use std::collections::{BTreeMap, BTreeSet};\n",
        "fn f() -> BTreeMap<u32, u32> {\n",
        "    BTreeMap::new()\n",
        "}\n",
    );
    assert!(check("src/ordering/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- D03

#[test]
fn d03_flags_wall_clock_reads_outside_the_allowlist() {
    let src = concat!(
        "fn f() -> std::time::Instant {\n",
        "    std::time::Instant::now()\n",
        "}\n",
        "fn g() -> std::time::SystemTime {\n",
        "    std::time::SystemTime::now()\n",
        "}\n",
    );
    let findings = check("src/train/x.rs", src);
    assert_eq!(sites_of(&findings), [("D03", 2), ("D03", 4), ("D03", 5)]);
}

#[test]
fn d03_allows_the_listed_clock_sites_and_non_src_trees() {
    let src = "fn f() { let _ = std::time::Instant::now(); }\n";
    assert!(check("src/util/timer.rs", src).is_empty());
    assert!(check("src/ordering/sharded.rs", src).is_empty());
    assert!(check("src/service/client.rs", src).is_empty());
    // Tests and benches may time things freely; D03 is a src/ rule.
    assert!(check("tests/x.rs", src).is_empty());
    assert!(check("benches/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- D04

#[test]
fn d04_flags_mul_add_and_fma_intrinsics_in_tensor() {
    let src = concat!(
        "fn f(a: f32, b: f32, c: f32) -> f32 {\n",
        "    a.mul_add(b, c)\n",
        "}\n",
        "fn g() {\n",
        "    // the intrinsic name matches by substring:\n",
        "    let _ = _mm256_fmadd_ps;\n",
        "}\n",
    );
    let findings = check("src/tensor/x.rs", src);
    assert_eq!(sites_of(&findings), [("D04", 2), ("D04", 6)]);
}

#[test]
fn d04_is_scoped_to_tensor() {
    let src = "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }\n";
    assert!(check("src/util/x.rs", src).is_empty());
    assert!(check("tests/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- S01

#[test]
fn s01_flags_unsafe_without_a_safety_comment() {
    let src = concat!(
        "fn f(p: *const u32) -> u32 {\n",
        "    unsafe { *p }\n",
        "}\n",
    );
    let findings = check("src/tensor/x.rs", src);
    assert_eq!(sites_of(&findings), [("S01", 2)]);
}

#[test]
fn s01_accepts_safety_on_the_same_line_or_within_the_lookback() {
    let src = concat!(
        "fn f(p: *const u32) -> u32 {\n",
        "    unsafe { *p } // SAFETY: caller guarantees p is valid\n",
        "}\n",
        "// SAFETY: caller guarantees p is valid and aligned; the\n",
        "// pointee outlives this call.\n",
        "#[inline]\n",
        "fn g(p: *const u32) -> u32 {\n",
        "    unsafe { *p }\n",
        "}\n",
    );
    assert!(check("src/tensor/x.rs", src).is_empty());
}

#[test]
fn s01_rejects_a_safety_comment_beyond_the_lookback() {
    let src = concat!(
        "// SAFETY: too far away to count\n",
        "//\n//\n//\n//\n//\n//\n",
        "fn f(p: *const u32) -> u32 {\n",
        "    unsafe { *p }\n",
        "}\n",
    );
    let findings = check("src/tensor/x.rs", src);
    assert_eq!(sites_of(&findings), [("S01", 9)]);
}

#[test]
fn s01_ignores_the_word_unsafe_in_comments_and_strings() {
    let src = concat!(
        "//! Discusses unsafe code without containing any.\n",
        "const W: &str = \"unsafe\";\n",
    );
    assert!(check("src/util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- W01

#[test]
fn w01_flags_bare_integer_casts_in_the_wire_layers() {
    let src = concat!(
        "fn f(v: u64, w: usize) -> (usize, u32) {\n",
        "    (v as usize, w as u32)\n",
        "}\n",
    );
    for path in ["src/util/ser.rs", "src/ordering/transport/codec.rs", "src/service/http.rs"] {
        let findings = check(path, src);
        assert_eq!(sites_of(&findings), [("W01", 2), ("W01", 2)], "{path}");
    }
}

#[test]
fn w01_is_scoped_to_the_wire_layers_and_to_integer_targets() {
    let cast = "fn f(v: u64) -> usize { v as usize }\n";
    assert!(check("src/util/rng.rs", cast).is_empty());
    assert!(check("src/tensor/x.rs", cast).is_empty());
    let float = "fn f(v: u64) -> f64 { v as f64 }\n";
    assert!(check("src/util/ser.rs", float).is_empty());
    // `as` as part of an identifier or a trait import must not match.
    let ident = "use std::io::Read as _;\nfn base(x: u32) -> u32 { x }\n";
    assert!(check("src/util/ser.rs", ident).is_empty());
}

// ------------------------------------------------------------- waivers

#[test]
fn waiver_on_the_same_line_absorbs_the_finding() {
    let src = concat!(
        "fn f(v: u64) -> usize {\n",
        "    v as usize // audit: allow(W01, reason = \"fixture\")\n",
        "}\n",
    );
    let (findings, waived) = audit_source("src/util/ser.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sites_of(&waived), [("W01", 2)]);
}

#[test]
fn waiver_on_the_previous_line_absorbs_the_finding() {
    let src = concat!(
        "fn f(v: u64) -> usize {\n",
        "    // audit: allow(W01, reason = \"fixture: exercised range\")\n",
        "    v as usize\n",
        "}\n",
    );
    let (findings, waived) = audit_source("src/util/ser.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sites_of(&waived), [("W01", 3)]);
}

#[test]
fn waiver_covers_only_its_own_rule() {
    let src = concat!(
        "fn f(v: u64) -> usize {\n",
        "    // audit: allow(D01, reason = \"wrong rule for this site\")\n",
        "    v as usize\n",
        "}\n",
    );
    let (findings, waived) = audit_source("src/util/ser.rs", src);
    assert!(waived.is_empty());
    // The cast survives as W01 and the unused D01 waiver goes stale.
    assert_eq!(rules_of(&findings), ["A00", "W01"]);
}

#[test]
fn malformed_unknown_and_empty_reason_waivers_are_a00() {
    let cases = [
        "fn a() {} // audit: allow(W01)\n",
        "fn b() {} // audit: allow(W01, reason = )\n",
        "fn c() {} // audit: allow(W01, reason = \"\")\n",
        "fn d() {} // audit: allow(Z99, reason = \"unknown rule\")\n",
        "fn e() {} // audit: allow(A00, reason = \"A00 is not waivable\")\n",
    ];
    for src in cases {
        let (findings, waived) = audit_source("src/util/x.rs", src);
        assert!(waived.is_empty());
        assert_eq!(sites_of(&findings), [("A00", 1)], "{src}");
    }
}

#[test]
fn stale_waiver_with_no_matching_finding_is_a00() {
    let src = concat!(
        "// audit: allow(W01, reason = \"the cast below was removed\")\n",
        "fn f(v: u64) -> u64 {\n",
        "    v\n",
        "}\n",
    );
    let (findings, waived) = audit_source("src/util/ser.rs", src);
    assert!(waived.is_empty());
    assert_eq!(sites_of(&findings), [("A00", 1)]);
}

#[test]
fn one_waiver_covers_multiple_findings_on_its_lines_only() {
    let src = concat!(
        "fn f(v: u64, w: u64) -> (usize, usize) {\n",
        "    // audit: allow(W01, reason = \"fixture: both casts\")\n",
        "    (v as usize, w as usize)\n",
        "}\n",
        "fn g(v: u64) -> usize {\n",
        "    v as usize\n",
        "}\n",
    );
    let (findings, waived) = audit_source("src/util/ser.rs", src);
    // Line 3's two casts are both covered; line 6's is out of range.
    assert_eq!(sites_of(&waived), [("W01", 3), ("W01", 3)]);
    assert_eq!(sites_of(&findings), [("W01", 6)]);
}

// ---------------------------------------------------------- self-audit

#[test]
fn shipped_tree_is_clean_with_no_s01_or_d01_waivers() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run(root).expect("audit walks src/, tests/, benches/");
    assert!(
        report.findings.is_empty(),
        "shipped tree has audit violations:\n{:#?}",
        report.findings
    );
    for f in &report.waived {
        assert!(
            f.rule != "S01" && f.rule != "D01",
            "{} waivers are not accepted (docs/audit.md): {f:?}",
            f.rule
        );
    }
    // The walker saw the real tree, not an empty directory.
    assert!(report.files_scanned >= 70, "{}", report.files_scanned);
}
