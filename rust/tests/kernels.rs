//! Kernel-dispatch equivalence test layer (determinism contract 7,
//! docs/determinism.md): every balance-kernel tier — portable scalar,
//! AVX2 SIMD, and SIMD plus the row-parallel worker pool — must produce
//! **bit-identical** results for the same inputs, from the raw tensor
//! ops (compared as IEEE-754 bit patterns, so NaN payloads count) all
//! the way up to multi-epoch GraB / PairBalance / sharded CD-GraB
//! epoch orders. Inputs are deliberately hostile (NaN, ±inf,
//! subnormals) and sweep every tail length `d % 8`, because "almost
//! equal" reductions diverge exactly there.
//!
//! On hosts without AVX2 the fast tiers dispatch to the scalar
//! reference, so every assertion still runs (trivially) everywhere;
//! policies pin their tier at construction via the `with_kernel`
//! constructors, so no test mutates the process-wide default.

use grab::balance::DeterministicBalancer;
use grab::ordering::{
    stream_static_epoch, GraBOrder, OrderPolicy, PairBalance,
    ShardedOrder,
};
use grab::tensor::Kernel;
use grab::util::prop::{self, assert_permutation, gen};
use grab::util::rng::Rng;

const TIERS: [Kernel; 3] =
    [Kernel::Scalar, Kernel::Simd, Kernel::SimdPar];

/// Every tail residue mod 8, plus block-and-a-bit lengths.
const DIMS: [usize; 14] =
    [1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 65, 250];

/// A vector salted with the IEEE-754 specials that break "almost
/// equal" reductions: NaN, both infinities, and a subnormal.
fn hostile(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| match i % 7 {
            0 => f32::NAN,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => 1.0e-40, // subnormal
            _ => rng.gauss() as f32,
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn reduction_kernels_match_scalar_bits_on_hostile_floats() {
    prop::forall("tier reductions bit-equal", 16, |rng| {
        for &d in &DIMS {
            let s = hostile(rng, d);
            let g = hostile(rng, d);
            let m = hostile(rng, d);
            let want_dot = Kernel::Scalar.dot(&s, &g).to_bits();
            let want_cent =
                Kernel::Scalar.dot_centered(&s, &g, &m).to_bits();
            let want_diff =
                Kernel::Scalar.dot_diff(&s, &g, &m).to_bits();
            for k in [Kernel::Simd, Kernel::SimdPar] {
                for (op, got, want) in [
                    ("dot", k.dot(&s, &g).to_bits(), want_dot),
                    (
                        "dot_centered",
                        k.dot_centered(&s, &g, &m).to_bits(),
                        want_cent,
                    ),
                    (
                        "dot_diff",
                        k.dot_diff(&s, &g, &m).to_bits(),
                        want_diff,
                    ),
                ] {
                    if got != want {
                        return Err(format!(
                            "{op} bits diverge at d={d} under {}: \
                             {got:#010x} != {want:#010x}",
                            k.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn update_kernels_match_scalar_bits_on_hostile_floats() {
    prop::forall("tier updates bit-equal", 16, |rng| {
        for &d in &DIMS {
            let a = hostile(rng, d);
            let b = hostile(rng, d);
            let m = hostile(rng, d);
            let base = hostile(rng, d);

            let mut want_axpy = base.clone();
            Kernel::Scalar.axpy(0.5, &a, &mut want_axpy);
            let mut want_diff = base.clone();
            Kernel::Scalar.axpy_diff(-1.0, &a, &b, &mut want_diff);
            let mut want_fold = base.clone();
            Kernel::Scalar
                .fold_signed_block(&a, -3.0, &m, &mut want_fold);

            for k in [Kernel::Simd, Kernel::SimdPar] {
                let mut got = base.clone();
                k.axpy(0.5, &a, &mut got);
                if bits(&got) != bits(&want_axpy) {
                    return Err(format!(
                        "axpy bits diverge at d={d} under {}",
                        k.name()
                    ));
                }
                let mut got = base.clone();
                k.axpy_diff(-1.0, &a, &b, &mut got);
                if bits(&got) != bits(&want_diff) {
                    return Err(format!(
                        "axpy_diff bits diverge at d={d} under {}",
                        k.name()
                    ));
                }
                let mut got = base.clone();
                k.fold_signed_block(&a, -3.0, &m, &mut got);
                if bits(&got) != bits(&want_fold) {
                    return Err(format!(
                        "fold_signed_block bits diverge at d={d} \
                         under {}",
                        k.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn block_kernels_match_scalar_bits_across_the_parallel_threshold() {
    // Shapes straddle PAR_MIN_ELEMS (32 Ki elements), so Kernel::SimdPar
    // exercises both its serial fallback and the worker pool.
    let mut rng = Rng::new(0x7707);
    for (rows, d) in
        [(1usize, 1usize), (3, 7), (17, 33), (40, 1027), (300, 129)]
    {
        let s = hostile(&mut rng, d);
        let m = hostile(&mut rng, d);
        let block = hostile(&mut rng, rows * d);
        let eps: Vec<f32> = (0..rows)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();

        let mut want_dots = Vec::new();
        Kernel::Scalar
            .dot_centered_block(&s, &m, &block, d, &mut want_dots);
        let mut want_signed = vec![0.1f32; d];
        let mut want_sum = vec![-0.2f32; d];
        Kernel::Scalar.accum_signed_sum(
            &eps,
            &block,
            d,
            &mut want_signed,
            &mut want_sum,
        );

        for k in [Kernel::Simd, Kernel::SimdPar] {
            let mut dots = Vec::new();
            k.dot_centered_block(&s, &m, &block, d, &mut dots);
            assert_eq!(
                bits(&dots),
                bits(&want_dots),
                "dot_centered_block rows={rows} d={d} tier={}",
                k.name()
            );
            let mut signed = vec![0.1f32; d];
            let mut sum = vec![-0.2f32; d];
            k.accum_signed_sum(&eps, &block, d, &mut signed, &mut sum);
            assert_eq!(
                bits(&signed),
                bits(&want_signed),
                "signed accum rows={rows} d={d} tier={}",
                k.name()
            );
            assert_eq!(
                bits(&sum),
                bits(&want_sum),
                "sum accum rows={rows} d={d} tier={}",
                k.name()
            );
        }
    }
}

fn feed_epoch(p: &mut dyn OrderPolicy, vs: &[Vec<f32>], block: usize) {
    let mut flat = Vec::new();
    // Epoch-agnostic policies only in this suite, so index 0 is exact.
    stream_static_epoch(p, 0, vs, &mut flat, block);
}

#[test]
fn grab_and_pair_orders_are_tier_invariant() {
    // The policy-level contract: pinning any kernel tier into GraB or
    // PairBalance changes nothing about the epoch orders, across
    // multiple epochs (so the balanced state feeding epoch e+1 is also
    // bit-equal), with hostile rows salted into the gradient stream.
    prop::forall("scalar == simd == simd+par orders", 8, |rng| {
        let n = 1 + rng.gen_range(60) as usize;
        let d = 1 + rng.gen_range(40) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let mut vs = gen::vec_set(rng, n, d);
        for (i, v) in vs.iter_mut().enumerate() {
            if i % 5 == 0 {
                *v = hostile(rng, d);
            }
        }
        let mut grabs: Vec<GraBOrder> = TIERS
            .iter()
            .map(|&k| {
                GraBOrder::with_kernel(
                    n,
                    d,
                    Box::new(DeterministicBalancer),
                    k,
                )
            })
            .collect();
        let mut pairs: Vec<PairBalance> = TIERS
            .iter()
            .map(|&k| PairBalance::with_kernel(n, d, k))
            .collect();
        for epoch in 0..3 {
            for p in grabs.iter_mut() {
                feed_epoch(p, &vs, b);
            }
            for p in pairs.iter_mut() {
                feed_epoch(p, &vs, b);
            }
            let want_grab = grabs[0].epoch_order(0).to_vec();
            assert_permutation(&want_grab)?;
            let want_pair = pairs[0].epoch_order(0).to_vec();
            assert_permutation(&want_pair)?;
            for (i, k) in TIERS.iter().enumerate().skip(1) {
                if grabs[i].epoch_order(0) != want_grab.as_slice() {
                    return Err(format!(
                        "GraB {} != scalar at epoch={epoch} n={n} \
                         d={d} b={b}",
                        k.name()
                    ));
                }
                if pairs[i].epoch_order(0) != want_pair.as_slice() {
                    return Err(format!(
                        "PairBalance {} != scalar at epoch={epoch} \
                         n={n} d={d} b={b}",
                        k.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_orders_are_tier_invariant_for_w_1_2_4() {
    // Contract 7 through the CD-GraB coordinator: every dispatch
    // backend (strided, gathered, async channel workers) under every
    // kernel tier produces the scalar-strided epoch orders, for
    // W in {1, 2, 4}, chained down to unsharded PairBalance at W = 1.
    prop::forall("sharded orders tier-invariant", 6, |rng| {
        let n = 1 + rng.gen_range(48) as usize;
        let d = 1 + rng.gen_range(6) as usize;
        let b = 1 + rng.gen_range(8) as usize;
        let depth = 1 + rng.gen_range(3) as usize;
        let vs = gen::vec_set(rng, n, d);
        for w in [1usize, 2, 4] {
            let mut reference =
                ShardedOrder::new_with_kernel(n, d, w, Kernel::Scalar);
            let mut pair =
                PairBalance::with_kernel(n, d, Kernel::Scalar);
            let mut lineup: Vec<(String, ShardedOrder)> = Vec::new();
            for &k in &TIERS {
                lineup.push((
                    format!("strided/{}", k.name()),
                    ShardedOrder::new_with_kernel(n, d, w, k),
                ));
                lineup.push((
                    format!("gathered/{}", k.name()),
                    ShardedOrder::new_gathered_with_kernel(n, d, w, k),
                ));
                lineup.push((
                    format!("async/{}", k.name()),
                    ShardedOrder::new_async_with_kernel(
                        n, d, w, depth, k,
                    ),
                ));
            }
            for epoch in 0..3 {
                feed_epoch(&mut reference, &vs, b);
                feed_epoch(&mut pair, &vs, b);
                let want = reference.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                for (label, policy) in lineup.iter_mut() {
                    feed_epoch(policy, &vs, b);
                    if policy.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "{label} != scalar strided at w={w} \
                             epoch={epoch} n={n} d={d} b={b} \
                             depth={depth}"
                        ));
                    }
                }
                if w == 1 && pair.epoch_order(0) != want.as_slice() {
                    return Err(format!(
                        "w=1 sharded != PairBalance at epoch={epoch} \
                         n={n} d={d} b={b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn per_example_shim_chains_to_blocks_under_every_tier() {
    // B = 1: the `observe` shim must stay bit-equal to arbitrary block
    // sizes under every tier, so the contract-1 chain (per-example ≡
    // block) composes with contract 7 instead of forking per tier.
    prop::forall("B=1 chains per tier", 8, |rng| {
        let n = 1 + rng.gen_range(40) as usize;
        let d = 1 + rng.gen_range(20) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let vs = gen::vec_set(rng, n, d);
        for &k in &TIERS {
            let mut shim = GraBOrder::with_kernel(
                n,
                d,
                Box::new(DeterministicBalancer),
                k,
            );
            let mut blocks = GraBOrder::with_kernel(
                n,
                d,
                Box::new(DeterministicBalancer),
                k,
            );
            let mut pair_shim = PairBalance::with_kernel(n, d, k);
            let mut pair_blocks = PairBalance::with_kernel(n, d, k);
            for epoch in 0..3 {
                // Drive the shim policies through the per-example
                // entry point, one row at a time.
                for (p, q) in [
                    (
                        &mut shim as &mut dyn OrderPolicy,
                        &mut blocks as &mut dyn OrderPolicy,
                    ),
                    (
                        &mut pair_shim as &mut dyn OrderPolicy,
                        &mut pair_blocks as &mut dyn OrderPolicy,
                    ),
                ] {
                    let order = p.epoch_order(0).to_vec();
                    for (pos, &unit) in order.iter().enumerate() {
                        p.observe(pos, &vs[unit]);
                    }
                    p.epoch_end();
                    feed_epoch(q, &vs, b);
                }
                if shim.epoch_order(0) != blocks.epoch_order(0) {
                    return Err(format!(
                        "GraB shim != block under {} at \
                         epoch={epoch} n={n} d={d} b={b}",
                        k.name()
                    ));
                }
                if pair_shim.epoch_order(0)
                    != pair_blocks.epoch_order(0)
                {
                    return Err(format!(
                        "PairBalance shim != block under {} at \
                         epoch={epoch} n={n} d={d} b={b}",
                        k.name()
                    ));
                }
            }
        }
        Ok(())
    });
}
