//! Durable-run test layer (determinism contract 8,
//! docs/determinism.md): kill a run after any epoch boundary, drop
//! every in-memory object, resume from the on-disk snapshot, and the
//! remaining epoch orders — and, with artifacts, the final model
//! parameters — are bit-equal to an uninterrupted run. Covered for
//! GraB, PairBalance, and the sharded CD-GraB coordinator over the
//! synchronous, channel, and loopback-TCP transports at W ∈ {1, 2, 4},
//! chained down to unsharded PairBalance at W = 1 (mirroring
//! tests/transport.rs). The negative-path matrix drives every
//! corruption mode through the public API: each must surface as a
//! typed [`CheckpointError`], never a panic or a silently-wrong
//! resume.
//!
//! Contract 8 covers **both** trainers: the synchronous loop and the
//! threaded pipeline snapshot through the same `RunDir` gate (the
//! pipeline at its epoch barrier, where the stage threads are joined),
//! and their snapshots are interchangeable.
//!
//! The policy-level suite needs no artifacts; the trainer-level tests
//! skip (like tests/integration.rs) when `artifacts/` is absent.

use grab::balance::DeterministicBalancer;
use grab::config::{OrderingKind, Task, TrainConfig};
use grab::ordering::{
    stream_static_epoch, GraBOrder, OrderPolicy, PairBalance,
    RandomReshuffle, ShardedOrder,
};
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::train::checkpoint::{
    self, Checkpoint, CheckpointError, RunDir,
};
use grab::train::Trainer;
use grab::util::prop::{self, assert_permutation, gen};
use grab::util::testdir::TestDir;

fn feed_epoch(p: &mut dyn OrderPolicy, vs: &[Vec<f32>], block: usize) {
    let mut flat = Vec::new();
    // Epoch-agnostic policies only in this suite, so index 0 is exact.
    stream_static_epoch(p, 0, vs, &mut flat, block);
}

/// The contract-8 core: run `epochs` uninterrupted epochs through one
/// policy instance; separately run a twin up to (and including) epoch
/// `kill`, `save_state`, drop it, rebuild a fresh instance from config
/// alone, `restore_state`, and finish. Every post-kill epoch order must
/// be bit-equal. Returns the uninterrupted order sequence so callers
/// can chain policies against each other (the W = 1 gate).
fn crash_replay(
    make: &dyn Fn() -> Result<Box<dyn OrderPolicy>, String>,
    vs: &[Vec<f32>],
    block: usize,
    epochs: usize,
    kill: usize,
) -> Result<Vec<Vec<usize>>, String> {
    let mut a = make()?;
    let mut orders = Vec::with_capacity(epochs);
    for _ in 0..epochs {
        feed_epoch(a.as_mut(), vs, block);
        let order = a.epoch_order(0).to_vec();
        assert_permutation(&order)?;
        orders.push(order);
    }

    let mut b = make()?;
    for _ in 0..=kill {
        feed_epoch(b.as_mut(), vs, block);
    }
    let state = b
        .save_state()
        .ok_or_else(|| format!("{}: no save_state", b.name()))?;
    let next = b.epoch_order(0).to_vec();
    drop(b); // the crash: every in-memory object is gone

    let mut c = make()?;
    c.restore_state(&state)
        .map_err(|e| format!("{}: restore_state: {e}", c.name()))?;
    if c.epoch_order(0) != next.as_slice() {
        return Err(format!(
            "{}: restored next-epoch order differs from the one \
             snapshotted at kill={kill}",
            c.name()
        ));
    }
    for (e, want) in orders.iter().enumerate().skip(kill + 1) {
        feed_epoch(c.as_mut(), vs, block);
        if c.epoch_order(0) != want.as_slice() {
            return Err(format!(
                "{}: epoch {e} order diverged after resuming from \
                 kill={kill}",
                c.name()
            ));
        }
    }
    Ok(orders)
}

#[test]
fn crash_replay_matches_uninterrupted_for_core_policies() {
    // Random n/d/block and a random kill epoch: snapshot → drop
    // everything → resume ≡ uninterrupted, for the unsharded balancing
    // policies.
    prop::forall("grab/pair crash-replay equivalence", 8, |rng| {
        let n = 1 + rng.gen_range(60) as usize;
        let d = 1 + rng.gen_range(6) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let epochs = 4usize;
        let kill = rng.gen_range(epochs as u64 - 1) as usize;
        let vs = gen::vec_set(rng, n, d);
        crash_replay(
            &|| Ok(Box::new(PairBalance::new(n, d))),
            &vs,
            b,
            epochs,
            kill,
        )?;
        crash_replay(
            &|| {
                Ok(Box::new(GraBOrder::new(
                    n,
                    d,
                    Box::new(DeterministicBalancer),
                )))
            },
            &vs,
            b,
            epochs,
            kill,
        )?;
        Ok(())
    });
}

#[test]
fn crash_replay_matches_over_channel_and_tcp_sharded_orders() {
    // The sharded coordinator across its three dispatch paths, W in
    // {1, 2, 4}: resume must reproduce the uninterrupted orders on
    // each transport, the transports must agree with each other, and
    // at W = 1 the chain extends to unsharded PairBalance — so a
    // resumed socket CD-GraB run is pinned all the way down to the
    // single-threaded reference.
    prop::forall("sharded crash-replay equivalence", 6, |rng| {
        let n = 1 + rng.gen_range(48) as usize;
        let d = 1 + rng.gen_range(5) as usize;
        let b = 1 + rng.gen_range(8) as usize;
        let depth = 1 + rng.gen_range(3) as usize;
        let epochs = 3usize;
        let kill = rng.gen_range(epochs as u64 - 1) as usize;
        let vs = gen::vec_set(rng, n, d);
        let pair = crash_replay(
            &|| Ok(Box::new(PairBalance::new(n, d))),
            &vs,
            b,
            epochs,
            kill,
        )?;
        for w in [1usize, 2, 4] {
            let sync = crash_replay(
                &|| Ok(Box::new(ShardedOrder::new(n, d, w))),
                &vs,
                b,
                epochs,
                kill,
            )?;
            let channel = crash_replay(
                &|| {
                    Ok(Box::new(ShardedOrder::new_async(n, d, w, depth)))
                },
                &vs,
                b,
                epochs,
                kill,
            )?;
            let tcp = crash_replay(
                &|| {
                    ShardedOrder::new_tcp_loopback(n, d, w)
                        .map(|p| Box::new(p) as Box<dyn OrderPolicy>)
                        .map_err(|e| format!("loopback spawn: {e}"))
                },
                &vs,
                b,
                epochs,
                kill,
            )?;
            if channel != sync || tcp != sync {
                return Err(format!(
                    "transports disagree at w={w} n={n} d={d} b={b} \
                     kill={kill}"
                ));
            }
            if w == 1 && sync != pair {
                return Err(format!(
                    "w=1 sharded != PairBalance at n={n} d={d} b={b} \
                     kill={kill}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn snapshotting_is_a_pure_observer() {
    // The run-with-checkpointing == run-without gate at the policy
    // layer: snapshot-time re-borrows (`epoch_order`) and `save_state`
    // must be cache hits, even for policies that mutate on an
    // epoch-order miss (RandomReshuffle's in-place shuffle).
    let mut a = RandomReshuffle::new(17, 5);
    let mut b = RandomReshuffle::new(17, 5);
    for epoch in 0..4 {
        let wa = a.epoch_order(epoch).to_vec();
        let wb = b.epoch_order(epoch).to_vec();
        let _ = b.save_state();
        let wb2 = b.epoch_order(epoch).to_vec(); // snapshot re-borrow
        assert_eq!(wa, wb, "twin diverged before snapshotting");
        assert_eq!(wb, wb2, "snapshot perturbed the epoch order");
        a.epoch_end();
        b.epoch_end();
    }

    // Same for the balancing policies on a gradient stream.
    let vs = gen::vec_set(&mut grab::util::rng::Rng::new(11), 24, 3);
    let mut plain = ShardedOrder::new_async(24, 3, 2, 2);
    let mut observed = ShardedOrder::new_async(24, 3, 2, 2);
    for _ in 0..3 {
        feed_epoch(&mut plain, &vs, 4);
        feed_epoch(&mut observed, &vs, 4);
        let _ = observed.save_state();
        assert_eq!(
            plain.epoch_order(0),
            observed.epoch_order(0),
            "save_state perturbed the sharded coordinator"
        );
    }
}

// ---------------------------------------------------------------------
// Negative-path matrix: every way a run directory can be damaged must
// surface as a typed CheckpointError through the public API.
// ---------------------------------------------------------------------

fn sample_checkpoint() -> Checkpoint {
    Checkpoint {
        epoch: 3,
        params: vec![1.0, -2.5, 3.25],
        velocity: vec![0.5, 0.0, -0.125],
        order: vec![2, 0, 1],
        sched: Some((0.1, 0.875, 2)),
        policy_state: Some(vec![9, 8, 7, 6]),
    }
}

#[test]
fn random_byte_flips_are_always_typed_errors() {
    let tmp = TestDir::new("ckpt-flips");
    let path = tmp.path().join("snap.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let bad_path = tmp.path().join("bad.ckpt");
    prop::forall("byte flips at random offsets", 48, |rng| {
        let off = rng.gen_range(bytes.len() as u64) as usize;
        let mut bad = bytes.clone();
        bad[off] ^= 0x20;
        std::fs::write(&bad_path, &bad)
            .map_err(|e| format!("write: {e}"))?;
        match Checkpoint::load(&bad_path) {
            Err(_) => Ok(()), // typed; never a panic
            Ok(_) => Err(format!(
                "flip at offset {off} loaded as a valid checkpoint"
            )),
        }
    });
    // A payload flip specifically is a CRC rejection whose message
    // says so.
    let mut bad = bytes.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x20;
    std::fs::write(&bad_path, &bad).unwrap();
    let err = Checkpoint::load(&bad_path).unwrap_err();
    assert!(matches!(err, CheckpointError::BadChecksum(_)));
    assert!(err.to_string().contains("CRC"), "got: {err}");
}

#[test]
fn snapshot_version_from_the_future_is_refused() {
    let tmp = TestDir::new("ckpt-future");
    let path = tmp.path().join("snap.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[8..12].copy_from_slice(&9u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let err = Checkpoint::load(&path).unwrap_err();
    assert!(
        matches!(
            err,
            CheckpointError::VersionFromTheFuture { found: 9, .. }
        ),
        "got: {err}"
    );
}

#[test]
fn truncated_files_and_manifests_are_typed_errors() {
    let tmp = TestDir::new("ckpt-trunc");
    let path = tmp.path().join("snap.ckpt");
    sample_checkpoint().save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Cut anywhere in the file: header cuts are Truncated, payload
    // cuts fail the CRC — always typed, never a panic.
    for cut in [1, 8, 15, 16, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(
            matches!(
                err,
                CheckpointError::Truncated(_)
                    | CheckpointError::BadChecksum(_)
            ),
            "cut at {cut}: got {err}"
        );
    }

    // A truncated manifest is refused as malformed, with the parse
    // diagnosis attached.
    let rd_dir = tmp.path().join("run");
    RunDir::create(
        &rd_dir,
        checkpoint::manifest_for(0xABCD, "run", "pair", "scalar", 1),
    )
    .unwrap();
    let mpath = rd_dir.join(checkpoint::MANIFEST_FILE);
    let text = std::fs::read_to_string(&mpath).unwrap();
    std::fs::write(&mpath, &text[..text.len() / 2]).unwrap();
    let err = RunDir::open(&rd_dir).unwrap_err();
    assert!(
        matches!(err, CheckpointError::Malformed(_)),
        "got: {err}"
    );
}

#[test]
fn fingerprint_mismatch_and_missing_epoch_are_typed_errors() {
    let tmp = TestDir::new("ckpt-gates");
    let rd = RunDir::create(
        tmp.path(),
        checkpoint::manifest_for(0x1111, "run", "pair", "scalar", 1),
    )
    .unwrap();
    let err = rd.check_fingerprint(0x2222).unwrap_err();
    assert!(
        matches!(
            err,
            CheckpointError::FingerprintMismatch {
                manifest: 0x1111,
                config: 0x2222,
            }
        ),
        "got: {err}"
    );

    // Retention keeps the last K snapshots; asking for a pruned epoch
    // is a typed miss, not a bogus read.
    let mut ckpt = sample_checkpoint();
    for epoch in 0..6 {
        ckpt.epoch = epoch;
        rd.save_epoch(&ckpt, 3).unwrap();
    }
    assert_eq!(rd.epochs().unwrap(), vec![3, 4, 5]);
    let err = rd.load_epoch(0).unwrap_err();
    assert!(
        matches!(err, CheckpointError::MissingEpoch { epoch: 0, .. }),
        "got: {err}"
    );
    assert_eq!(rd.load_epoch(5).unwrap().epoch, 5);
}

// ---------------------------------------------------------------------
// Trainer-level contract 8: full run state (params + momentum +
// scheduler + policy), through the CLI-visible --checkpoint-dir /
// --resume path. Skips without artifacts, like tests/integration.rs.
// ---------------------------------------------------------------------

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::open("artifacts").expect("runtime"))
}

fn tiny_cfg(ordering: OrderingKind) -> TrainConfig {
    let mut cfg = TrainConfig::for_task(Task::Mnist);
    cfg.ordering = ordering;
    cfg.epochs = 4;
    cfg.n_examples = 128;
    cfg.n_eval = 256;
    cfg.seed = 1;
    cfg
}

#[test]
fn trainer_crash_replay_matches_uninterrupted_run() {
    let Some(rt) = runtime() else { return };
    for ordering in [
        OrderingKind::RandomReshuffle,
        OrderingKind::GraB,
        OrderingKind::PairBalance,
        OrderingKind::ShardedPairBalance,
    ] {
        let cfg = tiny_cfg(ordering);

        // A: the uninterrupted reference run.
        let mut a = Trainer::new(cfg.clone(), &rt, None).unwrap();
        let ra = a.run().unwrap();

        // B: killed after epoch 1; only the run directory survives.
        let tmp = TestDir::new("trainer-crash");
        let mut b = Trainer::new(cfg.clone(), &rt, None).unwrap();
        b.run_epoch(0).unwrap();
        b.run_epoch(1).unwrap();
        let snap = b.snapshot(1);
        let rd = RunDir::create(
            tmp.path(),
            checkpoint::manifest_for(
                cfg.fingerprint(),
                &cfg.run_id(),
                cfg.ordering.name(),
                cfg.kernels.name(),
                1,
            ),
        )
        .unwrap();
        rd.save_epoch(&snap, 3).unwrap();
        drop(b);
        drop(rd);

        // C: a fresh process image — new trainer, state seeded purely
        // from the on-disk run directory via the --resume path.
        let mut c_cfg = cfg.clone();
        c_cfg.checkpoint_dir =
            Some(tmp.path().to_string_lossy().into_owned());
        c_cfg.resume = true;
        let mut c = Trainer::new(c_cfg, &rt, None).unwrap();
        let rc = c.run().unwrap();

        assert_eq!(
            rc.epochs.first().map(|m| m.epoch),
            Some(2),
            "{ordering:?}: resume must continue at kill + 1"
        );
        assert_eq!(rc.epochs.len(), 2, "{ordering:?}");
        assert_eq!(
            rc.final_order, ra.final_order,
            "{ordering:?}: final orders must be bit-equal"
        );
        assert_eq!(
            c.params, a.params,
            "{ordering:?}: final params must be bit-equal"
        );
    }
}

#[test]
fn pipeline_crash_replay_matches_uninterrupted_run() {
    // Contract 8's pipeline half: the threaded trainer snapshots at
    // its epoch barrier (stage threads joined, coordinator owns all
    // state), so kill-and-resume is bit-equal there too — including
    // against a *sync* reference, since both loops are bit-identical.
    let Some(rt) = runtime() else { return };
    for ordering in
        [OrderingKind::RandomReshuffle, OrderingKind::PairBalance]
    {
        let mut cfg = tiny_cfg(ordering);
        cfg.use_pipeline = true;

        // A: the uninterrupted pipelined reference run.
        let mut a = PipelineTrainer::new(cfg.clone(), &rt).unwrap();
        let ra = a.run().unwrap();

        // B: killed after epoch 1; only the run directory survives.
        let tmp = TestDir::new("pipeline-crash");
        let mut b = PipelineTrainer::new(cfg.clone(), &rt).unwrap();
        b.run_epoch(0).unwrap();
        b.run_epoch(1).unwrap();
        let snap = b.snapshot(1);
        let rd = RunDir::create(
            tmp.path(),
            checkpoint::manifest_for(
                cfg.fingerprint(),
                &cfg.run_id(),
                cfg.ordering.name(),
                cfg.kernels.name(),
                1,
            ),
        )
        .unwrap();
        rd.save_epoch(&snap, 3).unwrap();
        drop(b);
        drop(rd);

        // C: a fresh process image resumed via --checkpoint-dir +
        // --resume, exactly like the sync trainer's path.
        let mut c_cfg = cfg.clone();
        c_cfg.checkpoint_dir =
            Some(tmp.path().to_string_lossy().into_owned());
        c_cfg.resume = true;
        let mut c = PipelineTrainer::new(c_cfg, &rt).unwrap();
        let rc = c.run().unwrap();

        assert_eq!(
            rc.epochs.first().map(|m| m.epoch),
            Some(2),
            "{ordering:?}: pipeline resume must continue at kill + 1"
        );
        assert_eq!(rc.epochs.len(), 2, "{ordering:?}");
        assert_eq!(
            rc.final_order, ra.final_order,
            "{ordering:?}: pipeline final orders must be bit-equal"
        );
        assert_eq!(
            c.params, a.params,
            "{ordering:?}: pipeline final params must be bit-equal"
        );

        // Cross-trainer: the sync loop resumed from the *pipeline's*
        // snapshot lands on the same final params (both loops are
        // bit-identical, so their snapshots are interchangeable).
        let mut s = Trainer::new(cfg.clone(), &rt, None).unwrap();
        s.restore(&snap).unwrap();
        let rs = s.run().unwrap();
        assert_eq!(
            rs.final_order, ra.final_order,
            "{ordering:?}: sync-from-pipeline-snapshot order"
        );
        assert_eq!(
            s.params, a.params,
            "{ordering:?}: sync-from-pipeline-snapshot params"
        );
    }
}

#[test]
fn restore_resumes_at_the_snapshot_epoch_plus_one() {
    // Regression: `Trainer::restore` used to ignore `ckpt.epoch`, so a
    // resumed run silently re-executed epoch 0 onward.
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(OrderingKind::RandomReshuffle);
    let mut b = Trainer::new(cfg.clone(), &rt, None).unwrap();
    b.run_epoch(0).unwrap();
    let snap = b.snapshot(0);
    assert_eq!(snap.epoch, 0);

    let mut c = Trainer::new(cfg, &rt, None).unwrap();
    c.restore(&snap).unwrap();
    let rc = c.run().unwrap();
    assert_eq!(rc.epochs.len(), 3, "must not re-run epoch 0");
    assert_eq!(rc.epochs[0].epoch, 1);
}

#[test]
fn resume_refuses_a_mismatched_config_fingerprint() {
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(OrderingKind::PairBalance);
    let tmp = TestDir::new("trainer-fpr");
    let mut b = Trainer::new(cfg.clone(), &rt, None).unwrap();
    b.run_epoch(0).unwrap();
    let rd = RunDir::create(
        tmp.path(),
        checkpoint::manifest_for(
            cfg.fingerprint(),
            &cfg.run_id(),
            cfg.ordering.name(),
            cfg.kernels.name(),
            1,
        ),
    )
    .unwrap();
    rd.save_epoch(&b.snapshot(0), 3).unwrap();

    let mut other = cfg.clone();
    other.seed = 999; // a different run
    other.checkpoint_dir =
        Some(tmp.path().to_string_lossy().into_owned());
    other.resume = true;
    let err = Trainer::new(other, &rt, None)
        .unwrap()
        .run()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("fingerprint"),
        "wanted a fingerprint refusal, got: {err:#}"
    );
}
