//! Streaming-reservoir test layer (determinism contract 9,
//! docs/determinism.md): a [`StreamOrder`] window is one epoch, and
//! the contract has two halves — **static**: a prefilled reservoir
//! with no membership events replays a bare [`PairBalance`]
//! bit-for-bit; **transport**: on a count-neutral frozen schedule the
//! sharded reservoir's merged orders are bit-equal across channel and
//! loopback-TCP backends at every acceptance shard count W ∈ {1, 2, 4}
//! (the same schedule the daemon's `stream` jobs run over leased
//! sockets). Frozen schedules are also *replayable*: the same seed and
//! drift plan reproduce every window order, membership plan, and
//! reservoir counter exactly.
//!
//! These tests need no artifacts but do open real loopback sockets;
//! CI runs this target under a timeout guard so a hung socket fails
//! fast.

use grab::ordering::stream::{DriftPlan, StreamOrder};
use grab::ordering::{stream_static_epoch, OrderPolicy, PairBalance};
use grab::service::order_hash;
use grab::util::prop::{self, assert_permutation, gen};

/// Feed one window of slot-indexed gradients `vs` through `s`.
fn feed_window(s: &mut StreamOrder, vs: &[Vec<f32>], block: usize) {
    s.run_window(
        &mut |unit, out| out.copy_from_slice(&vs[unit as usize]),
        block,
    );
}

#[test]
fn static_reservoir_matches_pair_balance_bit_for_bit() {
    // Contract 9, static half, as a property over random shapes: with
    // units 0..n prefilled and no membership events, every window
    // order equals the bare PairBalance epoch order (slot i holds
    // unit i, so orders compare directly).
    prop::forall("static reservoir == PairBalance", 12, |rng| {
        let n = 1 + rng.gen_range(60) as usize;
        let d = 1 + rng.gen_range(6) as usize;
        let b = 1 + rng.gen_range(9) as usize;
        let vs = gen::vec_set(rng, n, d);
        let mut res = StreamOrder::prefilled(n, d);
        let mut pair = PairBalance::new(n, d);
        let mut flat = Vec::new();
        for epoch in 0..3 {
            feed_window(&mut res, &vs, b);
            stream_static_epoch(&mut pair, epoch, &vs, &mut flat, b);
            let want = pair.epoch_order(epoch + 1).to_vec();
            assert_permutation(&want)?;
            if res.epoch_order(epoch + 1) != want.as_slice() {
                return Err(format!(
                    "static reservoir != PairBalance at epoch={epoch} \
                     n={n} d={d} b={b}"
                ));
            }
        }
        if res.stats().replans != 0 {
            return Err("static reservoir re-planned".to_string());
        }
        Ok(())
    });
}

#[test]
fn frozen_count_neutral_schedule_is_bit_equal_channel_vs_tcp() {
    // Contract 9, transport half: the identical frozen steady-churn
    // schedule through channel and loopback-TCP sharded reservoirs at
    // W ∈ {1, 2, 4} merges to bit-equal window orders, and the fixed
    // count means no boundary ever re-links.
    prop::forall("stream channel == tcp at W in {1,2,4}", 4, |rng| {
        let n = 8 + rng.gen_range(40) as usize;
        let d = 1 + rng.gen_range(5) as usize;
        let b = 1 + rng.gen_range(8) as usize;
        let admit = rng.gen_range(5) as usize;
        let seed = rng.gen_range(u64::MAX);
        let units: Vec<u64> = (0..n as u64).collect();
        let drift = DriftPlan::steady(seed, admit);
        for w in [1usize, 2, 4] {
            let mut chan =
                StreamOrder::sharded_channel(n, d, &units, w, 2);
            let mut tcp =
                StreamOrder::sharded_tcp_loopback(n, d, &units, w)
                    .map_err(|e| format!("loopback spawn: {e}"))?;
            let mut next_chan = n as u64;
            let mut next_tcp = n as u64;
            for window in 0..3 {
                chan.drive_window(&drift, &mut next_chan, b);
                tcp.drive_window(&drift, &mut next_tcp, b);
                let want = chan.epoch_order(window + 1).to_vec();
                assert_permutation(&want)?;
                if tcp.epoch_order(window + 1) != want.as_slice() {
                    return Err(format!(
                        "stream tcp != channel at w={w} \
                         window={window} n={n} d={d} b={b} \
                         admit={admit} seed={seed}"
                    ));
                }
                if chan.live_units() != tcp.live_units() {
                    return Err(format!(
                        "membership diverged at w={w} window={window}"
                    ));
                }
            }
            if chan.stats().replans != 0 || tcp.stats().replans != 0 {
                return Err(format!(
                    "count-neutral schedule re-linked at w={w} \
                     (channel {} / tcp {} replans)",
                    chan.stats().replans,
                    tcp.stats().replans
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn frozen_drift_schedules_replay_bit_for_bit() {
    // Contract 9, replay: two reservoirs driven by the same seed and
    // drift plan — including resizing churn, bursts, and mass
    // retirements — agree on every window order, the live membership,
    // and every lifetime counter.
    let plans = [
        DriftPlan::steady(21, 3),
        DriftPlan::churn(22, 2, 5),
        DriftPlan::bursty(23, 1, 2, 6),
        DriftPlan {
            mass_retire_every: 3,
            shift_per_window: 0.1,
            ..DriftPlan::steady(24, 2)
        },
    ];
    let n = 48;
    let d = 4;
    for drift in &plans {
        let units: Vec<u64> = (0..n as u64).collect();
        let mut a = StreamOrder::with_units(n, d, &units);
        let mut b = StreamOrder::with_units(n, d, &units);
        let mut next_a = n as u64;
        let mut next_b = n as u64;
        for window in 0..6 {
            a.drive_window(drift, &mut next_a, 8);
            b.drive_window(drift, &mut next_b, 8);
            let order = a.epoch_order(window + 1).to_vec();
            assert_eq!(order.len(), a.len());
            assert_permutation(&order).unwrap();
            assert_eq!(
                order.as_slice(),
                b.epoch_order(window + 1),
                "replay diverged at window {window} under {drift:?}"
            );
            assert_eq!(a.live_units(), b.live_units());
        }
        assert_eq!(a.stats(), b.stats(), "counters diverged: {drift:?}");
        assert_eq!(
            a.plan_log().len(),
            7,
            "initial fill + one plan per boundary"
        );
    }
}

#[test]
fn daemon_static_stream_schedule_reduces_to_pair_balance_hashes() {
    // The degenerate daemon stream job (admit_rate = 0) is a static
    // membership: its per-window hashes over a W=1 sharded reservoir
    // must equal PairBalance's over the same drift gradients — the
    // bridge between contract 9's two halves that the service test
    // exercises end-to-end over real sockets.
    let n = 40;
    let d = 3;
    let block = 8;
    let drift = DriftPlan::steady(9, 0);
    let units: Vec<u64> = (0..n as u64).collect();
    let mut res = StreamOrder::sharded_channel(n, d, &units, 1, 2);
    let mut next_unit = n as u64;
    let vs: Vec<Vec<f32>> = units
        .iter()
        .map(|&u| {
            let mut g = vec![0.0f32; d];
            drift.grad(u, 0, &mut g);
            g
        })
        .collect();
    let mut pair = PairBalance::new(n, d);
    let mut flat = Vec::new();
    for window in 0..4 {
        res.drive_window(&drift, &mut next_unit, block);
        stream_static_epoch(&mut pair, window, &vs, &mut flat, block);
        assert_eq!(
            order_hash(res.epoch_order(window + 1)),
            order_hash(pair.epoch_order(window + 1)),
            "window {window}"
        );
    }
}
