//! Bench: the GraB per-example hot path (see docs/perf.md for the
//! kernel tiers and how to read the recorded `BENCH_*.json` runs).
//!
//! Compares, at the paper's logreg d and a larger d:
//!   * naive scalar dot vs 8-way unrolled dot
//!   * two-step (materialize c, then dot/axpy) vs fused centered ops
//!   * the full observe() step of GraBOrder
//!   * the Pallas/HLO balance artifact via PJRT (layer ablation)
//!
//! Run: `cargo bench --bench balance_hot`

use grab::balance::DeterministicBalancer;
use grab::ordering::{GraBOrder, OrderPolicy};
use grab::runtime::Runtime;
use grab::tensor;
use grab::util::rng::Rng;
use grab::util::timer::Bench;

fn main() {
    println!("== balance_hot bench (§Perf hot path) ==");
    for d in [1024usize, 7850, 65536] {
        let mut rng = Rng::new(d as u64);
        let s: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        let g: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        let m: Vec<f32> =
            (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();
        let mut c = vec![0.0f32; d];

        Bench::new(format!("dot_naive/d{d}"))
            .with_iters(100, 2000)
            .run(|| {
                std::hint::black_box(tensor::dot_naive(&s, &g));
            });
        Bench::new(format!("dot_unrolled/d{d}"))
            .with_iters(100, 2000)
            .run(|| {
                std::hint::black_box(tensor::dot(&s, &g));
            });
        Bench::new(format!("two_step_center_dot/d{d}"))
            .with_iters(100, 2000)
            .run(|| {
                tensor::sub_into(&g, &m, &mut c);
                std::hint::black_box(tensor::dot(&s, &c));
            });
        Bench::new(format!("fused_dot_centered/d{d}"))
            .with_iters(100, 2000)
            .run(|| {
                std::hint::black_box(tensor::dot_centered(&s, &g, &m));
            });

        // Full observe step (decision + signed update + mean accum +
        // placement), amortized over a synthetic epoch. The first-epoch
        // order is the identity, so a flat [n × d] buffer doubles as the
        // gathered visit-order stream.
        let n = 256usize;
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();
        let r = Bench::new(format!("grab_observe_epoch/n{n}/d{d}"))
            .with_iters(3, 50)
            .run(|| {
                let mut p = GraBOrder::new(
                    n, d, Box::new(DeterministicBalancer));
                let _ = p.epoch_order(0);
                for pos in 0..n {
                    p.observe(pos, &flat[pos * d..(pos + 1) * d]);
                }
                p.epoch_end();
            });
        println!(
            "  -> {:.1} ns per observe() at d={d}",
            r.summary.mean / n as f64 * 1e9
        );
        let b = 32usize;
        let r = Bench::new(format!("grab_observe_epoch_blk{b}/n{n}/d{d}"))
            .with_iters(3, 50)
            .run(|| {
                let mut p = GraBOrder::new(
                    n, d, Box::new(DeterministicBalancer));
                let _ = p.epoch_order(0);
                let mut pos = 0;
                while pos < n {
                    let end = (pos + b).min(n);
                    p.observe_block(
                        pos..end,
                        &tensor::GradBlock::new(
                            &flat[pos * d..end * d], d),
                    );
                    pos = end;
                }
                p.epoch_end();
            });
        println!(
            "  -> {:.1} ns per example via {b}-row blocks at d={d}",
            r.summary.mean / n as f64 * 1e9
        );
    }

    // PJRT kernel path, if artifacts are present.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::open("artifacts").expect("runtime");
        for d in [1024usize, 7850] {
            let kernel = rt.balance_executor(d).expect("balance artifact");
            let mut rng = Rng::new(9);
            let m: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();
            let g: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32).collect();
            let mut s = vec![0.0f32; d];
            Bench::new(format!("pallas_kernel_step/d{d}"))
                .with_iters(20, 200)
                .run(|| {
                    std::hint::black_box(
                        kernel.step(&mut s, &m, &g).unwrap());
                });
        }
    } else {
        println!("(artifacts missing — skipping PJRT kernel rows)");
    }
}
