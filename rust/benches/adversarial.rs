//! Bench: Statement 1 — greedy Ω(n) vs random O(√n) on the adversarial
//! family, with wall-time of the greedy selection itself.
//!
//! Run: `cargo bench --bench adversarial`

use grab::herding::adversarial::adversarial_vectors;
use grab::herding::greedy::{greedy_order, greedy_order_raw};
use grab::herding::herding_bound;
use grab::util::rng::Rng;
use grab::util::stats::scaling_exponent;
use grab::util::timer::Bench;

fn main() {
    println!("== adversarial bench (statement1) ==");
    let ns = [256usize, 512, 1024, 2048, 4096];
    let mut rng = Rng::new(0);
    let mut greedy_bounds = Vec::new();
    let mut random_bounds = Vec::new();

    println!(
        "{:>8} {:>14} {:>17} {:>12}",
        "n", "greedy_raw", "greedy_centered", "random(avg5)"
    );
    for &n in &ns {
        let vs = adversarial_vectors(n);
        let graw =
            herding_bound(&vs, &greedy_order_raw(&vs)).1 as f64;
        let gcen = herding_bound(&vs, &greedy_order(&vs)).1 as f64;
        let mut acc = 0.0;
        for _ in 0..5 {
            acc += herding_bound(&vs, &rng.permutation(n)).1 as f64;
        }
        let rand = acc / 5.0;
        println!("{n:>8} {graw:>14.2} {gcen:>17.2} {rand:>12.2}");
        greedy_bounds.push(graw);
        random_bounds.push(rand);
    }
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    println!(
        "exponents: greedy ~ n^{:.2} (paper Ω(n)), random ~ n^{:.2} \
         (paper O(√n))",
        scaling_exponent(&xs, &greedy_bounds),
        scaling_exponent(&xs, &random_bounds)
    );

    for &n in &[512usize, 2048] {
        let vs = adversarial_vectors(n);
        Bench::new(format!("greedy_select/adversarial/n{n}"))
            .with_iters(3, 30)
            .run(|| {
                std::hint::black_box(greedy_order_raw(&vs).len());
            });
    }
}
