//! Bench: herding-bound machinery (regenerates the data behind Fig. 1b
//! and Fig. 4) — wall-time of balance+reorder passes across (n, d) and
//! the bounds achieved by Alg. 5 vs Alg. 6 vs greedy vs random.
//!
//! Run: `cargo bench --bench herding_bound`

use grab::balance::{Balancer, DeterministicBalancer, WalkBalancer};
use grab::herding::offline::herd;
use grab::herding::{greedy::greedy_order, herding_bound};
use grab::util::rng::Rng;
use grab::util::timer::Bench;

fn main() {
    println!("== herding_bound bench (fig1/fig4 series) ==");
    let mut rng = Rng::new(0);

    // --- pass cost scaling (one balance+reorder pass) -------------------
    for (n, d) in [(1000usize, 16usize), (1000, 128), (4000, 128),
                   (10000, 128), (4000, 1024)] {
        let vs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32()).collect())
            .collect();
        Bench::new(format!("balance_reorder_pass/n{n}/d{d}"))
            .with_iters(3, 50)
            .run(|| {
                let mut b = DeterministicBalancer;
                let (_, stats) = herd(&mut b, &vs, 1);
                std::hint::black_box(stats.len());
            });
    }

    // --- achieved bounds: the fig1 comparison at bench scale -------------
    let n = 4000;
    let d = 128;
    let vs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| rng.f32()).collect())
        .collect();
    let identity: Vec<usize> = (0..n).collect();
    let random = rng.permutation(n);

    let mut rows: Vec<(String, f32, f32)> = Vec::new();
    let (i_inf, i_l2) = herding_bound(&vs, &identity);
    rows.push(("original".into(), i_inf, i_l2));
    let (r_inf, r_l2) = herding_bound(&vs, &random);
    rows.push(("random".into(), r_inf, r_l2));

    let mut alg5 = DeterministicBalancer;
    let (o1, _) = herd(&mut alg5, &vs, 1);
    let (a1_inf, a1_l2) = herding_bound(&vs, &o1);
    rows.push(("alg5_1pass".into(), a1_inf, a1_l2));
    let (o10, _) = herd(&mut alg5, &vs, 10);
    let (a10_inf, a10_l2) = herding_bound(&vs, &o10);
    rows.push(("alg5_10pass".into(), a10_inf, a10_l2));

    let mut alg6: Box<dyn Balancer> = Box::new(WalkBalancer::new(
        ((n * d) as f64).ln(),
        1,
    ));
    let (w10, _) = herd(alg6.as_mut(), &vs, 10);
    let (w_inf, w_l2) = herding_bound(&vs, &w10);
    rows.push(("alg6_10pass".into(), w_inf, w_l2));

    let g = greedy_order(&vs);
    let (g_inf, g_l2) = herding_bound(&vs, &g);
    rows.push(("greedy".into(), g_inf, g_l2));

    println!("\nachieved herding bounds (n={n}, d={d}):");
    println!("{:<14} {:>12} {:>12}", "order", "linf", "l2");
    for (name, inf, l2) in &rows {
        println!("{name:<14} {inf:>12.3} {l2:>12.3}");
    }

    // --- greedy cost (the O(n^2 d) wall the paper reports) ----------------
    for n in [500usize, 1000, 2000] {
        let vs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..128).map(|_| rng.f32()).collect())
            .collect();
        Bench::new(format!("greedy_order/n{n}/d128"))
            .with_iters(2, 10)
            .run(|| {
                std::hint::black_box(greedy_order(&vs).len());
            });
    }
}
