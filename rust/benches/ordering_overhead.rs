//! Bench: Table 1 — ordering compute/storage overhead of RR vs Greedy
//! Ordering vs GraB across n at the paper's logreg dimension d = 7850.
//!
//! Run: `cargo bench --bench ordering_overhead`

use grab::balance::DeterministicBalancer;
use grab::ordering::{GraBOrder, GreedyOrder, OrderPolicy,
                     RandomReshuffle};
use grab::util::prop::gen;
use grab::util::rng::Rng;
use grab::util::stats::scaling_exponent;
use grab::util::timer::Bench;

fn one_epoch(policy: &mut dyn OrderPolicy, vs: &[Vec<f32>]) {
    let order = policy.epoch_order(0);
    if policy.wants_grads() {
        for (pos, &unit) in order.iter().enumerate() {
            policy.observe(pos, &vs[unit]);
        }
    }
    policy.epoch_end();
}

fn main() {
    println!("== ordering_overhead bench (table1) ==");
    let d = 7850;
    let ns = [256usize, 512, 1024];
    let mut greedy_times = Vec::new();
    let mut grab_times = Vec::new();

    for &n in &ns {
        let mut rng = Rng::new(n as u64);
        let vs = gen::vec_set(&mut rng, n, d);

        let r = Bench::new(format!("epoch_order/rr/n{n}/d{d}"))
            .with_iters(5, 100)
            .run(|| {
                let mut p = RandomReshuffle::new(n, 0);
                one_epoch(&mut p, &vs);
            });
        let _ = r;

        let r = Bench::new(format!("epoch_order/grab/n{n}/d{d}"))
            .with_iters(5, 50)
            .run(|| {
                let mut p = GraBOrder::new(
                    n, d, Box::new(DeterministicBalancer));
                one_epoch(&mut p, &vs);
            });
        grab_times.push((n as f64, r.summary.mean));

        let r = Bench::new(format!("epoch_order/greedy/n{n}/d{d}"))
            .with_iters(2, 5)
            .run(|| {
                let mut p = GreedyOrder::new(n, d);
                one_epoch(&mut p, &vs);
            });
        greedy_times.push((n as f64, r.summary.mean));

        // Memory column, measured once.
        let mut greedy = GreedyOrder::new(n, d);
        one_epoch(&mut greedy, &vs);
        let mut grab = GraBOrder::new(
            n, d, Box::new(DeterministicBalancer));
        one_epoch(&mut grab, &vs);
        println!(
            "state_bytes n={n}: greedy={} grab={} ({:.2}%)",
            greedy.state_bytes(),
            grab.state_bytes(),
            100.0 * grab.state_bytes() as f64
                / greedy.state_bytes() as f64
        );
    }

    let xs: Vec<f64> = greedy_times.iter().map(|p| p.0).collect();
    let gy: Vec<f64> = greedy_times.iter().map(|p| p.1).collect();
    let by: Vec<f64> = grab_times.iter().map(|p| p.1).collect();
    println!(
        "\nscaling fits: greedy time ~ n^{:.2} (theory n^2), \
         grab time ~ n^{:.2} (theory n^1)",
        scaling_exponent(&xs, &gy),
        scaling_exponent(&xs, &by)
    );
}
