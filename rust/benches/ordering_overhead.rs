//! Bench: Table 1 — ordering compute/storage overhead of RR vs Greedy
//! Ordering vs GraB across n at the paper's logreg dimension d = 7850 —
//! plus the block-streaming deliverables:
//!
//!   * per-example (1-row blocks through the `observe` shim, one virtual
//!     dispatch + running-sum refresh per example) vs block observe
//!     throughput at d = 4096 — the refactor's ≥1.5× acceptance gate;
//!   * PairBalance (CD-GraB) vs GraB observe throughput and herding
//!     bounds on the same static gradient stream;
//!   * the ShardedOrder dispatch backends: strided row forwarding vs
//!     gathered scratch-block batching vs the async worker-thread
//!     coordinator vs the loopback-TCP socket coordinator (per-epoch
//!     wall clock incl. the epoch-boundary drain, plus queue
//!     backpressure counts and wire bytes);
//!   * the same dispatch lineup under a skewed 1:1:4 weighted topology
//!     (one shard owns 2/3 of the units) — what imbalance costs each
//!     backend, the elastic layer's motivating measurement;
//!   * the wire codec: block-frame encode/decode throughput vs the raw
//!     gather cost it rides on (what serialization adds per row before
//!     the socket is even touched);
//!   * the streaming reservoir (`StreamOrder`): window-advance cost vs
//!     reservoir size, static membership vs count-neutral churn — what
//!     the admit/evict/carry-out bookkeeping adds per window over bare
//!     pair balancing (contract 9 says the orders are identical).
//!
//! Run: `cargo bench --bench ordering_overhead`

use grab::balance::DeterministicBalancer;
use grab::herding::herding_bound;
use grab::ordering::stream::{DriftPlan, StreamOrder};
use grab::ordering::transport::codec;
use grab::ordering::{stream_static_epoch, GradBlock, GraBOrder,
                     GreedyOrder, OrderPolicy, PairBalance,
                     RandomReshuffle, ShardedOrder};
use grab::util::ser::{decode_frame, encode_frame, FrameKind};
use grab::util::prop::gen;
use grab::util::rng::Rng;
use grab::util::stats::scaling_exponent;
use grab::util::timer::Bench;

fn one_epoch(policy: &mut dyn OrderPolicy, vs: &[Vec<f32>]) {
    let order = policy.epoch_order(0).to_vec();
    if policy.wants_grads() {
        for (pos, &unit) in order.iter().enumerate() {
            policy.observe(pos, &vs[unit]);
        }
    }
    policy.epoch_end();
}

/// Stream one epoch of a flat [n × d] gradient matrix through a policy.
/// First-epoch orders are the identity for the gradient-aware policies
/// here, so the flat buffer doubles as the gathered visit-order stream —
/// both paths below read identical bytes.
fn observe_epoch_blocks(
    policy: &mut dyn OrderPolicy,
    flat: &[f32],
    n: usize,
    d: usize,
    block: usize,
) {
    let _ = policy.epoch_order(0);
    let mut pos = 0;
    while pos < n {
        let end = (pos + block).min(n);
        policy.observe_block(
            pos..end,
            &GradBlock::new(&flat[pos * d..end * d], d),
        );
        pos = end;
    }
    policy.epoch_end();
}

fn observe_epoch_per_example(
    policy: &mut dyn OrderPolicy,
    flat: &[f32],
    n: usize,
    d: usize,
) {
    let _ = policy.epoch_order(0);
    for pos in 0..n {
        policy.observe(pos, &flat[pos * d..(pos + 1) * d]);
    }
    policy.epoch_end();
}

fn table1_section() {
    println!("== ordering_overhead bench (table1) ==");
    let d = 7850;
    let ns = [256usize, 512, 1024];
    let mut greedy_times = Vec::new();
    let mut grab_times = Vec::new();

    for &n in &ns {
        let mut rng = Rng::new(n as u64);
        let vs = gen::vec_set(&mut rng, n, d);

        let r = Bench::new(format!("epoch_order/rr/n{n}/d{d}"))
            .with_iters(5, 100)
            .run(|| {
                let mut p = RandomReshuffle::new(n, 0);
                one_epoch(&mut p, &vs);
            });
        let _ = r;

        let r = Bench::new(format!("epoch_order/grab/n{n}/d{d}"))
            .with_iters(5, 50)
            .run(|| {
                let mut p = GraBOrder::new(
                    n, d, Box::new(DeterministicBalancer));
                one_epoch(&mut p, &vs);
            });
        grab_times.push((n as f64, r.summary.mean));

        let r = Bench::new(format!("epoch_order/greedy/n{n}/d{d}"))
            .with_iters(2, 5)
            .run(|| {
                let mut p = GreedyOrder::new(n, d);
                one_epoch(&mut p, &vs);
            });
        greedy_times.push((n as f64, r.summary.mean));

        // Memory column, measured once.
        let mut greedy = GreedyOrder::new(n, d);
        one_epoch(&mut greedy, &vs);
        let mut grab = GraBOrder::new(
            n, d, Box::new(DeterministicBalancer));
        one_epoch(&mut grab, &vs);
        println!(
            "state_bytes n={n}: greedy={} grab={} ({:.2}%)",
            greedy.state_bytes(),
            grab.state_bytes(),
            100.0 * grab.state_bytes() as f64
                / greedy.state_bytes() as f64
        );
    }

    let xs: Vec<f64> = greedy_times.iter().map(|p| p.0).collect();
    let gy: Vec<f64> = greedy_times.iter().map(|p| p.1).collect();
    let by: Vec<f64> = grab_times.iter().map(|p| p.1).collect();
    println!(
        "\nscaling fits: greedy time ~ n^{:.2} (theory n^2), \
         grab time ~ n^{:.2} (theory n^1)",
        scaling_exponent(&xs, &gy),
        scaling_exponent(&xs, &by)
    );
}

fn block_vs_per_example_section() {
    println!("\n== per-example vs block observe throughput ==");
    let d = 4096;
    let n = 512;
    let block = 64;
    let mut rng = Rng::new(42);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();

    let per = Bench::new(format!("grab_observe/per_example/n{n}/d{d}"))
        .with_iters(5, 60)
        .run(|| {
            let mut p = GraBOrder::new(
                n, d, Box::new(DeterministicBalancer));
            observe_epoch_per_example(&mut p, &flat, n, d);
        });
    let blk = Bench::new(format!(
        "grab_observe/block{block}/n{n}/d{d}"
    ))
    .with_iters(5, 60)
    .run(|| {
        let mut p = GraBOrder::new(
            n, d, Box::new(DeterministicBalancer));
        observe_epoch_blocks(&mut p, &flat, n, d, block);
    });
    let pair = Bench::new(format!(
        "pair_observe/block{block}/n{n}/d{d}"
    ))
    .with_iters(5, 60)
    .run(|| {
        let mut p = PairBalance::new(n, d);
        observe_epoch_blocks(&mut p, &flat, n, d, block);
    });

    let speedup = per.summary.mean / blk.summary.mean;
    println!(
        "\nblock observe speedup over per-example at d={d}: {speedup:.2}x \
         (gate: >= 1.5x)"
    );
    println!(
        "pair balance vs grab block observe: {:.2}x",
        blk.summary.mean / pair.summary.mean
    );
    println!(
        "per-example {:.1} ns/example, block {:.1} ns/example, \
         pair {:.1} ns/example",
        per.summary.mean / n as f64 * 1e9,
        blk.summary.mean / n as f64 * 1e9,
        pair.summary.mean / n as f64 * 1e9,
    );
}

fn pair_vs_grab_herding_section() {
    println!("\n== PairBalance vs GraB herding bounds (static set) ==");
    let n = 1024;
    let d = 64;
    let block = 64;
    let epochs = 8;
    let mut rng = Rng::new(7);
    let vs = gen::vec_set(&mut rng, n, d);
    let mut rand_acc = 0.0f32;
    for _ in 0..5 {
        let perm = rng.permutation(n);
        rand_acc += herding_bound(&vs, &perm).0;
    }
    let rand_inf = rand_acc / 5.0;
    println!("random reshuffling: {rand_inf:.4}");

    let mut flat = Vec::new();
    let mut policies: Vec<(&str, Box<dyn OrderPolicy>)> = vec![
        ("grab", Box::new(GraBOrder::new(
            n, d, Box::new(DeterministicBalancer)))),
        ("pair", Box::new(PairBalance::new(n, d))),
        ("cd-grab-w1", Box::new(ShardedOrder::new(n, d, 1))),
        ("cd-grab-w4", Box::new(ShardedOrder::new(n, d, 4))),
    ];
    for (name, policy) in policies.iter_mut() {
        for epoch in 0..epochs {
            stream_static_epoch(
                policy.as_mut(), epoch, &vs, &mut flat, block,
            );
        }
        let (inf, _) = herding_bound(&vs, policy.epoch_order(epochs));
        println!(
            "{name}: {inf:.4} after {epochs} epochs \
             ({:.1}x below random)",
            rand_inf / inf
        );
    }
}

fn sharded_dispatch_section() {
    println!(
        "\n== sharded coordinator dispatch: strided vs gathered vs \
         async =="
    );
    let n = 2048;
    let d = 256;
    let block = 64;
    let w = 4;
    let depth = 4;
    let mut rng = Rng::new(21);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();

    // Policies persist across bench iterations, so each iteration is
    // one steady-state epoch (thread spawn / first-touch costs land in
    // the warmup, not the measurement).
    let mut strided = ShardedOrder::new(n, d, w);
    let st = Bench::new(format!("sharded_observe/strided/w{w}/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut strided, &flat, n, d, block));

    let mut gathered = ShardedOrder::new_gathered(n, d, w);
    let ga = Bench::new(format!("sharded_observe/gathered/w{w}/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut gathered, &flat, n, d, block));

    let mut asynch = ShardedOrder::new_async(n, d, w, depth);
    let asy = Bench::new(format!(
        "sharded_observe/async/w{w}/d{d}/q{depth}"
    ))
    .with_iters(5, 60)
    .run(|| observe_epoch_blocks(&mut asynch, &flat, n, d, block));

    let mut socket = ShardedOrder::new_tcp_loopback(n, d, w)
        .expect("loopback workers");
    let tcp = Bench::new(format!("sharded_observe/tcp/w{w}/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut socket, &flat, n, d, block));

    println!(
        "\ngather vs strided (sync coordinator): {:.2}x \
         (one copy buys batched balancing)",
        st.summary.mean / ga.summary.mean
    );
    println!(
        "async vs sync strided coordinator: {:.2}x per epoch \
         (incl. epoch-boundary drain; {} queue stalls across all \
         epochs incl. warmup)",
        st.summary.mean / asy.summary.mean,
        asynch.queue_stalls(),
    );
    let wire = socket.transport_stats().total();
    println!(
        "tcp vs async channel coordinator: {:.2}x per epoch \
         ({} B tx + {} B rx across all epochs incl. warmup — \
         frame+checksum+loopback cost of the same conversation)",
        asy.summary.mean / tcp.summary.mean,
        wire.tx_bytes,
        wire.rx_bytes,
    );
    println!(
        "strided {:.1} ns/example, gathered {:.1} ns/example, \
         async {:.1} ns/example, tcp {:.1} ns/example \
         (coordinator-thread epoch time)",
        st.summary.mean / n as f64 * 1e9,
        ga.summary.mean / n as f64 * 1e9,
        asy.summary.mean / n as f64 * 1e9,
        tcp.summary.mean / n as f64 * 1e9,
    );
}

fn skewed_dispatch_section() {
    // The elastic-topology ablation: the same coordinator under a
    // 1:1:4 weight skew (one shard owns 2/3 of the units). Strided
    // pays per-row dispatch regardless; gathered batches the heavy
    // shard's rows; async hides the heavy shard's balancing behind the
    // queue until the boundary drain; tcp adds framing on top. Read
    // against sharded_dispatch_section for the imbalance premium.
    println!(
        "\n== skewed shard dispatch (weights 1:1:4): strided vs \
         gathered vs async vs tcp =="
    );
    let n = 2048;
    let d = 256;
    let block = 64;
    let depth = 4;
    let weights: [u64; 3] = [1, 1, 4];
    let mut rng = Rng::new(27);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();

    let mut strided = ShardedOrder::new_weighted(n, d, &weights);
    let st = Bench::new(format!("skewed_observe/strided/114/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut strided, &flat, n, d, block));

    let mut gathered =
        ShardedOrder::new_gathered_weighted(n, d, &weights);
    let ga = Bench::new(format!("skewed_observe/gathered/114/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut gathered, &flat, n, d, block));

    let mut asynch =
        ShardedOrder::new_async_weighted(n, d, &weights, depth);
    let asy = Bench::new(format!(
        "skewed_observe/async/114/d{d}/q{depth}"
    ))
    .with_iters(5, 60)
    .run(|| observe_epoch_blocks(&mut asynch, &flat, n, d, block));

    let mut socket = ShardedOrder::new_tcp_loopback_weighted(
        n, d, &weights,
    )
    .expect("loopback workers");
    let tcp = Bench::new(format!("skewed_observe/tcp/114/d{d}"))
        .with_iters(5, 60)
        .run(|| observe_epoch_blocks(&mut socket, &flat, n, d, block));

    println!(
        "\nskew 1:1:4 — gather vs strided: {:.2}x, async vs strided: \
         {:.2}x ({} stalls: the heavy shard's queue backpressure), \
         tcp vs async: {:.2}x",
        st.summary.mean / ga.summary.mean,
        st.summary.mean / asy.summary.mean,
        asynch.queue_stalls(),
        asy.summary.mean / tcp.summary.mean,
    );
    println!(
        "strided {:.1} ns/example, gathered {:.1} ns/example, \
         async {:.1} ns/example, tcp {:.1} ns/example under imbalance",
        st.summary.mean / n as f64 * 1e9,
        ga.summary.mean / n as f64 * 1e9,
        asy.summary.mean / n as f64 * 1e9,
        tcp.summary.mean / n as f64 * 1e9,
    );
}

fn wire_codec_section() {
    println!("\n== wire codec: block frame encode/decode throughput ==");
    let d = 256;
    let rows = 64; // one gathered microbatch block
    let mut rng = Rng::new(33);
    let data: Vec<f32> =
        (0..rows * d).map(|_| rng.gauss() as f32).collect();
    let bytes_per_block = (rows * d * 4) as f64;

    // Baseline: the gather copy the transport already pays (push_row
    // into a scratch block), for scale.
    let mut scratch: Vec<f32> = Vec::with_capacity(rows * d);
    let gather = Bench::new(format!("wire/gather/r{rows}/d{d}"))
        .with_iters(10, 2000)
        .run(|| {
            scratch.clear();
            for r in 0..rows {
                scratch.extend_from_slice(&data[r * d..(r + 1) * d]);
            }
        });

    let mut payload = Vec::new();
    let mut frame = Vec::new();
    let enc = Bench::new(format!("wire/encode/r{rows}/d{d}"))
        .with_iters(10, 2000)
        .run(|| {
            codec::encode_block(&data, d, &mut payload);
            frame.clear();
            encode_frame(FrameKind::Block, &payload, &mut frame);
        });

    let mut decoded: Vec<f32> = Vec::new();
    let dec = Bench::new(format!("wire/decode/r{rows}/d{d}"))
        .with_iters(10, 2000)
        .run(|| {
            let (kind, body, _) = decode_frame(&frame).expect("frame");
            assert!(matches!(kind, FrameKind::Block));
            codec::decode_block(body, d, &mut decoded).expect("block");
        });

    println!(
        "\ngather {:.2} GB/s, encode+frame {:.2} GB/s, \
         checksum+decode {:.2} GB/s ({} B/block)",
        bytes_per_block / gather.summary.mean / 1e9,
        bytes_per_block / enc.summary.mean / 1e9,
        bytes_per_block / dec.summary.mean / 1e9,
        rows * d * 4 + 20,
    );
    println!(
        "serialization overhead vs the gather it rides on: \
         encode {:.2}x, decode {:.2}x",
        enc.summary.mean / gather.summary.mean,
        dec.summary.mean / gather.summary.mean,
    );
}

fn stream_reservoir_section() {
    println!(
        "\n== streaming reservoir: window advance cost vs reservoir \
         size =="
    );
    let d = 256;
    let block = 64;
    for n in [256usize, 1024, 4096] {
        let mut rng = Rng::new(n as u64);
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();

        // Static membership: the reservoir degenerates to PairBalance
        // (contract 9), so this row is the window-advance overhead the
        // reservoir bookkeeping adds over pair_observe.
        let mut staticr = StreamOrder::prefilled(n, d);
        let st = Bench::new(format!("stream_window/static/n{n}/d{d}"))
            .with_iters(5, 60)
            .run(|| {
                staticr.run_window(
                    &mut |unit, out| {
                        let u = unit as usize % n;
                        out.copy_from_slice(&flat[u * d..(u + 1) * d]);
                    },
                    block,
                );
            });

        // Count-neutral churn: n/16 admits per window, FIFO eviction
        // absorbing them — adds plan derivation + carry-out per window
        // but never rebuilds the backend.
        let rate = (n / 16).max(1);
        let drift = DriftPlan::steady(7, rate);
        let mut churn = StreamOrder::prefilled(n, d);
        let mut next_unit = n as u64;
        let ch =
            Bench::new(format!("stream_window/churn{rate}/n{n}/d{d}"))
                .with_iters(5, 60)
                .run(|| {
                    churn.drive_window(&drift, &mut next_unit, block);
                });

        println!(
            "n={n}: static {:.1} ns/unit, churn({rate}/window) {:.1} \
             ns/unit ({:.2}x; {} evictions across all windows incl. \
             warmup, {} replans)",
            st.summary.mean / n as f64 * 1e9,
            ch.summary.mean / n as f64 * 1e9,
            ch.summary.mean / st.summary.mean,
            churn.stats().evictions,
            churn.stats().replans,
        );
    }
}

fn main() {
    table1_section();
    block_vs_per_example_section();
    pair_vs_grab_herding_section();
    sharded_dispatch_section();
    skewed_dispatch_section();
    wire_codec_section();
    stream_reservoir_section();
}
