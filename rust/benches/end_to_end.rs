//! Bench: end-to-end training throughput per ordering policy (the
//! wall-clock dimension of Fig. 2) plus the microbatch-size ablation
//! called out in DESIGN.md §8.
//!
//! Requires `artifacts/`. Run: `cargo bench --bench end_to_end`

use grab::config::{OrderingKind, Task, TrainConfig};
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::train::Trainer;
use grab::util::timer::Bench;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }
    println!("== end_to_end bench (fig2 wall-clock) ==");
    let rt = Runtime::open("artifacts").expect("runtime");

    // --- epoch cost per ordering on mnist/logreg -------------------------
    let n = 512;
    for ordering in [
        OrderingKind::RandomReshuffle,
        OrderingKind::ShuffleOnce,
        OrderingKind::FlipFlop,
        OrderingKind::GraB,
        OrderingKind::GreedyOrdering,
    ] {
        let mut cfg = TrainConfig::for_task(Task::Mnist);
        cfg.ordering = ordering;
        cfg.epochs = 1;
        cfg.n_examples = n;
        cfg.n_eval = 256;
        cfg.eval_every = 0;
        let r = Bench::new(format!(
            "train_epoch/mnist/{}/n{n}", ordering.name()))
            .with_iters(2, 8)
            .run(|| {
                let mut t =
                    Trainer::new(cfg.clone(), &rt, None).unwrap();
                let res = t.run().unwrap();
                std::hint::black_box(res.final_train_loss());
            });
        println!(
            "  -> {:.1} examples/s",
            n as f64 / r.summary.mean
        );
    }

    // --- sync vs threaded pipeline ---------------------------------------
    for (name, pipeline) in [("sync", false), ("pipeline", true)] {
        let mut cfg = TrainConfig::for_task(Task::Glue);
        cfg.ordering = OrderingKind::GraB;
        cfg.epochs = 1;
        cfg.n_examples = 256;
        cfg.n_eval = 64;
        cfg.eval_every = 0;
        cfg.accum_steps = 4;
        let r = Bench::new(format!("train_epoch/glue/grab/{name}"))
            .with_iters(2, 6)
            .run(|| {
                if pipeline {
                    let mut t =
                        PipelineTrainer::new(cfg.clone(), &rt).unwrap();
                    std::hint::black_box(t.run().unwrap().run_id.len());
                } else {
                    let mut t =
                        Trainer::new(cfg.clone(), &rt, None).unwrap();
                    std::hint::black_box(t.run().unwrap().run_id.len());
                }
            });
        println!("  -> {:.1} examples/s", 256.0 / r.summary.mean);
    }

    // --- microbatch/accumulation sweep (design ablation #3) --------------
    for accum in [1usize, 2, 4, 8] {
        let mut cfg = TrainConfig::for_task(Task::Mnist);
        cfg.ordering = OrderingKind::GraB;
        cfg.epochs = 1;
        cfg.n_examples = 512;
        cfg.n_eval = 256;
        cfg.eval_every = 0;
        cfg.accum_steps = accum;
        Bench::new(format!("accum_sweep/mnist/grab/accum{accum}"))
            .with_iters(2, 8)
            .run(|| {
                let mut t =
                    Trainer::new(cfg.clone(), &rt, None).unwrap();
                std::hint::black_box(t.run().unwrap().run_id.len());
            });
    }
}
