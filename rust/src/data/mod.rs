//! Dataset substrate: in-memory datasets, synthetic generators standing in
//! for the paper's corpora (see DESIGN.md §Substitutions), microbatch
//! loading and deterministic sharding.

pub mod loader;
pub mod shard;
pub mod synth;
pub mod text;

use anyhow::{bail, Result};

/// Feature storage. Models take either dense f32 features (logreg, lenet)
/// or i32 token sequences (lstm, transformer).
#[derive(Clone, Debug)]
pub enum Features {
    /// Dense row-major `[n × dim]` float features.
    F32 {
        /// Flattened feature matrix.
        data: Vec<f32>,
        /// Per-example feature width.
        dim: usize,
    },
    /// Row-major `[n × dim]` token-id sequences.
    I32 {
        /// Flattened token matrix.
        data: Vec<i32>,
        /// Per-example sequence length.
        dim: usize,
    },
}

impl Features {
    /// Per-example feature width.
    pub fn dim(&self) -> usize {
        match self {
            Features::F32 { dim, .. } | Features::I32 { dim, .. } => *dim,
        }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        match self {
            Features::F32 { data, dim } => data.len() / dim,
            Features::I32 { data, dim } => data.len() / dim,
        }
    }

    /// Whether the store holds no examples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Label storage: one class per example, or one target sequence (LM).
#[derive(Clone, Debug)]
pub enum Labels {
    /// One class id per example.
    Scalar(Vec<i32>),
    /// One `[dim]` target sequence per example (language modeling).
    Seq {
        /// Flattened target matrix.
        data: Vec<i32>,
        /// Per-example target length.
        dim: usize,
    },
}

impl Labels {
    /// Number of labeled examples.
    pub fn len(&self) -> usize {
        match self {
            Labels::Scalar(v) => v.len(),
            Labels::Seq { data, dim } => data.len() / dim,
        }
    }

    /// Whether the store holds no labels.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-example label width (1 for scalar labels).
    pub fn dim(&self) -> usize {
        match self {
            Labels::Scalar(_) => 1,
            Labels::Seq { dim, .. } => *dim,
        }
    }
}

/// An in-memory dataset of `n` ordering units.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable dataset name (used in logs and errors).
    pub name: String,
    /// Feature storage.
    pub x: Features,
    /// Label storage (same example count as `x`).
    pub y: Labels,
}

impl Dataset {
    /// Pair features with labels; errors on count mismatch.
    pub fn new(name: impl Into<String>, x: Features, y: Labels)
        -> Result<Dataset> {
        if x.len() != y.len() {
            bail!("feature/label count mismatch: {} vs {}",
                  x.len(), y.len());
        }
        Ok(Dataset { name: name.into(), x, y })
    }

    /// Number of ordering units (examples).
    pub fn len(&self) -> usize {
        self.x.len()
    }

    /// Whether the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Gather the features of `idx` into `out` (f32 datasets).
    pub fn gather_x_f32(&self, idx: &[usize], out: &mut Vec<f32>) {
        let Features::F32 { data, dim } = &self.x else {
            panic!("gather_x_f32 on i32 dataset {}", self.name);
        };
        out.clear();
        for &i in idx {
            out.extend_from_slice(&data[i * dim..(i + 1) * dim]);
        }
    }

    /// Gather the features of `idx` into `out` (token datasets).
    pub fn gather_x_i32(&self, idx: &[usize], out: &mut Vec<i32>) {
        let Features::I32 { data, dim } = &self.x else {
            panic!("gather_x_i32 on f32 dataset {}", self.name);
        };
        out.clear();
        for &i in idx {
            out.extend_from_slice(&data[i * dim..(i + 1) * dim]);
        }
    }

    /// Gather labels (scalar or sequence) of `idx` into `out`.
    pub fn gather_y(&self, idx: &[usize], out: &mut Vec<i32>) {
        out.clear();
        match &self.y {
            Labels::Scalar(v) => out.extend(idx.iter().map(|&i| v[i])),
            Labels::Seq { data, dim } => {
                for &i in idx {
                    out.extend_from_slice(&data[i * dim..(i + 1) * dim]);
                }
            }
        }
    }

    /// Class balance (scalar-label datasets): counts per class.
    pub fn class_counts(&self, n_classes: usize) -> Vec<usize> {
        let Labels::Scalar(v) = &self.y else {
            return vec![];
        };
        let mut counts = vec![0usize; n_classes];
        for &y in v {
            counts[y as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            "t",
            Features::F32 {
                data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                dim: 2,
            },
            Labels::Scalar(vec![0, 1, 0]),
        )
        .unwrap()
    }

    #[test]
    fn lengths() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.x.dim(), 2);
    }

    #[test]
    fn gather_orders_by_index() {
        let d = tiny();
        let mut x = Vec::new();
        let mut y = Vec::new();
        d.gather_x_f32(&[2, 0], &mut x);
        d.gather_y(&[2, 0], &mut y);
        assert_eq!(x, vec![5.0, 6.0, 1.0, 2.0]);
        assert_eq!(y, vec![0, 0]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        assert!(Dataset::new(
            "bad",
            Features::F32 { data: vec![0.0; 4], dim: 2 },
            Labels::Scalar(vec![0]),
        )
        .is_err());
    }

    #[test]
    fn class_counts() {
        let d = tiny();
        assert_eq!(d.class_counts(2), vec![2, 1]);
    }
}
