//! WikiText-2 stand-in: a character-level corpus drawn from a random sparse
//! first-order Markov chain, cut into bptt-length training sequences.
//!
//! The chain gives the corpus real learnable structure (conditional entropy
//! well below log|V|), so the LSTM's loss curve has the same "fast drop,
//! long tail" shape the paper's Fig. 2c exercises, and per-sequence
//! gradients are heterogeneous (different chain regions), which is what
//! GraB orders on.

use crate::data::{Dataset, Features, Labels};
use crate::util::rng::Rng;

/// Corpus generator parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    /// Vocabulary size (number of Markov states).
    pub vocab: usize,
    /// Sequence length (paper bptt = 35).
    pub bptt: usize,
    /// Out-degree of each state in the Markov chain (sparsity).
    pub branching: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { vocab: 32, bptt: 35, branching: 4 }
    }
}

/// Generate a character stream of length `len` from a random chain.
pub fn markov_stream(spec: &CorpusSpec, len: usize, seed: u64) -> Vec<i32> {
    // Chain *structure* depends only on the low seed bits (same language
    // for train and eval); the walk itself uses the full seed.
    let mut structure_rng = Rng::new((seed & 0xFFFF) ^ 0x7EC7);
    let mut rng = Rng::new(seed ^ 0xC7E7);
    let v = spec.vocab;
    // Each state transitions to `branching` successors with random weights.
    let mut succ = vec![vec![]; v];
    for s in succ.iter_mut() {
        for _ in 0..spec.branching {
            s.push((structure_rng.gen_range(v as u64) as usize,
                    structure_rng.uniform(0.5, 2.0)));
        }
    }
    let mut out = Vec::with_capacity(len);
    let mut state = rng.gen_range(v as u64) as usize;
    for _ in 0..len {
        out.push(state as i32);
        let weights: Vec<f64> =
            succ[state].iter().map(|&(_, w)| w).collect();
        let k = rng.categorical(&weights);
        state = succ[state][k].0;
    }
    out
}

/// Cut a stream into `n` (x, y) training sequences of length bptt where
/// y is x shifted by one (next-character prediction), at stride bptt —
/// the standard contiguous-chunks LM layout (paper's WikiText-2 setup).
pub fn lm_dataset(spec: &CorpusSpec, n: usize, seed: u64) -> Dataset {
    let t = spec.bptt;
    let stream = markov_stream(spec, n * t + 1, seed);
    let mut xs = Vec::with_capacity(n * t);
    let mut ys = Vec::with_capacity(n * t);
    for i in 0..n {
        let start = i * t;
        xs.extend_from_slice(&stream[start..start + t]);
        ys.extend_from_slice(&stream[start + 1..start + t + 1]);
    }
    Dataset::new(
        "markov_lm",
        Features::I32 { data: xs, dim: t },
        Labels::Seq { data: ys, dim: t },
    )
    .expect("generator invariant")
}

/// Empirical conditional entropy (nats) of a stream under its order-1
/// statistics — used by tests to verify the corpus is genuinely learnable
/// (entropy substantially below ln(vocab)).
pub fn conditional_entropy(stream: &[i32], vocab: usize) -> f64 {
    let mut counts = vec![vec![0usize; vocab]; vocab];
    for w in stream.windows(2) {
        counts[w[0] as usize][w[1] as usize] += 1;
    }
    let mut h = 0.0;
    let total: usize = counts.iter().map(|r| r.iter().sum::<usize>()).sum();
    for row in &counts {
        let rn: usize = row.iter().sum();
        if rn == 0 {
            continue;
        }
        let pr = rn as f64 / total as f64;
        let mut hr = 0.0;
        for &c in row {
            if c > 0 {
                let p = c as f64 / rn as f64;
                hr -= p * p.ln();
            }
        }
        h += pr * hr;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_tokens_in_vocab() {
        let spec = CorpusSpec::default();
        let s = markov_stream(&spec, 1000, 0);
        assert_eq!(s.len(), 1000);
        assert!(s.iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
    }

    #[test]
    fn corpus_is_learnable() {
        let spec = CorpusSpec::default();
        let s = markov_stream(&spec, 50_000, 1);
        let h = conditional_entropy(&s, spec.vocab);
        let hmax = (spec.vocab as f64).ln();
        assert!(
            h < 0.6 * hmax,
            "conditional entropy {h:.3} not << ln(V)={hmax:.3}"
        );
    }

    #[test]
    fn lm_dataset_shift_by_one() {
        let spec = CorpusSpec { vocab: 8, bptt: 5, branching: 3 };
        let d = lm_dataset(&spec, 4, 2);
        assert_eq!(d.len(), 4);
        let Features::I32 { data: xs, dim } = &d.x else { panic!() };
        let Labels::Seq { data: ys, .. } = &d.y else { panic!() };
        // Within a sequence, y[t] == x[t+1].
        for i in 0..4 {
            for t in 0..dim - 1 {
                assert_eq!(ys[i * dim + t], xs[i * dim + t + 1]);
            }
        }
    }

    #[test]
    fn deterministic() {
        let spec = CorpusSpec::default();
        assert_eq!(markov_stream(&spec, 64, 5), markov_stream(&spec, 64, 5));
        assert_ne!(markov_stream(&spec, 64, 5), markov_stream(&spec, 64, 6));
    }
}
