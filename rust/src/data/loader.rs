//! Microbatch loader: walks a permutation in fixed-size microbatches and
//! gathers contiguous upload buffers for the PJRT executor.
//!
//! The L2 grad artifacts take a *fixed* microbatch size B (baked into the
//! HLO), so the loader always emits full microbatches: when n is not a
//! multiple of B the tail is padded by repeating the final example, and the
//! `valid` count tells the trainer how many leading grads are real ordering
//! units (padded grads are never balanced or accumulated).

use crate::data::Dataset;

/// One gathered microbatch ready for upload.
#[derive(Clone, Debug)]
pub struct Microbatch {
    /// Dataset indices in visit order, padded to B (padding repeats the
    /// last valid index).
    pub idx: Vec<usize>,
    /// Number of real (non-padding) examples.
    pub valid: usize,
    /// Position of the first example within the epoch (0-based).
    pub offset: usize,
}

/// Iterator over microbatches of a permutation.
pub struct Loader<'a> {
    order: &'a [usize],
    batch: usize,
    pos: usize,
}

impl<'a> Loader<'a> {
    /// Walk `order` in `batch`-sized microbatches (tail padded).
    pub fn new(order: &'a [usize], batch: usize) -> Loader<'a> {
        assert!(batch > 0, "batch must be positive");
        Loader { order, batch, pos: 0 }
    }

    /// Number of microbatches in the epoch.
    pub fn num_batches(&self) -> usize {
        self.order.len().div_ceil(self.batch)
    }
}

impl<'a> Iterator for Loader<'a> {
    type Item = Microbatch;

    fn next(&mut self) -> Option<Microbatch> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let mut idx: Vec<usize> = self.order[self.pos..end].to_vec();
        let valid = idx.len();
        while idx.len() < self.batch {
            idx.push(*idx.last().expect("non-empty microbatch"));
        }
        let mb = Microbatch { idx, valid, offset: self.pos };
        self.pos = end;
        Some(mb)
    }
}

/// Gathered host buffers for one microbatch (typed by the dataset).
#[derive(Clone, Debug, Default)]
pub struct HostBatch {
    /// Gathered float features (empty for token datasets).
    pub x_f32: Vec<f32>,
    /// Gathered token features (empty for float datasets).
    pub x_i32: Vec<i32>,
    /// Gathered labels / target sequences.
    pub y: Vec<i32>,
}

impl HostBatch {
    /// Fill from a dataset. Buffers are reused across calls (no per-batch
    /// allocation on the hot path).
    pub fn fill(&mut self, ds: &Dataset, mb: &Microbatch) {
        match &ds.x {
            crate::data::Features::F32 { .. } => {
                ds.gather_x_f32(&mb.idx, &mut self.x_f32);
                self.x_i32.clear();
            }
            crate::data::Features::I32 { .. } => {
                ds.gather_x_i32(&mb.idx, &mut self.x_i32);
                self.x_f32.clear();
            }
        }
        ds.gather_y(&mb.idx, &mut self.y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Features, Labels};

    fn ds(n: usize) -> Dataset {
        Dataset::new(
            "t",
            Features::F32 {
                data: (0..n * 2).map(|i| i as f32).collect(),
                dim: 2,
            },
            Labels::Scalar((0..n as i32).collect()),
        )
        .unwrap()
    }

    #[test]
    fn covers_all_examples_once() {
        let order: Vec<usize> = vec![3, 1, 4, 0, 2];
        let mut seen = Vec::new();
        for mb in Loader::new(&order, 2) {
            seen.extend_from_slice(&mb.idx[..mb.valid]);
        }
        assert_eq!(seen, order);
    }

    #[test]
    fn pads_tail_with_last_index() {
        let order: Vec<usize> = vec![0, 1, 2];
        let mbs: Vec<_> = Loader::new(&order, 2).collect();
        assert_eq!(mbs.len(), 2);
        assert_eq!(mbs[1].idx, vec![2, 2]);
        assert_eq!(mbs[1].valid, 1);
        assert_eq!(mbs[1].offset, 2);
    }

    #[test]
    fn num_batches_matches_iteration() {
        for n in [1usize, 7, 8, 9] {
            let order: Vec<usize> = (0..n).collect();
            let l = Loader::new(&order, 4);
            assert_eq!(l.num_batches(), Loader::new(&order, 4).count());
        }
    }

    #[test]
    fn host_batch_gathers_in_visit_order() {
        let d = ds(4);
        let mb = Microbatch { idx: vec![2, 0], valid: 2, offset: 0 };
        let mut hb = HostBatch::default();
        hb.fill(&d, &mb);
        assert_eq!(hb.x_f32, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(hb.y, vec![2, 0]);
    }
}
