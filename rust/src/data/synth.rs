//! Synthetic dataset generators — the substitutes for the paper's corpora
//! (MNIST, CIFAR10, GLUE). Each generator is deterministic in its seed and
//! matches the original's (n, d, #classes) geometry; see DESIGN.md
//! §Substitutions for why these preserve the ordering-relevant structure
//! (per-example gradient heterogeneity ς over a finite sum).

use crate::data::{Dataset, Features, Labels};
use crate::util::rng::Rng;

/// MNIST stand-in: 10-class image mixture, 1×28×28 = 784 dims. Class mean
/// images are sums of smooth 2-D Gaussian blobs (digit-stroke-like energy),
/// so both linear models and convolutions have real signal.
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    image_mixture("mnist_like", n, 1, 28, 10, 0.30, seed)
}

/// CIFAR10 stand-in: 10-class image mixture, 3×32×32 = 3072 dims with
/// heavier within-class variance (natural images are noisier than digits).
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    image_mixture("cifar_like", n, 3, 32, 10, 0.45, seed)
}

/// Image-shaped mixture: per class, each channel's mean image is a sum of
/// `BLOBS` random Gaussian blobs; examples add i.i.d. pixel noise. Spatial
/// smoothness is what lets convolutional models (LeNet) exploit locality,
/// mirroring the real datasets' structure.
pub fn image_mixture(
    name: &str,
    n: usize,
    channels: usize,
    hw: usize,
    n_classes: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    const BLOBS: usize = 4;
    let dim = channels * hw * hw;
    // Task structure from low seed bits only (shared train/eval task).
    let mut srng = Rng::new((seed & 0xFFFF) ^ 0xB10B);
    let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
    let mut means = vec![vec![0.0f32; dim]; n_classes];
    for mean in means.iter_mut() {
        for ch in 0..channels {
            for _ in 0..BLOBS {
                let cx = srng.uniform(4.0, hw as f64 - 4.0);
                let cy = srng.uniform(4.0, hw as f64 - 4.0);
                let sigma = srng.uniform(1.5, 4.0);
                let amp = srng.uniform(-1.2, 1.2);
                for y in 0..hw {
                    for x in 0..hw {
                        let dx = x as f64 - cx;
                        let dy = y as f64 - cy;
                        let v = amp
                            * (-(dx * dx + dy * dy)
                                / (2.0 * sigma * sigma))
                                .exp();
                        mean[ch * hw * hw + y * hw + x] += v as f32;
                    }
                }
            }
        }
    }
    let mut data = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % n_classes;
        labels.push(c as i32);
        for &mu in means[c].iter() {
            let x = mu as f64 + noise * rng.gauss();
            data.push((0.5 + 0.5 * x) as f32); // roughly [0,1] pixels
        }
    }
    let perm = rng.permutation(n);
    let mut sdata = Vec::with_capacity(n * dim);
    let mut slabels = Vec::with_capacity(n);
    for &p in &perm {
        sdata.extend_from_slice(&data[p * dim..(p + 1) * dim]);
        slabels.push(labels[p]);
    }
    Dataset::new(name, Features::F32 { data: sdata, dim },
                 Labels::Scalar(slabels))
        .expect("generator invariant")
}

/// Shared Gaussian-mixture generator.
pub fn gaussian_mixture(
    name: &str,
    n: usize,
    dim: usize,
    n_classes: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    // Task *structure* (the class means) is derived from the low 16 bits of
    // the seed only, so train (seed s) and eval (a different sample seed
    // with the same low bits) describe the SAME classification task and
    // generalization is measurable; the remaining bits drive sampling.
    let mut structure_rng = Rng::new((seed & 0xFFFF) ^ 0x5EED_DA7A);
    let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
    // Sparse class means: each class activates ~12% of the coordinates.
    let mut means = vec![vec![0.0f32; dim]; n_classes];
    for mean in means.iter_mut() {
        for v in mean.iter_mut() {
            if structure_rng.bernoulli(0.12) {
                *v = structure_rng.gauss() as f32;
            }
        }
    }
    let mut data = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % n_classes; // balanced classes, like MNIST/CIFAR
        labels.push(c as i32);
        let mean = &means[c];
        for &mu in mean.iter() {
            let x = mu as f64 + noise * rng.gauss();
            // squash towards [0,1] like normalized pixels
            data.push((0.5 + 0.25 * x) as f32);
        }
    }
    // Shuffle example order once so classes are not strided (the paper's
    // datasets come pre-shuffled on disk; ordering policies must not be
    // able to exploit generator striding).
    let perm = rng.permutation(n);
    let mut sdata = Vec::with_capacity(n * dim);
    let mut slabels = Vec::with_capacity(n);
    for &p in &perm {
        sdata.extend_from_slice(&data[p * dim..(p + 1) * dim]);
        slabels.push(labels[p]);
    }
    Dataset::new(name, Features::F32 { data: sdata, dim },
                 Labels::Scalar(slabels))
        .expect("generator invariant")
}

/// GLUE stand-in (SST-2/QNLI shaped): binary classification of token
/// sequences. Two "topics" share a common vocabulary but differ in the
/// occurrence rates of a subset of indicator tokens — solvable by a
/// transformer via pooled attention, not by any single position.
pub fn glue_like(n: usize, seq: usize, vocab: usize, seed: u64) -> Dataset {
    // Same structure/sample seed split as gaussian_mixture.
    let mut structure_rng = Rng::new((seed & 0xFFFF) ^ 0x61_u64);
    let mut rng = Rng::new(seed ^ 0x161_u64);
    // Topic-specific token weights.
    let mut w0 = vec![1.0f64; vocab];
    let mut w1 = vec![1.0f64; vocab];
    for t in 0..vocab {
        if structure_rng.bernoulli(0.25) {
            w0[t] = 2.0;
        }
        if structure_rng.bernoulli(0.25) {
            w1[t] = 2.0;
        }
    }
    let mut data = Vec::with_capacity(n * seq);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = (i % 2) as i32;
        labels.push(c);
        let w = if c == 0 { &w0 } else { &w1 };
        for _ in 0..seq {
            data.push(rng.categorical(w) as i32);
        }
    }
    let perm = rng.permutation(n);
    let mut sdata = Vec::with_capacity(n * seq);
    let mut slabels = Vec::with_capacity(n);
    for &p in &perm {
        sdata.extend_from_slice(&data[p * seq..(p + 1) * seq]);
        slabels.push(labels[p]);
    }
    Dataset::new("glue_like", Features::I32 { data: sdata, dim: seq },
                 Labels::Scalar(slabels))
        .expect("generator invariant")
}

/// Failure injection: flip a fraction of scalar labels uniformly at
/// random (robustness experiments; herding still works, the loss floor
/// rises). No-op on sequence-labelled datasets.
pub fn inject_label_noise(ds: &mut Dataset, frac: f64, seed: u64) -> usize {
    let Labels::Scalar(labels) = &mut ds.y else {
        return 0;
    };
    let n_classes = 1 + labels.iter().copied().max().unwrap_or(0) as u64;
    let mut rng = Rng::new(seed ^ 0x4015E);
    let mut flipped = 0;
    for l in labels.iter_mut() {
        if rng.bernoulli(frac) {
            let new = rng.gen_range(n_classes) as i32;
            if new != *l {
                *l = new;
                flipped += 1;
            }
        }
    }
    flipped
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_geometry() {
        let d = mnist_like(64, 0);
        assert_eq!(d.len(), 64);
        assert_eq!(d.x.dim(), 784);
        let counts = d.class_counts(10);
        assert_eq!(counts.iter().sum::<usize>(), 64);
        // Balanced by construction (n % 10 spill only).
        assert!(counts.iter().all(|&c| (6..=7).contains(&c)));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = mnist_like(16, 7);
        let b = mnist_like(16, 7);
        let (Features::F32 { data: da, .. }, Features::F32 { data: db, .. }) =
            (&a.x, &b.x)
        else {
            panic!()
        };
        assert_eq!(da, db);
        let c = mnist_like(16, 8);
        let Features::F32 { data: dc, .. } = &c.x else { panic!() };
        assert_ne!(da, dc);
    }

    #[test]
    fn classes_are_separable_on_average() {
        // Mean feature vectors of two classes should differ measurably.
        let d = mnist_like(200, 3);
        let Features::F32 { data, dim } = &d.x else { panic!() };
        let Labels::Scalar(ys) = &d.y else { panic!() };
        let mut m0 = vec![0.0f64; *dim];
        let mut m1 = vec![0.0f64; *dim];
        let (mut n0, mut n1) = (0, 0);
        for i in 0..d.len() {
            let row = &data[i * dim..(i + 1) * dim];
            if ys[i] == 0 {
                n0 += 1;
                for (m, x) in m0.iter_mut().zip(row) {
                    *m += *x as f64;
                }
            } else if ys[i] == 1 {
                n1 += 1;
                for (m, x) in m1.iter_mut().zip(row) {
                    *m += *x as f64;
                }
            }
        }
        let dist: f64 = m0
            .iter()
            .zip(&m1)
            .map(|(a, b)| {
                let v = a / n0 as f64 - b / n1 as f64;
                v * v
            })
            .sum::<f64>()
            .sqrt();
        assert!(dist > 0.5, "class means too close: {dist}");
    }

    #[test]
    fn glue_like_tokens_in_vocab() {
        let d = glue_like(32, 32, 64, 0);
        assert_eq!(d.len(), 32);
        let Features::I32 { data, .. } = &d.x else { panic!() };
        assert!(data.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(d.class_counts(2).iter().sum::<usize>(), 32);
    }

    #[test]
    fn label_noise_flips_requested_fraction() {
        let mut d = mnist_like(1000, 0);
        let before = match &d.y {
            Labels::Scalar(v) => v.clone(),
            _ => panic!(),
        };
        let flipped = inject_label_noise(&mut d, 0.2, 1);
        let after = match &d.y {
            Labels::Scalar(v) => v.clone(),
            _ => panic!(),
        };
        let changed =
            before.iter().zip(&after).filter(|(a, b)| a != b).count();
        assert_eq!(changed, flipped);
        // ~20% * (9/10 actually change)
        assert!((100..=260).contains(&changed), "changed={changed}");
    }

    #[test]
    fn cifar_like_dims() {
        let d = cifar_like(10, 0);
        assert_eq!(d.x.dim(), 3072);
    }
}
