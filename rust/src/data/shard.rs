//! Deterministic sharding of an epoch's microbatch stream across pipeline
//! workers. Contiguous sharding preserves the *visit order semantics* GraB
//! needs (the balancer is inherently sequential), so shards split work at
//! the microbatch level for the grad stage while the balance stage consumes
//! results strictly in epoch order (reassembled by sequence number).

/// Assignment of microbatch sequence numbers to `workers` grad workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of grad workers.
    pub workers: usize,
    /// Microbatches in the epoch being sharded.
    pub num_batches: usize,
}

impl ShardPlan {
    /// A plan for `num_batches` microbatches over `workers` workers.
    pub fn new(workers: usize, num_batches: usize) -> ShardPlan {
        assert!(workers > 0);
        ShardPlan { workers, num_batches }
    }

    /// Worker that owns microbatch `seq` (round-robin keeps per-worker
    /// latency balanced even when batch cost varies slowly over the epoch).
    pub fn owner(&self, seq: usize) -> usize {
        seq % self.workers
    }

    /// All sequence numbers owned by `worker`, in order.
    pub fn owned(&self, worker: usize) -> Vec<usize> {
        (0..self.num_batches)
            .filter(|s| self.owner(*s) == worker)
            .collect()
    }

    /// Per-worker load (number of microbatches).
    pub fn loads(&self) -> Vec<usize> {
        let mut l = vec![0usize; self.workers];
        for s in 0..self.num_batches {
            l[self.owner(s)] += 1;
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_batch_owned_exactly_once() {
        let plan = ShardPlan::new(3, 10);
        let mut seen = vec![0usize; 10];
        for w in 0..3 {
            for s in plan.owned(w) {
                seen[s] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn loads_balanced_within_one() {
        let plan = ShardPlan::new(4, 11);
        let loads = plan.loads();
        let min = loads.iter().min().unwrap();
        let max = loads.iter().max().unwrap();
        assert!(max - min <= 1, "{loads:?}");
        assert_eq!(loads.iter().sum::<usize>(), 11);
    }

    #[test]
    fn single_worker_owns_all() {
        let plan = ShardPlan::new(1, 5);
        assert_eq!(plan.owned(0), vec![0, 1, 2, 3, 4]);
    }
}
