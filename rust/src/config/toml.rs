//! TOML-subset parser for run config files.
//!
//! Supported grammar (sufficient for flat run configs; nested tables are
//! flattened with dotted keys):
//!
//! ```toml
//! # comment
//! task = "mnist"          # strings
//! epochs = 5              # integers
//! lr = 0.1                # floats
//! pipeline = true         # booleans
//! dims = [16, 128, 1024]  # homogeneous scalar arrays
//! [optimizer]             # section -> "optimizer.lr" etc.
//! lr = 0.5
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A double-quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A homogeneous scalar array.
    Arr(Vec<TomlValue>),
}

/// Flattened document: dotted-key -> value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a TOML-subset document (see the module docs for grammar).
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').with_context(|| {
                format!("line {}: expected key = value", lineno + 1)
            })?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let v = parse_value(value.trim()).with_context(|| {
                format!("line {}: bad value for {key:?}", lineno + 1)
            })?;
            if doc.map.insert(full.clone(), v).is_some() {
                bail!("line {}: duplicate key {full:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    /// Read + parse a config file.
    pub fn from_file(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        TomlDoc::parse(&text)
    }

    /// Raw value lookup by (dotted) key.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    /// String value at `key`, if present and a string.
    pub fn get_str(&self, key: &str) -> Option<String> {
        match self.map.get(key) {
            Some(TomlValue::Str(s)) => Some(s.clone()),
            _ => None,
        }
    }

    /// Integer value at `key`, if present and an integer.
    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.map.get(key) {
            Some(TomlValue::Int(v)) => Some(*v),
            _ => None,
        }
    }

    /// Float value at `key` (integers coerce), if present.
    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.map.get(key) {
            Some(TomlValue::Float(v)) => Some(*v),
            Some(TomlValue::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    /// Boolean value at `key`, if present and a boolean.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.map.get(key) {
            Some(TomlValue::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    /// All (dotted) keys in the document, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = text.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .context("unterminated array")?
            .trim();
        if body.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(body)?;
        return Ok(TomlValue::Arr(
            items
                .iter()
                .map(|s| parse_value(s.trim()))
                .collect::<Result<Vec<_>>>()?,
        ));
    }
    if let Some(body) = text.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        return Ok(TomlValue::Str(
            body.replace("\\\"", "\"").replace("\\\\", "\\"),
        ));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(v) = text.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = text.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value {text:?}")
}

fn split_top_level(body: &str) -> Result<Vec<String>> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).context("unbalanced ]")?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                items.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_doc() {
        let doc = TomlDoc::parse(
            r#"
# run config
task = "mnist"
epochs = 5
lr = 0.1
pipeline = true
dims = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("task").unwrap(), "mnist");
        assert_eq!(doc.get_int("epochs").unwrap(), 5);
        assert_eq!(doc.get_float("lr").unwrap(), 0.1);
        assert_eq!(doc.get_bool("pipeline").unwrap(), true);
        assert_eq!(
            doc.get("dims").unwrap(),
            &TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ])
        );
    }

    #[test]
    fn sections_flatten() {
        let doc = TomlDoc::parse("[optim]\nlr = 0.5\n").unwrap();
        assert_eq!(doc.get_float("optim.lr").unwrap(), 0.5);
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc =
            TomlDoc::parse("name = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get_str("name").unwrap(), "a#b");
    }

    #[test]
    fn int_float_coercion() {
        let doc = TomlDoc::parse("x = 3\n").unwrap();
        assert_eq!(doc.get_float("x").unwrap(), 3.0);
        assert_eq!(doc.get_int("x").unwrap(), 3);
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
        assert!(TomlDoc::parse("a 1\n").is_err());
        assert!(TomlDoc::parse("a = [1, 2\n").is_err());
    }
}
