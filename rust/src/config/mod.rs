//! Configuration system: a TOML-subset parser plus the typed, validated
//! configs every run is launched from. A config can come from a file
//! (`--config runs/mnist.toml`), from CLI flags, or file-then-flag overlay
//! (flags win), and every completed run re-serializes its effective config
//! next to its metrics so results are reproducible.

mod toml;

pub use toml::TomlDoc;

use anyhow::{bail, Result};

use crate::util::cli::Args;

/// Which paper task (dataset + model pairing) to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Logistic regression on the MNIST-like mixture (Fig. 2a).
    Mnist,
    /// LeNet on the CIFAR-like mixture (Fig. 2b).
    Cifar,
    /// LSTM LM on the Markov character corpus (Fig. 2c).
    Wiki,
    /// Tiny transformer on the GLUE-like task (Fig. 2d).
    Glue,
}

impl Task {
    /// Parse a task name as accepted by `--task`.
    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s {
            "mnist" => Task::Mnist,
            "cifar" => Task::Cifar,
            "wiki" | "wikitext" => Task::Wiki,
            "glue" => Task::Glue,
            _ => bail!("unknown task {s:?} (mnist|cifar|wiki|glue)"),
        })
    }

    /// Canonical task name (round-trips through [`Task::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Task::Mnist => "mnist",
            Task::Cifar => "cifar",
            Task::Wiki => "wiki",
            Task::Glue => "glue",
        }
    }

    /// L2 model artifact family for this task.
    pub fn model_name(&self) -> &'static str {
        match self {
            Task::Mnist => "logreg",
            Task::Cifar => "lenet",
            Task::Wiki => "lstm",
            Task::Glue => "transformer",
        }
    }
}

/// Example-ordering policy selector (paper Section 6 baselines + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingKind {
    /// A fresh uniform permutation every epoch (the paper's baseline).
    RandomReshuffle,
    /// One random permutation reused every epoch.
    ShuffleOnce,
    /// Rajput et al. 2021: reshuffle on even epochs, replay reversed on
    /// odd epochs.
    FlipFlop,
    /// Greedy herding over stored stale gradients (paper Section 3).
    GreedyOrdering,
    /// The paper's GraB: stale-mean-centered online balancing.
    GraB,
    /// Fig. 3: GraB for one epoch, then freeze the found order.
    OneStepGraB,
    /// Fig. 3: fixed order imported from a finished GraB run's final epoch.
    RetrainFromGraB,
    /// CD-GraB's PairBalance: balance consecutive pair differences — no
    /// stale mean, one d-vector of state.
    PairBalance,
    /// CD-GraB: `num_shards` PairBalance workers over disjoint unit
    /// ranges with a round-robin coordinator merge.
    ShardedPairBalance,
    /// Streaming pair balancing over a bounded sliding reservoir of
    /// live examples (`ordering::StreamOrder`): units are admitted and
    /// retired at window boundaries instead of swept in fixed epochs.
    /// In the synchronous trainer the reservoir spans the whole
    /// dataset (one window per epoch ≡ PairBalance, determinism
    /// contract 9); sliding windows run through `grab exp stream` and
    /// daemon stream jobs. See docs/streaming.md.
    Stream,
    /// Plain in-order pass (sanity baseline; not in the paper's plots).
    Sequential,
}

impl OrderingKind {
    /// Parse an ordering name as accepted by `--ordering`.
    pub fn parse(s: &str) -> Result<OrderingKind> {
        Ok(match s {
            "rr" | "random-reshuffle" => OrderingKind::RandomReshuffle,
            "so" | "shuffle-once" => OrderingKind::ShuffleOnce,
            "flipflop" => OrderingKind::FlipFlop,
            "greedy" | "greedy-ordering" => OrderingKind::GreedyOrdering,
            "grab" => OrderingKind::GraB,
            "grab-1step" | "onestep-grab" => OrderingKind::OneStepGraB,
            "grab-retrain" | "retrain-from-grab" => {
                OrderingKind::RetrainFromGraB
            }
            "pair" | "pair-balance" | "pairbalance" => {
                OrderingKind::PairBalance
            }
            "cd-grab" | "cdgrab" | "sharded-pair" => {
                OrderingKind::ShardedPairBalance
            }
            "stream" | "stream-pair" => OrderingKind::Stream,
            "seq" | "sequential" => OrderingKind::Sequential,
            _ => bail!(
                "unknown ordering {s:?} (rr|so|flipflop|greedy|grab|\
                 grab-1step|grab-retrain|pair|cd-grab|stream|seq)"
            ),
        })
    }

    /// Canonical name (round-trips through [`OrderingKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            OrderingKind::RandomReshuffle => "rr",
            OrderingKind::ShuffleOnce => "so",
            OrderingKind::FlipFlop => "flipflop",
            OrderingKind::GreedyOrdering => "greedy",
            OrderingKind::GraB => "grab",
            OrderingKind::OneStepGraB => "grab-1step",
            OrderingKind::RetrainFromGraB => "grab-retrain",
            OrderingKind::PairBalance => "pair",
            OrderingKind::ShardedPairBalance => "cd-grab",
            OrderingKind::Stream => "stream",
            OrderingKind::Sequential => "seq",
        }
    }
}

/// Balancing subroutine for GraB (paper Algorithm 5 vs Algorithm 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalancerKind {
    /// Algorithm 5: deterministic, normalization-invariant (paper default).
    Deterministic,
    /// Algorithm 6: Alweiss et al. self-balancing walk, needs `c`.
    Walk,
    /// The Pallas/HLO balance artifact executed via PJRT (layer ablation).
    Kernel,
}

impl BalancerKind {
    /// Parse a balancer name as accepted by `--balancer`.
    pub fn parse(s: &str) -> Result<BalancerKind> {
        Ok(match s {
            "deterministic" | "alg5" => BalancerKind::Deterministic,
            "walk" | "alg6" => BalancerKind::Walk,
            "kernel" | "pallas" => BalancerKind::Kernel,
            _ => bail!("unknown balancer {s:?} (alg5|alg6|kernel)"),
        })
    }

    /// Canonical name (round-trips through [`BalancerKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            BalancerKind::Deterministic => "alg5",
            BalancerKind::Walk => "alg6",
            BalancerKind::Kernel => "kernel",
        }
    }
}

/// Order-exchange transport between the CD-GraB coordinator and its
/// shard balancers (only meaningful with
/// [`OrderingKind::ShardedPairBalance`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process: inline dispatch, or worker threads behind bounded
    /// mpsc block queues when `async_shards` is set (the default).
    Channel,
    /// Sockets: shard balancers behind checksummed length-prefixed
    /// frames over TCP — in-process loopback workers by default, or a
    /// remote worker server when `connect` names an address. Implies
    /// the async (transported) coordinator.
    Tcp,
}

impl TransportKind {
    /// Parse a transport name as accepted by `--transport`.
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "channel" | "mpsc" => TransportKind::Channel,
            "tcp" | "socket" => TransportKind::Tcp,
            _ => bail!("unknown transport {s:?} (channel|tcp)"),
        })
    }

    /// Canonical name (round-trips through [`TransportKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Balance-kernel dispatch tier for the L3 hot path (`--kernels`).
/// Every tier produces **bit-identical** epoch orders — determinism
/// contract 7 in `docs/determinism.md`; the only difference is
/// wall-clock (`docs/perf.md`, `BENCH_*.json`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Probe the host once and pick the best tier — `simd+par` when
    /// AVX2 is available, `scalar` otherwise (the default).
    Auto,
    /// Portable scalar kernels (the reference tier).
    Scalar,
    /// AVX2 kernels on the caller's thread.
    Simd,
    /// AVX2 kernels plus the row-parallel worker pool.
    SimdPar,
}

impl KernelKind {
    /// Parse a kernel tier as accepted by `--kernels`.
    pub fn parse(s: &str) -> Result<KernelKind> {
        Ok(match s {
            "auto" => KernelKind::Auto,
            "scalar" => KernelKind::Scalar,
            "simd" => KernelKind::Simd,
            "simd+par" | "simd-par" => KernelKind::SimdPar,
            _ => bail!(
                "unknown kernel tier {s:?} \
                 (auto|scalar|simd|simd+par)"
            ),
        })
    }

    /// Canonical name (round-trips through [`KernelKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
            KernelKind::SimdPar => "simd+par",
        }
    }

    /// Resolve to the tensor layer's dispatch tier (`Auto` probes the
    /// host via [`crate::tensor::Kernel::auto`]).
    pub fn resolve(&self) -> crate::tensor::Kernel {
        match self {
            KernelKind::Auto => crate::tensor::Kernel::auto(),
            KernelKind::Scalar => crate::tensor::Kernel::Scalar,
            KernelKind::Simd => crate::tensor::Kernel::Simd,
            KernelKind::SimdPar => crate::tensor::Kernel::SimdPar,
        }
    }
}

/// LR schedule selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// Fixed learning rate for the whole run.
    Constant,
    /// Multiply by `factor` when the epoch train loss fails to improve by
    /// `threshold` for `patience` epochs (paper's WikiText-2 recipe).
    ReduceOnPlateau {
        factor: f64,
        patience: usize,
        threshold: f64,
    },
}

/// A fully-specified training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Dataset + model pairing.
    pub task: Task,
    /// Example-ordering policy.
    pub ordering: OrderingKind,
    /// Balancing subroutine used by GraB-family orderings.
    pub balancer: BalancerKind,
    /// Number of training epochs.
    pub epochs: usize,
    /// Dataset size (number of ordering units). Paper-scale defaults are
    /// large; experiments shrink this for CI-speed runs.
    pub n_examples: usize,
    /// Eval dataset size.
    pub n_eval: usize,
    /// Optimizer step batch = microbatch (artifact B) * accum_steps.
    pub accum_steps: usize,
    /// Base learning rate.
    pub lr: f64,
    /// SGD momentum coefficient in `[0, 1)`.
    pub momentum: f64,
    /// Decoupled weight decay coefficient.
    pub weight_decay: f64,
    /// Learning-rate schedule.
    pub lr_schedule: LrSchedule,
    /// Seed for every stochastic component of the run.
    pub seed: u64,
    /// Walk balancer hyperparameter (Theorem 4's c); 0 = auto.
    pub walk_c: f64,
    /// Ordering granularity: units per group (1 = per-example ordering;
    /// >1 reorders groups, the paper's batch-granularity fallback).
    pub group_size: usize,
    /// Shard count for [`OrderingKind::ShardedPairBalance`] (CD-GraB
    /// workers); ignored by other orderings.
    pub num_shards: usize,
    /// Pinned integer shard weights for a *weighted* (uneven) CD-GraB
    /// topology (`--weights 1,1,4`, TOML `weights = "1,1,4"`): shard
    /// sizes are apportioned proportionally
    /// (`ordering::topology::split_units_weighted`). Must have
    /// `num_shards` entries; `None` = equal weights. Replaying a
    /// recorded elastic run pins its logged weights here.
    pub shard_weights: Option<Vec<u64>>,
    /// Elastic shard topology (`--elastic`, TOML `elastic = true`): at
    /// each epoch boundary the coordinator re-derives weights from
    /// measured per-link cost (EWMA, quantized, with hysteresis) and
    /// re-plans — re-split + fresh links (a fresh TCP `Hello` per
    /// shard) — when the skew is sustained or a worker link was lost
    /// mid-epoch. The per-epoch topology is recorded in
    /// `TrainResult::topology` (and the `exp cdgrab` CSV) so the run
    /// replays from its logged weights; frozen weights are
    /// bit-identical to the static topology (docs/determinism.md
    /// contract 6). Needs a transported backend (`--async-shards` or
    /// `--transport tcp`).
    pub elastic: bool,
    /// Run each CD-GraB shard balancer on its own worker thread behind a
    /// bounded block queue (`--async-shards`); the trainer's
    /// `observe_block` becomes gather + enqueue and the epoch-boundary
    /// merge is the only join. Bit-deterministic: epoch orders equal the
    /// synchronous path's exactly (see docs/determinism.md). Ignored by
    /// orderings other than [`OrderingKind::ShardedPairBalance`].
    pub async_shards: bool,
    /// Per-shard block-queue depth for `--async-shards`: the maximum
    /// number of in-flight gathered blocks per worker (also its scratch
    /// allocation budget). Deeper queues absorb burstier producers at
    /// the cost of `depth` gathered blocks per shard — each up to the
    /// shard's rows-per-microbatch × d floats.
    pub shard_queue_depth: usize,
    /// Order-exchange transport for the CD-GraB coordinator
    /// (`--transport channel|tcp`). `tcp` runs every shard balancer
    /// behind the socket wire protocol — against in-process loopback
    /// workers, or against a remote worker server when
    /// [`TrainConfig::connect`] is set. Bit-deterministic: every
    /// transport produces the same epoch orders (docs/determinism.md
    /// contract 5). Ignored by orderings other than
    /// [`OrderingKind::ShardedPairBalance`].
    pub shard_transport: TransportKind,
    /// Streaming reservoir capacity in units (`--window N`, TOML
    /// `stream_window`), for [`OrderingKind::Stream`]: the bound on
    /// how many live examples the sliding reservoir balances at once.
    /// `0` (the default) sizes the reservoir to the whole dataset.
    /// The synchronous trainer sweeps every example each epoch, so it
    /// requires `0` or a capacity ≥ `n_examples`; smaller sliding
    /// windows run through `grab exp stream` and daemon stream jobs
    /// (see docs/streaming.md).
    pub stream_window: usize,
    /// Fresh units admitted per window (`--admit-rate R`, TOML
    /// `stream_admit_rate`), for [`OrderingKind::Stream`] streaming
    /// runs: each boundary admits `R` new examples and FIFO-evicts the
    /// oldest once the reservoir is full. `0` (the default) freezes
    /// the membership — the static schedule that reproduces
    /// PairBalance bit-for-bit (determinism contract 9).
    pub stream_admit_rate: usize,
    /// Balance-kernel dispatch tier
    /// (`--kernels auto|scalar|simd|simd+par`), installed as the
    /// process-wide default before policies are built. Every tier is
    /// bit-identical (docs/determinism.md contract 7); pin `scalar`
    /// to cross-check a result, `simd`/`simd+par` to force the fast
    /// tiers on (see docs/perf.md).
    pub kernels: KernelKind,
    /// Address of a remote shard worker server (`--connect HOST:PORT`,
    /// started with `grab exp cdgrab --listen HOST:PORT`). Requires
    /// `shard_transport = tcp`.
    pub connect: Option<String>,
    /// Upper bound (seconds) on waiting for any single frame from a
    /// TCP shard worker (`--read-timeout SECS`, TOML
    /// `read_timeout_secs`). An expiry surfaces as a typed link
    /// `Timeout` at the epoch boundary — the signal an elastic run
    /// re-plans around. Not part of the config fingerprint: it is an
    /// operational knob, like `epochs`, with no bearing on the orders
    /// produced.
    pub read_timeout_secs: u64,
    /// Where artifacts live.
    pub artifacts_dir: String,
    /// Optional metrics CSV path.
    pub metrics_out: Option<String>,
    /// Evaluate every k epochs, plus always on the final epoch
    /// (0 = never evaluate).
    pub eval_every: usize,
    /// Run the threaded streaming pipeline instead of the sync loop.
    pub use_pipeline: bool,
    /// Grad-stage workers for the pipeline (each owns its own PJRT
    /// client); 1 = single worker.
    pub workers: usize,
    /// Clip the accumulated gradient to this global l2 norm before the
    /// optimizer step (0 = off). Matches standard practice for the CNN and
    /// the PyTorch LM recipe the paper's WikiText-2 setup follows.
    pub clip_norm: f64,
    /// Durable run directory (`--checkpoint-dir DIR`): the trainer
    /// writes a JSON manifest (schema version, config fingerprint,
    /// policy, kernel tier, git rev) plus per-epoch CRC-framed
    /// snapshots there, atomically, keeping the newest few. `None`
    /// (the default) disables checkpointing. See
    /// docs/determinism.md contract 8.
    pub checkpoint_dir: Option<String>,
    /// Snapshot cadence in epochs (`--checkpoint-every N`, default 1):
    /// a snapshot lands after every N-th epoch and always after the
    /// final one. Only meaningful with [`TrainConfig::checkpoint_dir`].
    pub checkpoint_every: usize,
    /// Resume from the newest snapshot in
    /// [`TrainConfig::checkpoint_dir`] (`--resume`): the manifest's
    /// config fingerprint must match this config's (typed
    /// `FingerprintMismatch` otherwise), the policy is reconstructed
    /// from config and re-seeded from its saved epoch-boundary state,
    /// and training continues at the snapshot's epoch + 1 —
    /// bit-identical to the uninterrupted run (contract 8).
    pub resume: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            task: Task::Mnist,
            ordering: OrderingKind::GraB,
            balancer: BalancerKind::Deterministic,
            epochs: 5,
            n_examples: 4096,
            n_eval: 1024,
            accum_steps: 1,
            lr: 0.01,
            momentum: 0.9,
            weight_decay: 1e-4,
            lr_schedule: LrSchedule::Constant,
            seed: 0,
            walk_c: 0.0,
            group_size: 1,
            num_shards: 1,
            shard_weights: None,
            elastic: false,
            async_shards: false,
            shard_queue_depth: 4,
            shard_transport: TransportKind::Channel,
            stream_window: 0,
            stream_admit_rate: 0,
            kernels: KernelKind::Auto,
            connect: None,
            read_timeout_secs:
                crate::ordering::transport::tcp::DEFAULT_READ_TIMEOUT_SECS,
            artifacts_dir: "artifacts".to_string(),
            metrics_out: None,
            eval_every: 1,
            use_pipeline: false,
            workers: 1,
            clip_norm: 0.0,
            checkpoint_dir: None,
            checkpoint_every: 1,
            resume: false,
        }
    }
}

impl TrainConfig {
    /// Paper-matched hyperparameters per task (Appendix A), adapted to this
    /// testbed's synthetic datasets.
    pub fn for_task(task: Task) -> TrainConfig {
        let mut c = TrainConfig { task, ..TrainConfig::default() };
        match task {
            Task::Mnist => {
                c.lr = 0.1; // paper sweep best for logreg
                c.accum_steps = 1;
                c.weight_decay = 1e-4;
            }
            Task::Cifar => {
                c.lr = 0.05;
                c.accum_steps = 1;
                c.weight_decay = 1e-4;
                c.clip_norm = 5.0; // LeNet spikes post-convergence
            }
            Task::Wiki => {
                c.lr = 1.0; // paper uses 5 with ReduceLROnPlateau
                c.lr_schedule = LrSchedule::ReduceOnPlateau {
                    factor: 0.1,
                    patience: 5,
                    threshold: 0.05,
                };
                c.weight_decay = 0.0;
                c.clip_norm = 0.25; // pytorch word_language_model recipe
            }
            Task::Glue => {
                c.lr = 0.005;
                c.weight_decay = 0.01;
            }
        }
        c
    }

    /// Overlay CLI flags onto this config.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(t) = args.opt_str("task") {
            *self = TrainConfig {
                metrics_out: self.metrics_out.clone(),
                artifacts_dir: self.artifacts_dir.clone(),
                checkpoint_dir: self.checkpoint_dir.clone(),
                checkpoint_every: self.checkpoint_every,
                resume: self.resume,
                ..TrainConfig::for_task(Task::parse(&t)?)
            };
        }
        if let Some(o) = args.opt_str("ordering") {
            self.ordering = OrderingKind::parse(&o)?;
        }
        if let Some(b) = args.opt_str("balancer") {
            self.balancer = BalancerKind::parse(&b)?;
        }
        self.epochs = args.usize_or("epochs", self.epochs)?;
        self.n_examples = args.usize_or("n", self.n_examples)?;
        self.n_eval = args.usize_or("n-eval", self.n_eval)?;
        self.accum_steps = args.usize_or("accum", self.accum_steps)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.momentum = args.f64_or("momentum", self.momentum)?;
        self.weight_decay = args.f64_or("wd", self.weight_decay)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.walk_c = args.f64_or("walk-c", self.walk_c)?;
        self.group_size = args.usize_or("group-size", self.group_size)?;
        self.num_shards = args.usize_or("shards", self.num_shards)?;
        if let Some(w) = args.opt_str("weights") {
            let weights = crate::ordering::topology::parse_weights(&w)
                .map_err(|e| anyhow::anyhow!("--weights: {e}"))?;
            // `--weights` alone fully determines the shard count.
            if args.opt_str("shards").is_none() {
                self.num_shards = weights.len();
            }
            self.shard_weights = Some(weights);
        }
        // `--async-shards <token>` would silently bind the next token as
        // this option's value and leave async mode off; reject that
        // instead of letting the flag be swallowed.
        if args.opt_str("async-shards").is_some() {
            bail!(
                "--async-shards is a boolean flag and takes no value \
                 (put it last or before another --flag)"
            );
        }
        if args.flag("async-shards") {
            self.async_shards = true;
        }
        if args.opt_str("elastic").is_some() {
            bail!(
                "--elastic is a boolean flag and takes no value \
                 (put it last or before another --flag)"
            );
        }
        if args.flag("elastic") {
            self.elastic = true;
        }
        self.shard_queue_depth =
            args.usize_or("queue-depth", self.shard_queue_depth)?;
        if let Some(t) = args.opt_str("transport") {
            self.shard_transport = TransportKind::parse(&t)?;
        }
        if args.opt_str("stream").is_some() {
            bail!(
                "--stream is a boolean flag and takes no value \
                 (put it last or before another --flag)"
            );
        }
        if args.flag("stream") {
            // Sugar for `--ordering stream`; an explicit contradictory
            // `--ordering` is a config error, not a silent override.
            if args.opt_str("ordering").is_some()
                && self.ordering != OrderingKind::Stream
            {
                bail!(
                    "--stream conflicts with --ordering {} \
                     (--stream means --ordering stream)",
                    self.ordering.name()
                );
            }
            self.ordering = OrderingKind::Stream;
        }
        self.stream_window =
            args.usize_or("window", self.stream_window)?;
        self.stream_admit_rate =
            args.usize_or("admit-rate", self.stream_admit_rate)?;
        if let Some(k) = args.opt_str("kernels") {
            self.kernels = KernelKind::parse(&k)?;
        }
        if let Some(addr) = args.opt_str("connect") {
            self.connect = Some(addr);
        }
        self.read_timeout_secs = args
            .usize_or("read-timeout", self.read_timeout_secs as usize)?
            as u64;
        self.artifacts_dir =
            args.str_or("artifacts", &self.artifacts_dir);
        if let Some(m) = args.opt_str("metrics-out") {
            self.metrics_out = Some(m);
        }
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        if args.flag("pipeline") {
            self.use_pipeline = true;
        }
        self.workers = args.usize_or("workers", self.workers)?;
        self.clip_norm = args.f64_or("clip", self.clip_norm)?;
        if let Some(dir) = args.opt_str("checkpoint-dir") {
            self.checkpoint_dir = Some(dir);
        }
        self.checkpoint_every =
            args.usize_or("checkpoint-every", self.checkpoint_every)?;
        if args.opt_str("resume").is_some() {
            bail!(
                "--resume is a boolean flag and takes no value \
                 (put it last or before another --flag)"
            );
        }
        if args.flag("resume") {
            self.resume = true;
        }
        self.validate()
    }

    /// Load from a TOML-subset file, then validate.
    pub fn from_toml(doc: &TomlDoc) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        if let Some(t) = doc.get_str("task") {
            c = TrainConfig::for_task(Task::parse(&t)?);
        }
        if let Some(o) = doc.get_str("ordering") {
            c.ordering = OrderingKind::parse(&o)?;
        }
        if let Some(b) = doc.get_str("balancer") {
            c.balancer = BalancerKind::parse(&b)?;
        }
        c.epochs = doc.get_int("epochs").unwrap_or(c.epochs as i64) as usize;
        c.n_examples = doc.get_int("n").unwrap_or(c.n_examples as i64)
            as usize;
        c.n_eval = doc.get_int("n_eval").unwrap_or(c.n_eval as i64) as usize;
        c.accum_steps =
            doc.get_int("accum").unwrap_or(c.accum_steps as i64) as usize;
        c.lr = doc.get_float("lr").unwrap_or(c.lr);
        c.momentum = doc.get_float("momentum").unwrap_or(c.momentum);
        c.weight_decay = doc.get_float("weight_decay")
            .unwrap_or(c.weight_decay);
        c.seed = doc.get_int("seed").unwrap_or(c.seed as i64) as u64;
        c.walk_c = doc.get_float("walk_c").unwrap_or(c.walk_c);
        // Guard the `as usize` conversions: a negative TOML value must
        // error, not wrap to ~2^64 (which would hang allocation).
        let shards = doc
            .get_int("num_shards")
            .unwrap_or(c.num_shards as i64);
        if shards < 1 {
            bail!("num_shards must be >= 1, got {shards}");
        }
        c.num_shards = shards as usize;
        if let Some(w) = doc.get_str("weights") {
            let weights = crate::ordering::topology::parse_weights(&w)
                .map_err(|e| anyhow::anyhow!("weights: {e}"))?;
            if doc.get_int("num_shards").is_none() {
                c.num_shards = weights.len();
            }
            c.shard_weights = Some(weights);
        }
        c.elastic = doc.get_bool("elastic").unwrap_or(c.elastic);
        c.async_shards =
            doc.get_bool("async_shards").unwrap_or(c.async_shards);
        let depth = doc
            .get_int("shard_queue_depth")
            .unwrap_or(c.shard_queue_depth as i64);
        if depth < 1 {
            bail!("shard_queue_depth must be >= 1, got {depth}");
        }
        c.shard_queue_depth = depth as usize;
        if let Some(t) = doc.get_str("transport") {
            c.shard_transport = TransportKind::parse(&t)?;
        }
        let window = doc
            .get_int("stream_window")
            .unwrap_or(c.stream_window as i64);
        if window < 0 {
            bail!("stream_window must be >= 0, got {window}");
        }
        c.stream_window = window as usize;
        let admit = doc
            .get_int("stream_admit_rate")
            .unwrap_or(c.stream_admit_rate as i64);
        if admit < 0 {
            bail!("stream_admit_rate must be >= 0, got {admit}");
        }
        c.stream_admit_rate = admit as usize;
        if let Some(k) = doc.get_str("kernels") {
            c.kernels = KernelKind::parse(&k)?;
        }
        if let Some(addr) = doc.get_str("connect") {
            c.connect = Some(addr);
        }
        let rt = doc
            .get_int("read_timeout_secs")
            .unwrap_or(c.read_timeout_secs as i64);
        if rt < 1 {
            bail!("read_timeout_secs must be >= 1, got {rt}");
        }
        c.read_timeout_secs = rt as u64;
        if let Some(a) = doc.get_str("artifacts") {
            c.artifacts_dir = a;
        }
        if let Some(m) = doc.get_str("metrics_out") {
            c.metrics_out = Some(m);
        }
        if let Some(dir) = doc.get_str("checkpoint_dir") {
            c.checkpoint_dir = Some(dir);
        }
        let every = doc
            .get_int("checkpoint_every")
            .unwrap_or(c.checkpoint_every as i64);
        if every < 1 {
            bail!("checkpoint_every must be >= 1, got {every}");
        }
        c.checkpoint_every = every as usize;
        c.resume = doc.get_bool("resume").unwrap_or(c.resume);
        c.validate()?;
        Ok(c)
    }

    /// Check cross-field invariants; every config source ends here.
    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("epochs must be >= 1");
        }
        if self.n_examples == 0 {
            bail!("n must be >= 1");
        }
        if self.accum_steps == 0 {
            bail!("accum must be >= 1");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be > 0");
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("momentum must be in [0, 1)");
        }
        if self.weight_decay < 0.0 {
            bail!("weight_decay must be >= 0");
        }
        if self.group_size == 0 {
            bail!("group_size must be >= 1");
        }
        if self.num_shards == 0 {
            bail!("num_shards must be >= 1");
        }
        if self.shard_queue_depth == 0 {
            bail!("shard queue depth must be >= 1");
        }
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.read_timeout_secs == 0 {
            bail!(
                "--read-timeout must be >= 1 second \
                 (a zero timeout would block forever)"
            );
        }
        if self.connect.is_some()
            && self.shard_transport != TransportKind::Tcp
        {
            bail!(
                "--connect requires --transport tcp \
                 (got transport {})",
                self.shard_transport.name()
            );
        }
        if let Some(weights) = &self.shard_weights {
            if weights.len() != self.num_shards {
                bail!(
                    "--weights has {} entries but --shards is {}",
                    weights.len(),
                    self.num_shards
                );
            }
            if weights.iter().all(|&w| w == 0) {
                bail!("--weights must not be all zero");
            }
        }
        if self.elastic
            && self.ordering == OrderingKind::ShardedPairBalance
            && self.shard_transport != TransportKind::Tcp
            && !self.async_shards
        {
            bail!(
                "--elastic needs a transported CD-GraB backend: add \
                 --async-shards or --transport tcp"
            );
        }
        if self.checkpoint_every == 0 {
            bail!("checkpoint-every must be >= 1");
        }
        if self.resume && self.checkpoint_dir.is_none() {
            bail!("--resume needs --checkpoint-dir (the run directory)");
        }
        if self.ordering == OrderingKind::Stream
            && self.stream_window != 0
            && self.stream_window < self.n_examples
        {
            bail!(
                "--window {} is smaller than the dataset (n = {}): the \
                 synchronous trainer sweeps every example each epoch, \
                 so its reservoir must span the dataset. Run a sliding \
                 window through `grab exp stream` or a daemon stream \
                 job instead (docs/streaming.md)",
                self.stream_window,
                self.n_examples
            );
        }
        if self.stream_window != 0
            && self.ordering != OrderingKind::Stream
        {
            bail!(
                "--window requires --stream (got ordering {})",
                self.ordering.name()
            );
        }
        if self.stream_admit_rate != 0 {
            if self.ordering != OrderingKind::Stream {
                bail!(
                    "--admit-rate requires --stream (got ordering {})",
                    self.ordering.name()
                );
            }
            bail!(
                "--admit-rate is only meaningful for sliding-reservoir \
                 runs, and `grab train` sweeps a fixed dataset: drive \
                 membership churn through `grab exp stream --admit-rate` \
                 or a daemon stream job (docs/streaming.md)"
            );
        }
        if self.ordering == OrderingKind::GreedyOrdering {
            // Greedy stores all stale gradients: warn-level sanity bound so
            // a config cannot accidentally demand hundreds of GiB (the
            // paper's OOM failure mode, which exp::table1 measures safely).
            let bytes = self.n_examples as u64 * 4 * 8_000_000;
            let _ = bytes; // size depends on d; hard check in Trainer.
        }
        Ok(())
    }

    /// One-line run identity (used for file names / logs).
    pub fn run_id(&self) -> String {
        format!(
            "{}-{}-{}-e{}-n{}-s{}",
            self.task.name(),
            self.ordering.name(),
            self.balancer.name(),
            self.epochs,
            self.n_examples,
            self.seed
        )
    }

    /// FNV-1a hash of every *result-relevant* field, recorded in a run
    /// directory's manifest; `--resume` refuses a directory whose
    /// fingerprint differs (docs/determinism.md contract 8).
    ///
    /// Deliberately excluded: fields the determinism contracts prove
    /// cannot change the result — the shard transport and async/queue
    /// knobs (contract 5), the kernel tier (contract 7) — plus pure
    /// run infrastructure (artifact/metrics/checkpoint paths, eval
    /// cadence, pipeline workers, and the `resume` flag itself, which
    /// necessarily differs between the writing and resuming run).
    pub fn fingerprint(&self) -> u32 {
        let sched = match self.lr_schedule {
            LrSchedule::Constant => "constant".to_string(),
            LrSchedule::ReduceOnPlateau {
                factor,
                patience,
                threshold,
            } => format!("plateau/{factor}/{patience}/{threshold}"),
        };
        let weights = match &self.shard_weights {
            Some(w) => w
                .iter()
                .map(|x| x.to_string())
                .collect::<Vec<_>>()
                .join(":"),
            None => "equal".to_string(),
        };
        let canon = format!(
            "task={};ordering={};balancer={};epochs={};n={};n_eval={};\
             accum={};lr={};momentum={};wd={};sched={};seed={};\
             walk_c={};group={};shards={};weights={};elastic={};\
             clip={};window={};admit={}",
            self.task.name(),
            self.ordering.name(),
            self.balancer.name(),
            self.epochs,
            self.n_examples,
            self.n_eval,
            self.accum_steps,
            self.lr,
            self.momentum,
            self.weight_decay,
            sched,
            self.seed,
            self.walk_c,
            self.group_size,
            self.num_shards,
            weights,
            self.elastic,
            self.clip_norm,
            self.stream_window,
            self.stream_admit_rate,
        );
        crate::util::ser::fnv1a32(canon.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_roundtrip() {
        for t in [Task::Mnist, Task::Cifar, Task::Wiki, Task::Glue] {
            assert_eq!(Task::parse(t.name()).unwrap(), t);
        }
        assert!(Task::parse("nope").is_err());
    }

    #[test]
    fn ordering_roundtrip() {
        for o in [
            OrderingKind::RandomReshuffle,
            OrderingKind::ShuffleOnce,
            OrderingKind::FlipFlop,
            OrderingKind::GreedyOrdering,
            OrderingKind::GraB,
            OrderingKind::OneStepGraB,
            OrderingKind::RetrainFromGraB,
            OrderingKind::PairBalance,
            OrderingKind::ShardedPairBalance,
            OrderingKind::Stream,
            OrderingKind::Sequential,
        ] {
            assert_eq!(OrderingKind::parse(o.name()).unwrap(), o);
        }
    }

    #[test]
    fn stream_config_plumbs_through() {
        // --stream is sugar for --ordering stream.
        let args = Args::parse(["--stream"]).unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.ordering, OrderingKind::Stream);

        // A window spanning the dataset is accepted…
        let args =
            Args::parse(["--stream", "--window", "4096"]).unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.stream_window, 4096);
        // …a sliding (smaller) window is the exp/daemon drivers' job.
        let args = Args::parse(["--stream", "--window", "64"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        // --window / --admit-rate without --stream are config errors,
        // as is --stream against a contradictory --ordering.
        let args = Args::parse(["--window", "4096"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());
        let args = Args::parse(["--admit-rate", "2"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());
        let args =
            Args::parse(["--stream", "--ordering", "grab"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        // The sync trainer cannot honor membership churn — loud error
        // pointing at the sliding-reservoir drivers.
        let args =
            Args::parse(["--stream", "--admit-rate", "2"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        // TOML forms + negative guards.
        let doc = TomlDoc::parse(
            "ordering = \"stream\"\nstream_window = 4096",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.ordering, OrderingKind::Stream);
        assert_eq!(c.stream_window, 4096);
        let doc = TomlDoc::parse("stream_window = -1").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("stream_admit_rate = -2").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn shard_config_plumbs_through() {
        let args = Args::parse([
            "--ordering", "cd-grab", "--shards", "4",
            "--queue-depth", "8", "--async-shards",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.ordering, OrderingKind::ShardedPairBalance);
        assert_eq!(c.num_shards, 4);
        assert!(c.async_shards);
        assert_eq!(c.shard_queue_depth, 8);
        let mut bad = TrainConfig::default();
        bad.num_shards = 0;
        assert!(bad.validate().is_err());
        let mut bad = TrainConfig::default();
        bad.shard_queue_depth = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn weighted_elastic_config_plumbs_through() {
        let args = Args::parse([
            "--ordering", "cd-grab", "--weights", "1,1,4",
            "--transport", "tcp", "--elastic",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.shard_weights.as_deref(), Some(&[1u64, 1, 4][..]));
        assert_eq!(c.num_shards, 3, "--weights sets the shard count");
        assert!(c.elastic);

        // --weights disagreeing with an explicit --shards is an error.
        let args = Args::parse([
            "--ordering", "cd-grab", "--shards", "2",
            "--weights", "1,1,4",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        // --elastic without a transported backend is an error…
        let args = Args::parse([
            "--ordering", "cd-grab", "--shards", "2", "--elastic",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());
        // …but channel workers (--async-shards) qualify.
        let args = Args::parse([
            "--ordering", "cd-grab", "--shards", "2",
            "--async-shards", "--elastic",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert!(c.elastic && c.async_shards);

        // TOML forms.
        let doc = TomlDoc::parse(
            "ordering = \"cd-grab\"\nweights = \"2:1\"\n\
             elastic = true\ntransport = \"tcp\"",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.shard_weights.as_deref(), Some(&[2u64, 1][..]));
        assert_eq!(c.num_shards, 2);
        assert!(c.elastic);
        let doc = TomlDoc::parse("weights = \"0,0\"").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn transport_config_plumbs_through() {
        for t in [TransportKind::Channel, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(t.name()).unwrap(), t);
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());

        let args = Args::parse([
            "--ordering", "cd-grab", "--shards", "2",
            "--transport", "tcp", "--connect", "127.0.0.1:7070",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.shard_transport, TransportKind::Tcp);
        assert_eq!(c.connect.as_deref(), Some("127.0.0.1:7070"));

        // --connect without --transport tcp is a config error.
        let args =
            Args::parse(["--connect", "127.0.0.1:7070"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        let doc =
            TomlDoc::parse("transport = \"tcp\"\nconnect = \"h:1\"")
                .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.shard_transport, TransportKind::Tcp);
        assert_eq!(c.connect.as_deref(), Some("h:1"));
        let doc = TomlDoc::parse("transport = \"warp\"").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn read_timeout_config_plumbs_through() {
        let c = TrainConfig::default();
        assert_eq!(
            c.read_timeout_secs,
            crate::ordering::transport::tcp::DEFAULT_READ_TIMEOUT_SECS
        );

        let args = Args::parse(["--read-timeout", "5"]).unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.read_timeout_secs, 5);

        // Zero would mean "block forever" — rejected from both sources.
        let args = Args::parse(["--read-timeout", "0"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());
        let doc = TomlDoc::parse("read_timeout_secs = 0").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());

        let doc = TomlDoc::parse("read_timeout_secs = 7").unwrap();
        assert_eq!(
            TrainConfig::from_toml(&doc).unwrap().read_timeout_secs,
            7
        );
    }

    #[test]
    fn kernel_config_plumbs_through() {
        for k in [
            KernelKind::Auto,
            KernelKind::Scalar,
            KernelKind::Simd,
            KernelKind::SimdPar,
        ] {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert!(KernelKind::parse("abacus").is_err());

        let args = Args::parse(["--kernels", "scalar"]).unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.kernels, KernelKind::Scalar);
        assert_eq!(
            c.kernels.resolve(),
            crate::tensor::Kernel::Scalar
        );

        let doc = TomlDoc::parse("kernels = \"simd+par\"").unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.kernels, KernelKind::SimdPar);
        let doc = TomlDoc::parse("kernels = \"avx512\"").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn toml_rejects_negative_shard_values() {
        // Regression: a negative TOML int must error instead of
        // wrapping through `as usize` into an enormous allocation.
        let doc = TomlDoc::parse("num_shards = -1").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("shard_queue_depth = -2").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("shard_queue_depth = 8").unwrap();
        assert_eq!(
            TrainConfig::from_toml(&doc).unwrap().shard_queue_depth,
            8
        );
    }

    #[test]
    fn args_overlay() {
        let args = Args::parse([
            "--task", "cifar", "--ordering", "rr", "--epochs", "3",
            "--lr", "0.2", "--seed", "9",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.task, Task::Cifar);
        assert_eq!(c.ordering, OrderingKind::RandomReshuffle);
        assert_eq!(c.epochs, 3);
        assert_eq!(c.lr, 0.2);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut c = TrainConfig::default();
        c.epochs = 0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.momentum = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn task_defaults_match_paper_shapes() {
        let wiki = TrainConfig::for_task(Task::Wiki);
        assert!(matches!(wiki.lr_schedule,
            LrSchedule::ReduceOnPlateau { .. }));
        let glue = TrainConfig::for_task(Task::Glue);
        assert_eq!(glue.weight_decay, 0.01);
    }

    #[test]
    fn run_id_stable() {
        let c = TrainConfig::default();
        assert_eq!(c.run_id(), "mnist-grab-alg5-e5-n4096-s0");
    }

    #[test]
    fn checkpoint_config_plumbs_through() {
        let args = Args::parse([
            "--checkpoint-dir", "/tmp/run",
            "--checkpoint-every", "2", "--resume",
        ])
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some("/tmp/run"));
        assert_eq!(c.checkpoint_every, 2);
        assert!(c.resume);

        // --resume without a run directory is a config error.
        let args = Args::parse(["--resume"]).unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_args(&args).is_err());

        // Checkpointing through the pipeline trainer is supported:
        // PipelineTrainer snapshots at its epoch barrier (contract 8
        // covers both trainers).
        let args = Args::parse(
            ["--checkpoint-dir", "runs/x", "--pipeline"],
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_args(&args).unwrap();
        assert!(c.use_pipeline);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("runs/x"));

        // TOML forms + cadence guard.
        let doc = TomlDoc::parse(
            "checkpoint_dir = \"runs/a\"\ncheckpoint_every = 3",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some("runs/a"));
        assert_eq!(c.checkpoint_every, 3);
        let doc = TomlDoc::parse("checkpoint_every = 0").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn fingerprint_tracks_result_relevant_fields_only() {
        let a = TrainConfig::default();
        let mut b = TrainConfig::default();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.seed = 1;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut b = TrainConfig::default();
        b.ordering = OrderingKind::PairBalance;
        assert_ne!(a.fingerprint(), b.fingerprint());

        // Contract-5/7-equivalent knobs and run infrastructure must
        // NOT shift the fingerprint — a resume with a different
        // transport or kernel tier is still the same run.
        let mut c = TrainConfig::default();
        c.shard_transport = TransportKind::Tcp;
        c.async_shards = true;
        c.kernels = KernelKind::Scalar;
        c.checkpoint_dir = Some("runs/x".into());
        c.resume = true;
        c.eval_every = 7;
        assert_eq!(a.fingerprint(), c.fingerprint());

        // The streaming reservoir shape is result-relevant.
        let mut s = TrainConfig::default();
        s.stream_window = 8192;
        assert_ne!(a.fingerprint(), s.fingerprint());
    }
}
