//! Lexical Rust scanner for the audit pass.
//!
//! [`scan`] splits a source file into two parallel views with identical
//! line structure: `code`, where every comment and string/char-literal
//! *content* is blanked to spaces, and `comment_lines`, the comment text
//! found on each line. Rules match against `code` so that a forbidden
//! pattern quoted inside a string literal or discussed in a comment
//! (both of which exist in this tree) can never fire, while waiver and
//! `SAFETY:` detection read `comment_lines` only.
//!
//! The scanner is a byte-level state machine handling the Rust surface
//! that matters for blanking: line comments, nested block comments,
//! plain/byte strings with escapes, raw and byte-raw strings with any
//! `#` count, and char literals — disambiguated from lifetimes and loop
//! labels (`'a'` is a literal, `'static` is not) by the "identifier
//! char not followed by a closing quote" rule. It does not need to be a
//! full lexer: anything it cannot classify stays in `code` as-is, which
//! can only ever *add* findings, never hide one.

/// A scanned source file: blanked code plus per-line comment text.
pub(crate) struct Scan {
    /// The source with comment and literal contents replaced by spaces.
    /// Newlines are preserved, so byte offsets map to the original
    /// file's line numbers.
    pub code: String,
    /// `comment_lines[i]` is the comment text on 1-based line `i + 1`
    /// (empty where the line has no comment).
    pub comment_lines: Vec<String>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Scan `src` into its code and comment views.
pub(crate) fn scan(src: &str) -> Scan {
    let b = src.as_bytes();
    let n = b.len();
    let mut code = vec![b' '; n];
    let mut comm = vec![b' '; n];
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        let prev_ident = i > 0 && is_ident(b[i - 1]);

        // Line comment: copy to the comment view through end of line.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                comm[i] = b[i];
                i += 1;
            }
            continue;
        }

        // Block comment, tracking nesting (Rust block comments nest).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 0usize;
            while i < n {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    comm[i] = b[i];
                    comm[i + 1] = b[i + 1];
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    comm[i] = b[i];
                    comm[i + 1] = b[i + 1];
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    comm[i] = b[i];
                    i += 1;
                }
            }
            continue;
        }

        // Raw / byte-raw string: r"..", r#".."#, br".." — blank through
        // the matching `"` + same number of `#`s. The prefix must not
        // continue an identifier (`carry` is not `r"ry"`).
        if !prev_ident && (c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r')) {
            let j = i + if c == b'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j + hashes < n && b[j + hashes] == b'#' {
                hashes += 1;
            }
            if j + hashes < n && b[j + hashes] == b'"' {
                i = j + hashes + 1;
                while i < n {
                    if b[i] == b'"' {
                        let mut h = 0usize;
                        while h < hashes && i + 1 + h < n && b[i + 1 + h] == b'#' {
                            h += 1;
                        }
                        if h == hashes {
                            i += 1 + hashes;
                            break;
                        }
                    }
                    i += 1;
                }
                continue;
            }
        }

        // Byte string / byte char: skip the `b` prefix and handle the
        // quote below exactly like the unprefixed form.
        let mut i2 = i;
        if !prev_ident && c == b'b' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'\'') {
            i2 = i + 1;
        }
        let c = b[i2];

        // Plain string literal with escapes.
        if c == b'"' {
            i = i2 + 1;
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }

        // Char literal vs lifetime/loop label: after `'`, an identifier
        // char NOT followed by a closing `'` is a lifetime (kept as
        // code); otherwise consume a char literal (bounded at end of
        // line so an apostrophe in a malformed spot cannot eat the
        // file).
        if c == b'\'' {
            let nxt = if i2 + 1 < n { b[i2 + 1] } else { 0 };
            let nxt2 = if i2 + 2 < n { b[i2 + 2] } else { 0 };
            if nxt != 0 && nxt != b'\\' && is_ident(nxt) && nxt2 != b'\'' {
                code[i] = b[i];
                i += 1;
                continue;
            }
            i = i2 + 1;
            while i < n && b[i] != b'\n' {
                if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'\'' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }

        code[i] = b[i];
        i += 1;
    }

    // Newlines exist in both views so line numbering is shared.
    for (idx, &ch) in b.iter().enumerate() {
        if ch == b'\n' {
            code[idx] = b'\n';
            comm[idx] = b'\n';
        }
    }

    let code = String::from_utf8_lossy(&code).into_owned();
    let comment_lines = String::from_utf8_lossy(&comm)
        .split('\n')
        .map(str::to_string)
        .collect();
    Scan { code, comment_lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_code_survives() {
        let src = "let x = \"partial_cmp\"; // partial_cmp here\nlet y = 1;\n";
        let s = scan(src);
        assert!(!s.code.contains("partial_cmp"));
        assert!(s.code.contains("let x ="));
        assert!(s.code.contains("let y = 1;"));
        assert!(s.comment_lines[0].contains("partial_cmp"));
        assert_eq!(s.comment_lines[1], "");
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let src = concat!(
            "let a = r#\"unsafe \"quoted\" inside\"#;\n",
            "let b = br\"HashMap\";\n",
            "let c = b\"SystemTime\";\n",
        );
        let s = scan(src);
        assert!(!s.code.contains("unsafe"));
        assert!(!s.code.contains("HashMap"));
        assert!(!s.code.contains("SystemTime"));
        assert_eq!(s.code.matches('\n').count(), 3);
    }

    #[test]
    fn lifetimes_stay_code_char_literals_are_blanked() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\n'static;\n";
        let s = scan(src);
        // Lifetime names survive; the char literal's content does not.
        assert!(s.code.contains("&'a str"));
        assert!(!s.code.contains("'x'"));
        assert!(s.code.contains("'static"));
    }

    #[test]
    fn nested_block_comments_end_where_rust_says() {
        let src = "/* outer /* inner */ still comment */ let z = 3;\n";
        let s = scan(src);
        assert!(!s.code.contains("outer"));
        assert!(!s.code.contains("still comment"));
        assert!(s.code.contains("let z = 3;"));
        assert!(s.comment_lines[0].contains("inner"));
    }

    #[test]
    fn escaped_quotes_do_not_end_literals() {
        let src = "let s = \"a\\\"unsafe\\\"b\"; let t = '\\''; let u = 9;\n";
        let s = scan(src);
        assert!(!s.code.contains("unsafe"));
        assert!(s.code.contains("let u = 9;"));
    }

    #[test]
    fn identifier_ending_in_r_is_not_a_raw_string() {
        let src = "let carry = var\"\"; // `var\"\"` is nonsense but `r` must not bind\n";
        let s = scan(src);
        assert!(s.code.contains("let carry = var"));
    }
}
