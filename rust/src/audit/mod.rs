//! `grab audit` — the repo-native determinism/safety lint pass.
//!
//! A source-level audit over `src/`, `tests/`, and `benches/` enforcing
//! the invariants the determinism contracts (docs/determinism.md)
//! depend on but the type system cannot express: NaN-safe float
//! ordering (D01), no order-randomized containers in result-relevant
//! modules (D02), wall-clock reads only at allowlisted sites (D03), no
//! FMA in the kernel tier (D04), `SAFETY:` justifications on every
//! `unsafe` (S01), and no bare truncating casts in the wire layers
//! (W01). Rules are lexical — [`lex`] blanks comments and string
//! literals first, so quoting a forbidden pattern in a doc comment or a
//! test fixture never trips the pass, and no violation can hide behind
//! failed type inference.
//!
//! Findings print as `path:line: RULE: message` and make the command
//! exit non-zero. A site that genuinely needs an exemption carries an
//! `audit: allow` waiver comment naming the rule and a quoted reason
//! (syntax in docs/audit.md) on its own or the preceding line; the pass
//! re-checks waivers — unknown rules, missing reasons, and waivers that
//! no longer match a finding are violations themselves (rule `A00`).
//!
//! The pass is wired into CI as a gate in front of the test jobs, with
//! Miri and AddressSanitizer jobs covering the dynamic UB classes a
//! lexical pass cannot see (docs/audit.md has the scope table).
//! `tools/audit_mirror.py` is a Python mirror of this module for hosts
//! without a Rust toolchain; the fixture suite in `tests/audit.rs` is
//! the semantics contract keeping the two in sync.

pub(crate) mod lex;
pub(crate) mod rules;

pub use rules::{Rule, RULES};

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::cli::Args;

/// One audit violation at a specific source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule id (`D01`, …, or `A00` for waiver hygiene).
    pub rule: &'static str,
    /// Path relative to the crate root, `/`-separated.
    pub path: String,
    /// 1-based line of the offending token.
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
}

/// The result of auditing a tree.
#[derive(Debug, Default)]
pub struct Report {
    /// Surviving violations, ordered by path then line.
    pub findings: Vec<Finding>,
    /// Findings absorbed by well-formed waivers (kept so callers can
    /// assert waiver policy — the self-audit requires zero S01/D01
    /// waivers on the shipped tree).
    pub waived: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

/// Audit a single file's source text. `rel_path` is the crate-relative
/// `/`-separated path (`src/util/ser.rs`) the per-rule scopes match
/// against. Returns the surviving findings and the waived findings —
/// this is the whole engine; [`run`] just walks the tree and feeds it.
pub fn audit_source(rel_path: &str, source: &str) -> (Vec<Finding>, Vec<Finding>) {
    rules::check_source(rel_path, source)
}

/// Audit every `.rs` file under `<root>/src`, `<root>/tests`, and
/// `<root>/benches`, where `root` is the crate root (the directory
/// holding `Cargo.toml`). Files are visited in sorted path order so
/// output is deterministic.
pub fn run(root: &Path) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        collect_rs(&root.join(sub), &mut files)?;
    }
    files.sort();
    if files.is_empty() {
        bail!(
            "no .rs files under {} (expected a crate root with \
             src/, tests/, benches/)",
            root.display()
        );
    }
    let mut report = Report::default();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .expect("walked paths start at root")
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let source = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let (findings, waived) = audit_source(&rel, &source);
        report.findings.extend(findings);
        report.waived.extend(waived);
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let entries = fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Find the crate root from the current directory: `rust/` when
/// invoked at the repository root, `.` when invoked inside `rust/`.
fn locate_root() -> Result<PathBuf> {
    for candidate in ["rust", "."] {
        let root = PathBuf::from(candidate);
        if root.join("src").is_dir() && root.join("Cargo.toml").is_file() {
            return Ok(root);
        }
    }
    bail!(
        "cannot find the crate root (run from the repository root or \
         rust/, or pass --root DIR)"
    );
}

/// `grab audit` entry point: scan the tree, print findings, exit
/// non-zero on any violation.
///
/// Flags: `--root DIR` (crate root; auto-detected otherwise) and
/// `--list` (print the rule table and exit).
pub fn run_from_cli(args: &Args) -> Result<()> {
    let list = args.flag("list");
    let root = args.opt_str("root").map(PathBuf::from);
    args.reject_unknown()?;

    if list {
        println!("{:<5} {:<45} summary", "rule", "scope");
        for rule in &RULES {
            println!("{:<5} {:<45} {}", rule.id, rule.scope, rule.summary);
        }
        println!(
            "A00   (implicit)                                    \
             waiver hygiene: malformed or stale waivers; not waivable"
        );
        return Ok(());
    }

    let root = match root {
        Some(r) => r,
        None => locate_root()?,
    };
    let report = run(&root)?;
    for f in &report.findings {
        println!("{}/{}:{}: {}: {}", root.display(), f.path, f.line, f.rule, f.message);
    }
    eprintln!(
        "audit: {} violation(s), {} waiver(s) honored, {} file(s) scanned",
        report.findings.len(),
        report.waived.len(),
        report.files_scanned
    );
    if !report.findings.is_empty() {
        bail!("audit failed with {} violation(s)", report.findings.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The fixture matrix (positive/negative/waiver per rule) lives in
    // tests/audit.rs; these unit tests cover the walker and the report
    // plumbing, and run under Miri.

    #[test]
    fn audit_source_reports_crate_relative_path_and_line() {
        let src = "fn f() {\n    let p = std::time::SystemTime::now();\n}\n";
        let (findings, waived) = audit_source("src/train/run.rs", src);
        assert!(waived.is_empty());
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, "D03");
        assert_eq!(findings[0].path, "src/train/run.rs");
        assert_eq!(findings[0].line, 2);
    }

    #[test]
    fn clean_source_is_clean() {
        let src = "/// Doc.\npub fn ok(a: f32, b: f32) -> bool {\n    \
                   a.total_cmp(&b).is_lt()\n}\n";
        let (findings, waived) = audit_source("src/herding/x.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert!(waived.is_empty());
    }

    #[test]
    fn rules_table_is_sorted_and_unique() {
        let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(ids, sorted, "RULES must stay in sorted id order");
        assert_eq!(ids.len(), 6);
    }
}
