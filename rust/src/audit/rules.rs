//! The audit rule set and the per-file checker.
//!
//! Each rule is keyed to a determinism or safety contract
//! (docs/determinism.md, docs/audit.md) and matches *lexically* against
//! the blanked code view from [`super::lex`] — no type information, so
//! a rule can be conservative but never silently misses a site because
//! inference failed. Waivers — `audit: allow` comments naming a rule
//! and a quoted reason (syntax in docs/audit.md) — are parsed from the
//! comment view and cover same-rule findings on their own line and the
//! next; malformed or unused waivers are themselves findings (rule
//! `A00`, which is not waivable).

use super::lex::{scan, Scan};
use super::Finding;

/// Integer cast targets rule W01 treats as potentially truncating.
const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64",
    "i128", "isize",
];

/// Module prefixes whose containers feed epoch orders (rule D02).
const D02_DIRS: [&str; 5] = [
    "src/ordering/",
    "src/balance/",
    "src/herding/",
    "src/tensor/",
    "src/train/",
];

/// The allowlisted clock sites for rule D03: the bench harness's own
/// timer, the elastic coordinator's per-shard cost clocks, and the
/// service client's connect/read deadlines. Everything else in `src/`
/// must stay wall-clock-free so time can never reach a static-path
/// order.
const D03_ALLOW: [&str; 3] = [
    "src/util/timer.rs",
    "src/ordering/sharded.rs",
    "src/service/client.rs",
];

/// The wire layers rule W01 covers: every byte that crosses a socket or
/// a checkpoint file is produced/consumed here.
const W01_FILES: [&str; 3] = [
    "src/util/ser.rs",
    "src/ordering/transport/codec.rs",
    "src/service/http.rs",
];

/// How many lines above an `unsafe` token rule S01 searches for a
/// `SAFETY:` comment.
const SAFETY_LOOKBACK: usize = 6;

/// A rule's identity and scope, for `grab audit --list` and the docs.
pub struct Rule {
    /// Stable rule id (`D01`, `S01`, …) used in findings and waivers.
    pub id: &'static str,
    /// Where the rule applies, in one phrase.
    pub scope: &'static str,
    /// What the rule forbids and why, in one sentence.
    pub summary: &'static str,
}

/// Every shipped rule, in id order. `A00` (waiver hygiene) is implicit:
/// it guards the waiver mechanism itself and cannot be waived.
pub const RULES: [Rule; 6] = [
    Rule {
        id: "D01",
        scope: "all scanned sources",
        summary: "no `partial_cmp` unwrap/expect chains and no \
                  sort/min/max comparators built on `partial_cmp` — \
                  NaN either panics or breaks the ordering; use \
                  `total_cmp`",
    },
    Rule {
        id: "D02",
        scope: "ordering/, balance/, herding/, tensor/, train/",
        summary: "no `HashMap`/`HashSet` where iteration order could \
                  leak into an epoch order; use BTreeMap/BTreeSet/Vec",
    },
    Rule {
        id: "D03",
        scope: "src/ outside the allowlisted clock sites",
        summary: "no `Instant::now`/`SystemTime` — wall-clock must \
                  never reach a static-path order",
    },
    Rule {
        id: "D04",
        scope: "src/tensor/",
        summary: "no `mul_add`/FMA — contract 7 bit-equality needs \
                  separate mul then add",
    },
    Rule {
        id: "S01",
        scope: "all scanned sources",
        summary: "every `unsafe` must carry a `// SAFETY:` comment on \
                  the same line or within the 6 lines above",
    },
    Rule {
        id: "W01",
        scope: "util/ser.rs, ordering/transport/codec.rs, \
                service/http.rs",
        summary: "no bare `as` integer casts in the wire layers; use \
                  the checked conversions in util::ser",
    },
];

fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets of whole-word occurrences of `needle` in `code`.
fn find_words(code: &str, needle: &str) -> Vec<usize> {
    let cb = code.as_bytes();
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(needle) {
        let off = start + pos;
        let before_ok = off == 0 || !is_word(cb[off - 1]);
        let end = off + needle.len();
        let after_ok = end >= cb.len() || !is_word(cb[end]);
        if before_ok && after_ok {
            out.push(off);
        }
        start = off + 1;
    }
    out
}

fn skip_ws(code: &[u8], mut i: usize) -> usize {
    while i < code.len() && code[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// `i` points at `(`; returns the index just past the matching `)`.
fn balanced_span(code: &[u8], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < code.len() {
        match code[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    i
}

fn line_of(code: &str, off: usize) -> usize {
    code.as_bytes()[..off].iter().filter(|&&b| b == b'\n').count() + 1
}

fn check_d01(code: &str, mut emit: impl FnMut(usize, String)) {
    let cb = code.as_bytes();
    for off in find_words(code, "partial_cmp") {
        let mut j = skip_ws(cb, off + "partial_cmp".len());
        if j >= cb.len() || cb[j] != b'(' {
            continue;
        }
        j = skip_ws(cb, balanced_span(cb, j));
        if j < cb.len() && cb[j] == b'.' {
            j = skip_ws(cb, j + 1);
            for m in ["unwrap", "expect"] {
                let hit = code[j..].starts_with(m)
                    && (j + m.len() >= cb.len() || !is_word(cb[j + m.len()]));
                if hit {
                    emit(
                        off,
                        format!(
                            "`partial_cmp(..).{m}()` panics on NaN; \
                             compare floats with `total_cmp`"
                        ),
                    );
                }
            }
        }
    }
    for fun in ["sort_by", "sort_unstable_by", "max_by", "min_by"] {
        for off in find_words(code, fun) {
            let j = skip_ws(cb, off + fun.len());
            if j >= cb.len() || cb[j] != b'(' {
                continue;
            }
            let body = &code[j..balanced_span(cb, j)];
            if !find_words(body, "partial_cmp").is_empty() {
                emit(
                    off,
                    format!(
                        "`{fun}` comparator uses `partial_cmp`: NaN \
                         ordering is undefined; use `total_cmp`"
                    ),
                );
            }
        }
    }
}

/// One parsed waiver comment.
struct Waiver {
    rule: String,
    line: usize,
    used: bool,
}

/// Parse the text after the waiver marker (everything following the
/// opening paren); `Some(rule)` on a well-formed waiver with a known
/// rule and a non-empty reason.
fn parse_waiver_body(body: &str) -> Option<String> {
    let s = body.trim_start();
    let sb = s.as_bytes();
    if sb.len() < 3
        || !sb[0].is_ascii_uppercase()
        || !sb[1].is_ascii_digit()
        || !sb[2].is_ascii_digit()
    {
        return None;
    }
    let rule = &s[..3];
    if !RULES.iter().any(|r| r.id == rule) {
        return None;
    }
    let s = s[3..].trim_start().strip_prefix(',')?;
    let s = s.trim_start().strip_prefix("reason")?;
    let s = s.trim_start().strip_prefix('=')?;
    let s = s.trim_start().strip_prefix('"')?;
    let end = s.find('"')?;
    let reason = &s[..end];
    s[end + 1..].trim_start().strip_prefix(')')?;
    if reason.trim().is_empty() {
        return None;
    }
    Some(rule.to_string())
}

/// Audit one file's source. `rel_path` is the path relative to the
/// crate root with `/` separators (`src/util/ser.rs`), which is what
/// the per-rule scopes match against. Returns the surviving findings
/// (sorted by line) and the findings absorbed by waivers (so callers
/// can assert waiver policy — e.g. the self-audit requires zero
/// S01/D01 waivers).
pub(crate) fn check_source(rel_path: &str, source: &str) -> (Vec<Finding>, Vec<Finding>) {
    let Scan { code, comment_lines } = scan(source);
    let mut findings: Vec<(&'static str, usize, String)> = Vec::new();

    check_d01(&code, |off, msg| {
        findings.push(("D01", line_of(&code, off), msg));
    });

    if D02_DIRS.iter().any(|d| rel_path.starts_with(d)) {
        for name in ["HashMap", "HashSet"] {
            for off in find_words(&code, name) {
                findings.push((
                    "D02",
                    line_of(&code, off),
                    format!(
                        "`{name}` iteration order is randomized per \
                         process and can leak into an epoch order; use \
                         BTreeMap/BTreeSet/Vec"
                    ),
                ));
            }
        }
    }

    if rel_path.starts_with("src/") && !D03_ALLOW.contains(&rel_path) {
        for needle in ["Instant::now", "SystemTime"] {
            for off in find_words(&code, needle) {
                findings.push((
                    "D03",
                    line_of(&code, off),
                    format!(
                        "wall-clock read (`{needle}`) outside the \
                         allowlisted clock sites can reach a \
                         static-path order"
                    ),
                ));
            }
        }
    }

    for off in find_words(&code, "unsafe") {
        let line = line_of(&code, off);
        let lo = line.saturating_sub(1 + SAFETY_LOOKBACK);
        let hi = line.min(comment_lines.len());
        let covered = (lo..hi).any(|k| comment_lines[k].contains("SAFETY:"));
        if !covered {
            findings.push((
                "S01",
                line,
                format!(
                    "`unsafe` without a `// SAFETY:` comment in the \
                     {SAFETY_LOOKBACK} lines above"
                ),
            ));
        }
    }

    if rel_path.starts_with("src/tensor/") {
        for off in find_words(&code, "mul_add") {
            findings.push((
                "D04",
                line_of(&code, off),
                "`mul_add` fuses mul+add (FMA): contract 7 \
                 bit-equality needs separate mul then add"
                    .to_string(),
            ));
        }
        let mut start = 0usize;
        while let Some(pos) = code[start..].find("fmadd") {
            let off = start + pos;
            findings.push((
                "D04",
                line_of(&code, off),
                "FMA intrinsic: contract 7 bit-equality needs \
                 separate mul then add"
                    .to_string(),
            ));
            start = off + 1;
        }
    }

    if W01_FILES.contains(&rel_path) {
        let cb = code.as_bytes();
        for off in find_words(&code, "as") {
            let j = skip_ws(cb, off + 2);
            let mut end = j;
            while end < cb.len() && is_word(cb[end]) {
                end += 1;
            }
            let target = &code[j..end];
            if INT_TYPES.contains(&target) {
                findings.push((
                    "W01",
                    line_of(&code, off),
                    format!(
                        "bare `as {target}` cast in a wire layer can \
                         truncate silently; use the checked \
                         conversions in util::ser"
                    ),
                ));
            }
        }
    }

    // Waivers.
    let mut waivers: Vec<Waiver> = Vec::new();
    const MARKER: &str = "audit: allow(";
    for (idx, ctext) in comment_lines.iter().enumerate() {
        let Some(pos) = ctext.find(MARKER) else { continue };
        let line = idx + 1;
        match parse_waiver_body(&ctext[pos + MARKER.len()..]) {
            Some(rule) => waivers.push(Waiver { rule, line, used: false }),
            None => findings.push((
                "A00",
                line,
                "malformed waiver: expected `audit: allow(<rule>, \
                 reason = \"...\")` with a known rule and a non-empty \
                 reason"
                    .to_string(),
            )),
        }
    }

    let mut kept: Vec<(&'static str, usize, String)> = Vec::new();
    let mut waived: Vec<(&'static str, usize, String)> = Vec::new();
    for f in findings {
        let hit = waivers
            .iter_mut()
            .find(|w| w.rule == f.0 && (f.1 == w.line || f.1 == w.line + 1));
        match hit {
            Some(w) => {
                w.used = true;
                waived.push(f);
            }
            None => kept.push(f),
        }
    }
    for w in &waivers {
        if !w.used {
            kept.push((
                "A00",
                w.line,
                format!(
                    "stale waiver: no {} finding on this or the next \
                     line",
                    w.rule
                ),
            ));
        }
    }
    kept.sort_by_key(|f| f.1);

    let to_findings = |v: Vec<(&'static str, usize, String)>| -> Vec<Finding> {
        v.into_iter()
            .map(|(rule, line, message)| Finding {
                rule,
                path: rel_path.to_string(),
                line,
                message,
            })
            .collect()
    };
    (to_findings(kept), to_findings(waived))
}
