//! `grab bench` — the JSON bench runner behind the repo's recorded
//! perf trajectory (`BENCH_*.json` at the repo root; docs/perf.md
//! explains the kernel tiers and how to read the files).
//!
//! Re-runs the case lists of `benches/balance_hot.rs` and
//! `benches/ordering_overhead.rs` through [`crate::util::timer::Bench`]
//! — once per requested kernel tier — and emits one versioned JSON
//! document instead of human-grepable lines, so successive PRs can
//! commit comparable snapshots:
//!
//! ```json
//! {"schema_version": 1, "runner": "grab-bench", "git_rev": "abc1234",
//!  "results": [{"case": "fused_dot_centered/d65536", "d": 65536,
//!               "n": null, "B": null, "W": null, "kernel": "simd",
//!               "mean_ns": 8123.4, "iters": 187}, …]}
//! ```
//!
//! The runner is the one place allowed to call
//! [`crate::tensor::set_default_kernel`]: it owns the process and runs
//! each tier's section to completion before switching, so every policy
//! (including transport worker threads) snapshots the tier under
//! measurement. Kernel-independent cases (`dot_naive`, `epoch_order/rr`,
//! the wire codec) are still recorded under every tier label — they
//! double as per-tier noise floors. `--quick` shrinks every case to a
//! handful of iterations for the CI smoke job; the committed trajectory
//! files use the full budgets.

use std::hint::black_box;

use anyhow::bail;

use crate::balance::DeterministicBalancer;
use crate::config::KernelKind;
use crate::ordering::stream::{DriftPlan, StreamOrder};
use crate::ordering::transport::codec;
use crate::ordering::{
    GradBlock, GraBOrder, GreedyOrder, OrderPolicy, PairBalance,
    RandomReshuffle, ShardedOrder,
};
use crate::runtime::Runtime;
use crate::tensor::{self, Kernel};
use crate::util::cli::Args;
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{decode_frame, encode_frame, FrameKind};
use crate::util::timer::{Bench, BenchResult};
use crate::Result;

/// One measured (case, kernel) pair as it appears in the JSON output.
struct CaseResult {
    case: String,
    d: Option<usize>,
    n: Option<usize>,
    b: Option<usize>,
    w: Option<usize>,
    kernel: &'static str,
    mean_ns: f64,
    iters: usize,
}

/// A bench series with the full or `--quick` iteration budget.
fn series(name: String, quick: bool, min: usize, max: usize) -> Bench {
    if quick {
        // `heavy()` cuts warmup to one iteration; the max-iters cap is
        // what actually bounds CI time.
        Bench::new(name).heavy().with_iters(1, 3)
    } else {
        Bench::new(name).with_iters(min, max)
    }
}

#[allow(clippy::too_many_arguments)]
fn push(
    out: &mut Vec<CaseResult>,
    r: BenchResult,
    kernel: Kernel,
    d: Option<usize>,
    n: Option<usize>,
    b: Option<usize>,
    w: Option<usize>,
) {
    out.push(CaseResult {
        case: r.name.clone(),
        d,
        n,
        b,
        w,
        kernel: kernel.name(),
        mean_ns: r.mean_ns(),
        iters: r.iters,
    });
}

fn observe_epoch_blocks(
    policy: &mut dyn OrderPolicy,
    flat: &[f32],
    n: usize,
    d: usize,
    block: usize,
) {
    let _ = policy.epoch_order(0);
    let mut pos = 0;
    while pos < n {
        let end = (pos + block).min(n);
        policy.observe_block(
            pos..end,
            &GradBlock::new(&flat[pos * d..end * d], d),
        );
        pos = end;
    }
    policy.epoch_end();
}

fn observe_epoch_per_example(
    policy: &mut dyn OrderPolicy,
    flat: &[f32],
    n: usize,
    d: usize,
) {
    let _ = policy.epoch_order(0);
    for pos in 0..n {
        policy.observe(pos, &flat[pos * d..(pos + 1) * d]);
    }
    policy.epoch_end();
}

fn one_epoch(policy: &mut dyn OrderPolicy, vs: &[Vec<f32>]) {
    let order = policy.epoch_order(0).to_vec();
    if policy.wants_grads() {
        for (pos, &unit) in order.iter().enumerate() {
            policy.observe(pos, &vs[unit]);
        }
    }
    policy.epoch_end();
}

/// The `benches/balance_hot.rs` case list under kernel tier `k`.
fn balance_hot_cases(
    k: Kernel,
    quick: bool,
    out: &mut Vec<CaseResult>,
) {
    for d in [1024usize, 7850, 65536] {
        let mut rng = Rng::new(d as u64);
        let s: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        let g: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
        let m: Vec<f32> =
            (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();
        let mut c = vec![0.0f32; d];

        let r = series(format!("dot_naive/d{d}"), quick, 100, 2000)
            .run(|| {
                black_box(tensor::dot_naive(&s, &g));
            });
        push(out, r, k, Some(d), None, None, None);
        let r = series(format!("dot_unrolled/d{d}"), quick, 100, 2000)
            .run(|| {
                black_box(k.dot(&s, &g));
            });
        push(out, r, k, Some(d), None, None, None);
        let r =
            series(format!("two_step_center_dot/d{d}"), quick, 100, 2000)
                .run(|| {
                    tensor::sub_into(&g, &m, &mut c);
                    black_box(k.dot(&s, &c));
                });
        push(out, r, k, Some(d), None, None, None);
        let r =
            series(format!("fused_dot_centered/d{d}"), quick, 100, 2000)
                .run(|| {
                    black_box(k.dot_centered(&s, &g, &m));
                });
        push(out, r, k, Some(d), None, None, None);

        let n = 256usize;
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();
        let r = series(format!("grab_observe_epoch/n{n}/d{d}"), quick, 3, 50)
            .run(|| {
                let mut p =
                    GraBOrder::new(n, d, Box::new(DeterministicBalancer));
                observe_epoch_per_example(&mut p, &flat, n, d);
            });
        push(out, r, k, Some(d), Some(n), None, None);
        let b = 32usize;
        let r = series(
            format!("grab_observe_epoch_blk{b}/n{n}/d{d}"),
            quick,
            3,
            50,
        )
        .run(|| {
            let mut p =
                GraBOrder::new(n, d, Box::new(DeterministicBalancer));
            observe_epoch_blocks(&mut p, &flat, n, d, b);
        });
        push(out, r, k, Some(d), Some(n), Some(b), None);
    }

    // PJRT kernel path, if artifacts are present (device-side; the CPU
    // kernel tier does not apply, but the row keys the layer ablation).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::open("artifacts").expect("runtime");
        for d in [1024usize, 7850] {
            let kernel =
                rt.balance_executor(d).expect("balance artifact");
            let mut rng = Rng::new(9);
            let m: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32 * 0.1).collect();
            let g: Vec<f32> =
                (0..d).map(|_| rng.gauss() as f32).collect();
            let mut s = vec![0.0f32; d];
            let r = series(format!("pallas_kernel_step/d{d}"), quick, 20, 200)
                .run(|| {
                    black_box(kernel.step(&mut s, &m, &g).unwrap());
                });
            push(out, r, k, Some(d), None, None, None);
        }
    } else {
        println!("(artifacts missing — skipping PJRT kernel rows)");
    }
}

/// The `benches/ordering_overhead.rs` case list under kernel tier `k`.
fn ordering_overhead_cases(
    k: Kernel,
    quick: bool,
    out: &mut Vec<CaseResult>,
) {
    // Table-1 policy epochs at the paper's logreg dimension.
    let d = 7850;
    for n in [256usize, 512, 1024] {
        let mut rng = Rng::new(n as u64);
        let vs = gen::vec_set(&mut rng, n, d);
        let r = series(format!("epoch_order/rr/n{n}/d{d}"), quick, 5, 100)
            .run(|| {
                let mut p = RandomReshuffle::new(n, 0);
                one_epoch(&mut p, &vs);
            });
        push(out, r, k, Some(d), Some(n), None, None);
        let r = series(format!("epoch_order/grab/n{n}/d{d}"), quick, 5, 50)
            .run(|| {
                let mut p =
                    GraBOrder::new(n, d, Box::new(DeterministicBalancer));
                one_epoch(&mut p, &vs);
            });
        push(out, r, k, Some(d), Some(n), None, None);
        let r = series(format!("epoch_order/greedy/n{n}/d{d}"), quick, 2, 5)
            .run(|| {
                let mut p = GreedyOrder::new(n, d);
                one_epoch(&mut p, &vs);
            });
        push(out, r, k, Some(d), Some(n), None, None);
    }

    // Per-example vs block observe throughput.
    let d = 4096;
    let n = 512;
    let block = 64;
    let mut rng = Rng::new(42);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();
    let r = series(
        format!("grab_observe/per_example/n{n}/d{d}"),
        quick,
        5,
        60,
    )
    .run(|| {
        let mut p = GraBOrder::new(n, d, Box::new(DeterministicBalancer));
        observe_epoch_per_example(&mut p, &flat, n, d);
    });
    push(out, r, k, Some(d), Some(n), None, None);
    let r = series(
        format!("grab_observe/block{block}/n{n}/d{d}"),
        quick,
        5,
        60,
    )
    .run(|| {
        let mut p = GraBOrder::new(n, d, Box::new(DeterministicBalancer));
        observe_epoch_blocks(&mut p, &flat, n, d, block);
    });
    push(out, r, k, Some(d), Some(n), Some(block), None);
    let r = series(
        format!("pair_observe/block{block}/n{n}/d{d}"),
        quick,
        5,
        60,
    )
    .run(|| {
        let mut p = PairBalance::new(n, d);
        observe_epoch_blocks(&mut p, &flat, n, d, block);
    });
    push(out, r, k, Some(d), Some(n), Some(block), None);

    // Sharded dispatch backends, equal and skewed topologies. Policies
    // persist across iterations so each measured epoch is steady-state.
    let n = 2048;
    let d = 256;
    let block = 64;
    let w = 4;
    let depth = 4;
    let mut rng = Rng::new(21);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();
    let mut strided = ShardedOrder::new(n, d, w);
    let r = series(format!("sharded_observe/strided/w{w}/d{d}"), quick, 5, 60)
        .run(|| observe_epoch_blocks(&mut strided, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(w));
    let mut gathered = ShardedOrder::new_gathered(n, d, w);
    let r =
        series(format!("sharded_observe/gathered/w{w}/d{d}"), quick, 5, 60)
            .run(|| {
                observe_epoch_blocks(&mut gathered, &flat, n, d, block)
            });
    push(out, r, k, Some(d), Some(n), Some(block), Some(w));
    let mut asynch = ShardedOrder::new_async(n, d, w, depth);
    let r = series(
        format!("sharded_observe/async/w{w}/d{d}/q{depth}"),
        quick,
        5,
        60,
    )
    .run(|| observe_epoch_blocks(&mut asynch, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(w));
    let mut socket =
        ShardedOrder::new_tcp_loopback(n, d, w).expect("loopback workers");
    let r = series(format!("sharded_observe/tcp/w{w}/d{d}"), quick, 5, 60)
        .run(|| observe_epoch_blocks(&mut socket, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(w));

    let weights: [u64; 3] = [1, 1, 4];
    let mut rng = Rng::new(27);
    let flat: Vec<f32> =
        (0..n * d).map(|_| rng.gauss() as f32).collect();
    let mut strided = ShardedOrder::new_weighted(n, d, &weights);
    let r = series(format!("skewed_observe/strided/114/d{d}"), quick, 5, 60)
        .run(|| observe_epoch_blocks(&mut strided, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(weights.len()));
    let mut gathered =
        ShardedOrder::new_gathered_weighted(n, d, &weights);
    let r = series(format!("skewed_observe/gathered/114/d{d}"), quick, 5, 60)
        .run(|| observe_epoch_blocks(&mut gathered, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(weights.len()));
    let mut asynch =
        ShardedOrder::new_async_weighted(n, d, &weights, depth);
    let r = series(
        format!("skewed_observe/async/114/d{d}/q{depth}"),
        quick,
        5,
        60,
    )
    .run(|| observe_epoch_blocks(&mut asynch, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(weights.len()));
    let mut socket = ShardedOrder::new_tcp_loopback_weighted(n, d, &weights)
        .expect("loopback workers");
    let r = series(format!("skewed_observe/tcp/114/d{d}"), quick, 5, 60)
        .run(|| observe_epoch_blocks(&mut socket, &flat, n, d, block));
    push(out, r, k, Some(d), Some(n), Some(block), Some(weights.len()));

    // Wire codec throughput (kernel-independent noise floor).
    let d = 256;
    let rows = 64;
    let mut rng = Rng::new(33);
    let data: Vec<f32> =
        (0..rows * d).map(|_| rng.gauss() as f32).collect();
    let mut scratch: Vec<f32> = Vec::with_capacity(rows * d);
    let r = series(format!("wire/gather/r{rows}/d{d}"), quick, 10, 2000)
        .run(|| {
            scratch.clear();
            for r in 0..rows {
                scratch.extend_from_slice(&data[r * d..(r + 1) * d]);
            }
        });
    push(out, r, k, Some(d), None, Some(rows), None);
    let mut payload = Vec::new();
    let mut frame = Vec::new();
    let r = series(format!("wire/encode/r{rows}/d{d}"), quick, 10, 2000)
        .run(|| {
            codec::encode_block(&data, d, &mut payload);
            frame.clear();
            encode_frame(FrameKind::Block, &payload, &mut frame);
        });
    push(out, r, k, Some(d), None, Some(rows), None);
    let mut decoded: Vec<f32> = Vec::new();
    let r = series(format!("wire/decode/r{rows}/d{d}"), quick, 10, 2000)
        .run(|| {
            let (kind, body, _) = decode_frame(&frame).expect("frame");
            assert!(matches!(kind, FrameKind::Block));
            codec::decode_block(body, d, &mut decoded).expect("block");
        });
    push(out, r, k, Some(d), None, Some(rows), None);

    // Streaming reservoir: window-advance cost vs reservoir size —
    // static membership (== PairBalance work, contract 9) vs
    // count-neutral churn (plan derivation + carry-out on top, no
    // backend rebuild). Policies persist so each iteration is one
    // steady-state window.
    let d = 256;
    let block = 64;
    for n in [256usize, 1024, 4096] {
        let mut rng = Rng::new(n as u64);
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();
        let mut staticr = StreamOrder::prefilled(n, d);
        let r = series(format!("stream_window/static/n{n}/d{d}"), quick, 5, 60)
            .run(|| {
                staticr.run_window(
                    &mut |unit, out| {
                        let u = unit as usize % n;
                        out.copy_from_slice(&flat[u * d..(u + 1) * d]);
                    },
                    block,
                );
            });
        push(out, r, k, Some(d), Some(n), Some(block), None);

        let rate = (n / 16).max(1);
        let drift = DriftPlan::steady(7, rate);
        let mut churn = StreamOrder::prefilled(n, d);
        let mut next_unit = n as u64;
        let r = series(
            format!("stream_window/churn{rate}/n{n}/d{d}"),
            quick,
            5,
            60,
        )
        .run(|| {
            churn.drive_window(&drift, &mut next_unit, block);
        });
        push(out, r, k, Some(d), Some(n), Some(block), None);
    }
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_opt(v: Option<usize>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "null".to_string(),
    }
}

fn render_json(rev: &str, results: &[CaseResult]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema_version\": 1,\n");
    s.push_str("  \"runner\": \"grab-bench\",\n");
    s.push_str(&format!("  \"git_rev\": {},\n", json_str(rev)));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"case\": {}, \"d\": {}, \"n\": {}, \"B\": {}, \
             \"W\": {}, \"kernel\": {}, \"mean_ns\": {:.1}, \
             \"iters\": {}}}{}\n",
            json_str(&r.case),
            json_opt(r.d),
            json_opt(r.n),
            json_opt(r.b),
            json_opt(r.w),
            json_str(r.kernel),
            r.mean_ns,
            r.iters,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Entry point for `grab bench [--out FILE.json] [--quick]
/// [--kernels k1,k2,…]`. Runs every case under every requested kernel
/// tier and writes the versioned JSON document to `--out` (stdout when
/// omitted).
pub fn run_from_cli(args: &Args) -> Result<()> {
    let out_path = args.opt_str("out");
    if args.opt_str("quick").is_some() {
        bail!(
            "--quick is a boolean flag and takes no value \
             (put it last or before another --flag)"
        );
    }
    let quick = args.flag("quick");
    let tiers = args.str_or("kernels", "scalar,simd,simd+par");
    args.reject_unknown()?;

    let mut kernels: Vec<Kernel> = Vec::new();
    for tok in tiers.split(',') {
        let k = KernelKind::parse(tok.trim())?.resolve();
        if !kernels.contains(&k) {
            kernels.push(k);
        }
    }
    if kernels.is_empty() {
        bail!("--kernels must name at least one tier");
    }

    let mut results = Vec::new();
    for &k in &kernels {
        // The runner owns the process: install the tier under
        // measurement so every policy (and every transport worker it
        // spawns) snapshots it at construction.
        tensor::set_default_kernel(k);
        eprintln!(
            "[bench] kernel tier {} ({} mode)",
            k.name(),
            if quick { "quick" } else { "full" }
        );
        balance_hot_cases(k, quick, &mut results);
        ordering_overhead_cases(k, quick, &mut results);
    }

    let json = render_json(&git_rev(), &results);
    match out_path {
        Some(path) => {
            std::fs::write(&path, &json)?;
            eprintln!(
                "[bench] wrote {} results to {path}",
                results.len()
            );
        }
        None => print!("{json}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_document_is_schema_shaped() {
        let results = vec![
            CaseResult {
                case: "fused_dot_centered/d64".to_string(),
                d: Some(64),
                n: None,
                b: None,
                w: None,
                kernel: "scalar",
                mean_ns: 12.3456,
                iters: 100,
            },
            CaseResult {
                case: "sharded_observe/tcp/w4/d256".to_string(),
                d: Some(256),
                n: Some(2048),
                b: Some(64),
                w: Some(4),
                kernel: "simd",
                mean_ns: 99.0,
                iters: 5,
            },
        ];
        let doc = render_json("abc1234", &results);
        assert!(doc.contains("\"schema_version\": 1"));
        assert!(doc.contains("\"runner\": \"grab-bench\""));
        assert!(doc.contains("\"git_rev\": \"abc1234\""));
        assert!(doc.contains("\"n\": null"));
        assert!(doc.contains("\"W\": 4"));
        assert!(doc.contains("\"mean_ns\": 12.3"));
        // Exactly one separator comma between the two entries.
        assert_eq!(doc.matches("}},\n").count() + doc.matches("},\n").count(), 1);
    }

    #[test]
    fn json_strings_escape_specials() {
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny"), "\"x\\ny\"");
    }

    #[test]
    fn git_rev_never_panics() {
        let rev = git_rev();
        assert!(!rev.is_empty());
    }
}
