//! Statement 1 — the Chelidze et al. construction on which greedy herding
//! (Algorithm 1) is Ω(n) while a random permutation is O(√n).
//!
//! n/2 copies of (1, 1) and n/2 copies of (4, −2): greedy keeps selecting
//! (1, 1) for the first n/2 steps (by induction, with running sum (m, m),
//! 2(m+1)² < (m+4)² + (m−2)²), so the centered prefix sum grows linearly.

/// Build the adversarial family (n must be even).
pub fn adversarial_vectors(n: usize) -> Vec<Vec<f32>> {
    assert!(n % 2 == 0, "n must be even");
    let mut vs = Vec::with_capacity(n);
    for _ in 0..n / 2 {
        vs.push(vec![1.0f32, 1.0]);
    }
    for _ in 0..n / 2 {
        vs.push(vec![4.0f32, -2.0]);
    }
    vs
}

/// The mean of the family: ((1+4)/2, (1-2)/2) = (2.5, -0.5).
pub fn adversarial_mean() -> Vec<f32> {
    vec![2.5, -0.5]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::greedy::greedy_order_raw;
    use crate::herding::herding_bound;
    use crate::util::rng::Rng;
    use crate::util::stats::scaling_exponent;

    #[test]
    fn greedy_picks_ones_first() {
        // Greedy must select all (1,1) vectors before any (4,-2).
        let n = 64;
        let vs = adversarial_vectors(n);
        let order = greedy_order_raw(&vs);
        for (t, &i) in order.iter().take(n / 2).enumerate() {
            assert!(
                i < n / 2,
                "step {t} picked vector {i} (a (4,-2)) too early"
            );
        }
    }

    #[test]
    fn greedy_is_linear_random_is_sqrt() {
        // The Statement 1 separation, measured: fit scaling exponents of
        // the herding objective vs n for both orderings.
        let ns = [64usize, 128, 256, 512, 1024];
        let mut greedy_bounds = Vec::new();
        let mut random_bounds = Vec::new();
        let mut rng = Rng::new(0);
        for &n in &ns {
            let vs = adversarial_vectors(n);
            let g = greedy_order_raw(&vs);
            greedy_bounds.push(herding_bound(&vs, &g).1 as f64);
            // Average a few random permutations.
            let mut acc = 0.0;
            for _ in 0..5 {
                let p = rng.permutation(n);
                acc += herding_bound(&vs, &p).1 as f64;
            }
            random_bounds.push(acc / 5.0);
        }
        let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
        let greedy_exp = scaling_exponent(&xs, &greedy_bounds);
        let random_exp = scaling_exponent(&xs, &random_bounds);
        assert!(
            greedy_exp > 0.85,
            "greedy exponent {greedy_exp} (want ~1)"
        );
        assert!(
            random_exp < 0.7,
            "random exponent {random_exp} (want ~0.5)"
        );
    }
}
