//! Algorithm 1 — greedy herding / Greedy Ordering (Lu et al. 2021a).
//!
//! Center the vectors, then repeatedly pick the candidate minimizing
//! ‖s + z_j‖₂. This is the paper's memory-hungry baseline: O(nd) storage
//! (all stale gradients) and O(n²) selection work (n scans of up to n
//! candidates, each O(d) via the cached-norm trick below).

use crate::tensor;

/// Run greedy herding over `vs`; returns the selected permutation.
///
/// Selection cost per step is O(|Φ|·d): ‖s+z_j‖² = ‖s‖² + 2⟨s,z_j⟩ + ‖z_j‖²
/// and ‖s‖² is common to all candidates, so only 2⟨s,z_j⟩ + ‖z_j‖² is
/// compared, with ‖z_j‖² precomputed once.
pub fn greedy_order(vs: &[Vec<f32>]) -> Vec<usize> {
    greedy_order_centered_at(vs, None)
}

/// Greedy selection **without** the centering step — the variant analysed
/// in the paper's Statement 1 proof (Appendix B.1 tracks the running sum of
/// the *raw* vectors: after m picks of (1,1) the sum is (m,m)). On the
/// Chelidze construction this is Ω(n) in the herding objective, while a
/// random permutation is O(√n); centering happens to rescue greedy on that
/// specific instance (the two classes become exact opposites), which is
/// itself reported in the statement1 experiment.
pub fn greedy_order_raw(vs: &[Vec<f32>]) -> Vec<usize> {
    let zero = vec![0.0f32; vs.first().map_or(0, |v| v.len())];
    greedy_order_centered_at(vs, Some(&zero))
}

fn greedy_order_centered_at(
    vs: &[Vec<f32>],
    center_override: Option<&[f32]>,
) -> Vec<usize> {
    let n = vs.len();
    if n == 0 {
        return vec![];
    }
    let d = vs[0].len();
    let center = match center_override {
        Some(c) => c.to_vec(),
        None => super::mean(vs),
    };
    // Centered copies (this is the O(nd) storage the paper charges).
    let centered: Vec<Vec<f32>> = vs
        .iter()
        .map(|v| {
            let mut c = vec![0.0f32; d];
            tensor::sub_into(v, &center, &mut c);
            c
        })
        .collect();
    let sq_norms: Vec<f32> =
        centered.iter().map(|c| tensor::dot(c, c)).collect();

    let mut remaining: Vec<usize> = (0..n).collect();
    let mut s = vec![0.0f32; d];
    let mut order = Vec::with_capacity(n);
    while !remaining.is_empty() {
        let mut best_pos = 0usize;
        let mut best_score = f32::INFINITY;
        for (pos, &j) in remaining.iter().enumerate() {
            let score = 2.0 * tensor::dot(&s, &centered[j]) + sq_norms[j];
            if score < best_score {
                best_score = score;
                best_pos = pos;
            }
        }
        let j = remaining.swap_remove(best_pos);
        tensor::axpy(1.0, &centered[j], &mut s);
        order.push(j);
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    #[test]
    fn output_is_permutation() {
        prop::forall("greedy permutation", 32, |rng| {
            let (n, d) = gen::small_dims(rng, 40, 8);
            let vs = gen::vec_set(rng, n, d);
            assert_permutation(&greedy_order(&vs))
        });
    }

    #[test]
    fn greedy_interleaves_opposite_pairs() {
        // +v, -v pairs: greedy should alternate, achieving bound ~ ||v||.
        let v = vec![1.0f32, 2.0];
        let neg: Vec<f32> = v.iter().map(|x| -x).collect();
        let vs = vec![
            v.clone(), v.clone(), v.clone(), v.clone(),
            neg.clone(), neg.clone(), neg.clone(), neg.clone(),
        ];
        let order = greedy_order(&vs);
        let (_, l2) = herding_bound(&vs, &order);
        assert!(l2 <= tensor::norm2(&v) + 1e-4, "l2={l2}");
    }

    #[test]
    fn greedy_beats_worst_case_order_on_gaussians() {
        let mut rng = Rng::new(4);
        let vs = gen::vec_set(&mut rng, 256, 8);
        let greedy = greedy_order(&vs);
        let (_, greedy_l2) = herding_bound(&vs, &greedy);
        // Sorted-by-first-coordinate is a pathologically bad order.
        let mut bad: Vec<usize> = (0..vs.len()).collect();
        bad.sort_by(|&a, &b| vs[a][0].total_cmp(&vs[b][0]));
        let (_, bad_l2) = herding_bound(&vs, &bad);
        assert!(greedy_l2 < bad_l2 / 2.0,
                "greedy {greedy_l2} vs bad {bad_l2}");
    }

    #[test]
    fn greedy_survives_nan_inputs() {
        // A NaN projected cost must never panic the selection loop (the
        // `partial_cmp().unwrap()` bug class from PR 8's `Summary::of`,
        // audit rule D01): `score < best_score` is simply false for NaN,
        // so poisoned candidates are picked last and the output is still
        // a permutation.
        let vs = vec![
            vec![1.0f32, 2.0],
            vec![f32::NAN, 0.0],
            vec![-1.0, -2.0],
            vec![0.5, f32::NAN],
            vec![3.0, -1.0],
        ];
        assert_permutation(&greedy_order(&vs)).unwrap();
        assert_permutation(&greedy_order_raw(&vs)).unwrap();
        // All-NaN input: still a permutation, still no panic.
        let poisoned = vec![vec![f32::NAN; 3]; 4];
        assert_permutation(&greedy_order(&poisoned)).unwrap();
    }

    #[test]
    fn empty_and_singleton() {
        assert!(greedy_order(&[]).is_empty());
        assert_eq!(greedy_order(&[vec![1.0, 2.0]]), vec![0]);
    }
}
