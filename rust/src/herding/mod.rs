//! The herding problem (Harvey & Samadi 2014) — objective evaluation,
//! greedy ordering (Algorithm 1), offline balance-and-reorder herding, and
//! the Statement-1 adversarial construction where greedy fails.

pub mod adversarial;
pub mod greedy;
pub mod offline;

use crate::tensor;

/// Evaluate the herding objective of Eq. (3) for `order` over `vs`:
/// max_k ‖Σ_{t≤k} (z_{σ(t)} − mean)‖ in both ℓ∞ and ℓ2.
pub fn herding_bound(vs: &[Vec<f32>], order: &[usize]) -> (f32, f32) {
    let center = mean(vs);
    tensor::prefix_bounds(vs, &center, order)
}

/// Herding objective against an explicit center (e.g. zero for pre-centered
/// inputs, or a stale mean as in GraB's analysis).
pub fn herding_bound_centered(
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (f32, f32) {
    tensor::prefix_bounds(vs, center, order)
}

/// Mean of a vector set.
pub fn mean(vs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut m = vec![0.0f32; vs[0].len()];
    tensor::mean_into(vs, &mut m);
    m
}

/// Full prefix-norm trajectory ‖Σ_{t≤k}(z_{σ(t)} − mean)‖₂ for k = 1..n —
/// the curve plotted in Fig. 1b.
pub fn prefix_trajectory(vs: &[Vec<f32>], order: &[usize]) -> Vec<f32> {
    let center = mean(vs);
    let d = center.len();
    let mut sum = vec![0.0f32; d];
    let mut out = Vec::with_capacity(order.len());
    for &i in order {
        for j in 0..d {
            sum[j] += vs[i][j] - center[j];
        }
        out.push(tensor::norm2(&sum));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn bound_zero_for_identical_vectors() {
        let vs = vec![vec![2.0f32, -1.0]; 8];
        let order: Vec<usize> = (0..8).collect();
        let (inf, l2) = herding_bound(&vs, &order);
        assert!(inf < 1e-6 && l2 < 1e-6);
    }

    #[test]
    fn bound_is_order_sensitive() {
        let vs = vec![vec![1.0f32], vec![1.0], vec![-1.0], vec![-1.0]];
        let (bad, _) = herding_bound(&vs, &[0, 1, 2, 3]);
        let (good, _) = herding_bound(&vs, &[0, 2, 1, 3]);
        assert!(bad > good + 0.5);
    }

    #[test]
    fn trajectory_last_point_near_zero_for_zero_sum() {
        // Prefix sums of centered vectors return to 0 at k = n.
        let mut rng = Rng::new(2);
        let vs: Vec<Vec<f32>> =
            (0..32).map(|_| vec![rng.gauss() as f32; 4]).collect();
        let order: Vec<usize> = (0..32).collect();
        let traj = prefix_trajectory(&vs, &order);
        assert_eq!(traj.len(), 32);
        assert!(traj[31].abs() < 1e-3, "final={}", traj[31]);
    }

    #[test]
    fn random_order_bound_scales_like_sqrt_n() {
        // Azuma: random permutation achieves O(sqrt(n)) — check the ratio
        // between n=4096 and n=256 is near sqrt(16)=4, not 16.
        let mut rng = Rng::new(3);
        let mut bound_at = |n: usize| {
            let vs: Vec<Vec<f32>> = (0..n)
                .map(|_| vec![rng.gauss() as f32, rng.gauss() as f32])
                .collect();
            let order = rng.permutation(n);
            herding_bound(&vs, &order).1 as f64
        };
        let b_small: f64 =
            (0..5).map(|_| bound_at(256)).sum::<f64>() / 5.0;
        let b_big: f64 =
            (0..5).map(|_| bound_at(4096)).sum::<f64>() / 5.0;
        let ratio = b_big / b_small;
        assert!(
            ratio < 8.0,
            "ratio {ratio} suggests super-sqrt growth"
        );
    }

    #[test]
    fn bound_permutation_invariant_inputs() {
        prop::forall("herding bound well-defined", 16, |rng| {
            let n = 2 + rng.gen_range(30) as usize;
            let d = 1 + rng.gen_range(8) as usize;
            let vs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.gauss() as f32).collect())
                .collect();
            let order: Vec<usize> = (0..n).collect();
            let (inf, l2) = herding_bound(&vs, &order);
            if !(inf.is_finite() && l2.is_finite() && inf <= l2 + 1e-4) {
                return Err(format!("inf={inf} l2={l2}"));
            }
            Ok(())
        });
    }
}
