//! Offline herding via repeated balance-and-reorder (the Õ(1) herding
//! subroutine of Section 4: Theorem 2 halves the bound towards the
//! balancing constant A on every pass, so iterating drives H → A ≈ Õ(1)).
//!
//! This is what `Herding(·)` in Algorithm 2 resolves to, and what Fig. 4
//! sweeps over "epochs" (number of passes) for Algorithms 5 vs 6.

use crate::balance::{balance_pass, reorder, Balancer};
use crate::herding::mean;
use crate::tensor;

/// One pass: balance the (centered) vectors along `order`, then reorder by
/// the signs. Returns (new_order, pass ℓ∞ balancing bound, pass ℓ2 bound).
pub fn balance_reorder_pass(
    balancer: &mut dyn Balancer,
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (Vec<usize>, f32, f32) {
    let (signs, max_inf, max_l2) = balance_pass(balancer, vs, center, order);
    (reorder(order, &signs), max_inf, max_l2)
}

/// Record of one offline herding pass (for the Fig. 4 series).
#[derive(Clone, Debug)]
pub struct PassStats {
    /// 0-based pass index.
    pub pass: usize,
    /// Herding objective (Eq. 3) of the order *after* this pass.
    pub herding_inf: f32,
    /// ℓ2 herding objective after this pass.
    pub herding_l2: f32,
    /// Signed balancing objective observed during the pass.
    pub balance_inf: f32,
    /// ℓ2 of the signed running sum during the pass.
    pub balance_l2: f32,
}

/// Run `passes` balance-reorder iterations starting from the identity
/// order. Returns the final order and per-pass statistics.
pub fn herd(
    balancer: &mut dyn Balancer,
    vs: &[Vec<f32>],
    passes: usize,
) -> (Vec<usize>, Vec<PassStats>) {
    let center = mean(vs);
    let mut order: Vec<usize> = (0..vs.len()).collect();
    let mut stats = Vec::with_capacity(passes);
    for pass in 0..passes {
        balancer.reset();
        let (new_order, b_inf, b_l2) =
            balance_reorder_pass(balancer, vs, &center, &order);
        order = new_order;
        let (h_inf, h_l2) =
            tensor::prefix_bounds(vs, &center, &order);
        stats.push(PassStats {
            pass: pass + 1,
            herding_inf: h_inf,
            herding_l2: h_l2,
            balance_inf: b_inf,
            balance_l2: b_l2,
        });
    }
    (order, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::DeterministicBalancer;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    #[test]
    fn herd_outputs_permutation() {
        prop::forall("herd permutation", 16, |rng| {
            let (n, d) = gen::small_dims(rng, 60, 8);
            let vs = gen::vec_set(rng, n, d);
            let mut b = DeterministicBalancer;
            let (order, stats) = herd(&mut b, &vs, 3);
            assert_permutation(&order)?;
            if stats.len() != 3 {
                return Err("missing stats".into());
            }
            Ok(())
        });
    }

    #[test]
    fn repeated_passes_drive_bound_down() {
        // Theorem 2: the herding bound contracts towards A over passes.
        let mut rng = Rng::new(5);
        let n = 1024;
        let vs = gen::vec_set(&mut rng, n, 16);
        let identity: Vec<usize> = (0..n).collect();
        let (start_inf, _) = herding_bound(&vs, &identity);
        let mut b = DeterministicBalancer;
        let (order, stats) = herd(&mut b, &vs, 8);
        let final_inf = stats.last().unwrap().herding_inf;
        assert!(
            final_inf < start_inf / 3.0,
            "start {start_inf} -> final {final_inf}"
        );
        // And the bound is monotone-ish: last is no worse than first pass.
        assert!(final_inf <= stats[0].herding_inf + 1e-4);
        assert_eq!(order.len(), n);
    }

    #[test]
    fn herding_bound_far_below_random_after_passes() {
        let mut rng = Rng::new(6);
        let n = 2048;
        let vs = gen::vec_set(&mut rng, n, 32);
        let random = rng.permutation(n);
        let (rand_inf, _) = herding_bound(&vs, &random);
        let mut b = DeterministicBalancer;
        let (_, stats) = herd(&mut b, &vs, 10);
        let herded = stats.last().unwrap().herding_inf;
        assert!(
            herded < rand_inf / 2.0,
            "herded {herded} vs random {rand_inf}"
        );
    }
}
