//! Minimal property-based testing harness (proptest is not in the vendored
//! dependency closure). Coordinator invariants — permutation validity,
//! herding-bound contraction, balance-sign behaviour — are checked over
//! randomized cases with a reported reproduction seed on failure.

use super::rng::Rng;

/// Number of cases per property (override with env `GRAB_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("GRAB_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases` randomized inputs. `prop` receives a fresh RNG
/// per case and returns `Err(msg)` to fail. Panics with the case seed so the
/// failure is reproducible with `Rng::new(seed)`.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Miri runs ~two orders of magnitude slower than native code; a
    // handful of cases still walks every property's logic, and native
    // runs keep the full budget.
    let cases = if cfg!(miri) { cases.min(6) } else { cases };
    let base = 0xC0FF_EE00u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} \
                 (reproduce with Rng::new({seed:#x})): {msg}"
            );
        }
    }
}

/// Convenience generators used across property tests.
pub mod gen {
    use super::Rng;

    /// Random vector of dimension `d` with entries ~ N(0, scale²).
    pub fn gauss_vec(rng: &mut Rng, d: usize, scale: f32) -> Vec<f32> {
        (0..d).map(|_| rng.gauss() as f32 * scale).collect()
    }

    /// A set of `n` random d-dim vectors.
    pub fn vec_set(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| gauss_vec(rng, d, 1.0)).collect()
    }

    /// A set of `n` vectors that sums (numerically) to zero: pair +v/-v.
    pub fn zero_sum_set(rng: &mut Rng, half: usize, d: usize)
        -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(half * 2);
        for _ in 0..half {
            let v = gauss_vec(rng, d, 1.0);
            out.push(v.iter().map(|x| -x).collect());
            out.push(v);
        }
        out
    }

    /// Dimension in [1, max_d], n in [1, max_n].
    pub fn small_dims(rng: &mut Rng, max_n: usize, max_d: usize)
        -> (usize, usize) {
        (
            1 + rng.gen_range(max_n as u64) as usize,
            1 + rng.gen_range(max_d as u64) as usize,
        )
    }
}

/// Assert a slice is a permutation of 0..n (shared invariant helper).
pub fn assert_permutation(p: &[usize]) -> Result<(), String> {
    let n = p.len();
    let mut seen = vec![false; n];
    for &i in p {
        if i >= n {
            return Err(format!("index {i} out of range (n={n})"));
        }
        if seen[i] {
            return Err(format!("duplicate index {i}"));
        }
        seen[i] = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 10, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always_fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn permutation_checker() {
        assert!(assert_permutation(&[2, 0, 1]).is_ok());
        assert!(assert_permutation(&[0, 0, 1]).is_err());
        assert!(assert_permutation(&[3, 0, 1]).is_err());
    }

    #[test]
    fn zero_sum_generator_sums_to_zero() {
        let mut rng = Rng::new(1);
        let set = gen::zero_sum_set(&mut rng, 8, 16);
        let mut sum = vec![0.0f32; 16];
        for v in &set {
            for (s, x) in sum.iter_mut().zip(v) {
                *s += x;
            }
        }
        for s in sum {
            assert!(s.abs() < 1e-4);
        }
    }
}
