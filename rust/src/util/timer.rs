//! Timing substrate: scoped timers and the bench measurement loop used by
//! every `benches/*.rs` target (criterion is not in the vendored closure,
//! so the harness is built here: warmup, repeated timed batches, and a
//! throughput-aware summary printed in a stable machine-grepable format).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// A single named measurement series.
pub struct Bench {
    /// Series name printed with the result line.
    pub name: String,
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    target: Duration,
}

/// Result of a bench run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Series name.
    pub name: String,
    /// Timed iterations performed.
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
}

impl BenchResult {
    /// Mean iteration time in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean * 1e9
    }

    /// Render one line: `bench <name>  mean=…  p50=…  p95=…  iters=N`.
    pub fn line(&self) -> String {
        format!(
            "bench {:<44} mean={:>12} p50={:>12} p95={:>12} iters={}",
            self.name,
            fmt_dur(self.summary.mean),
            fmt_dur(self.summary.p50),
            fmt_dur(self.summary.p95),
            self.iters
        )
    }
}

/// Human-friendly duration from seconds.
pub fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

impl Bench {
    /// A measurement series with the default warmup/iteration policy.
    pub fn new(name: impl Into<String>) -> Bench {
        Bench {
            name: name.into(),
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            target: Duration::from_millis(1500),
        }
    }

    /// Configure for expensive end-to-end runs.
    pub fn heavy(mut self) -> Bench {
        self.warmup_iters = 1;
        self.min_iters = 3;
        self.max_iters = 20;
        self.target = Duration::from_secs(5);
        self
    }

    /// Override the minimum/maximum timed iteration counts.
    pub fn with_iters(mut self, min: usize, max: usize) -> Bench {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run the measurement loop; `f` is one iteration.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
            let enough_iters = times.len() >= self.min_iters;
            let out_of_time = start.elapsed() >= self.target;
            if (enough_iters && out_of_time) || times.len() >= self.max_iters
            {
                break;
            }
        }
        let res = BenchResult {
            name: self.name.clone(),
            iters: times.len(),
            summary: Summary::of(&times),
        };
        println!("{}", res.line());
        res
    }
}

/// Simple scoped stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    /// Seconds elapsed since `start`.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_min_iters() {
        let b = Bench::new("noop").with_iters(5, 5);
        let r = b.run(|| {});
        assert_eq!(r.iters, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.secs() >= 0.001);
    }
}
