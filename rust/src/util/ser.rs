//! Serialization substrate: a minimal JSON value model (parser + writer)
//! and a CSV writer.
//!
//! The JSON parser exists to read `artifacts/manifest.json` (written by
//! `python/compile/aot.py`); the writers emit experiment results under
//! `results/` and run metadata. Only the JSON subset json.dump produces is
//! required (no comments, `\uXXXX` escapes supported).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`, like json.dump emits).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, key-sorted for deterministic output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Read + parse a JSON file.
    pub fn from_file(path: &Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text)
    }

    // -- typed accessors ---------------------------------------------------

    /// Object member lookup; errors on non-objects / missing keys.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let bytes = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    s.push_str(std::str::from_utf8(bytes)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("invalid number {text:?} at offset {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// CSV writer
// ---------------------------------------------------------------------------

/// Simple CSV writer with header enforcement. Used by the experiment
/// harness: one file per figure/table under `results/`.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path`, write `header`, fix the column count.
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = CsvWriter {
            file: std::io::BufWriter::new(file),
            ncols: header.len(),
        };
        w.write_raw(header)?;
        Ok(w)
    }

    /// Write one row; errors if the cell count mismatches the header.
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        anyhow::ensure!(
            cells.len() == self.ncols,
            "row has {} cells, header has {}",
            cells.len(),
            self.ncols
        );
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        self.write_raw(&refs)
    }

    fn write_raw(&mut self, cells: &[&str]) -> Result<()> {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                line.push('"');
                line.push_str(&c.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(c);
            }
        }
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Format a float for CSV/tables with sensible precision.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 || x.abs() < 0.001 {
        format!("{x:.4e}")
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"models": [{"name": "logreg", "dim": 7850}], "ok": true}"#,
        )
        .unwrap();
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(
            models[0].get("name").unwrap().as_str().unwrap(),
            "logreg"
        );
        assert_eq!(models[0].get("dim").unwrap().as_usize().unwrap(), 7850);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn csv_writer_quotes() {
        let dir = std::env::temp_dir().join("grab_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["x,y".to_string(), "plain".to_string()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",plain\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
