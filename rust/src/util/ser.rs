//! Serialization substrate: a minimal JSON value model (parser + writer),
//! a CSV writer, and the binary wire-frame layer used by the CD-GraB
//! socket transport.
//!
//! The JSON parser exists to read `artifacts/manifest.json` (written by
//! `python/compile/aot.py`); the writers emit experiment results under
//! `results/` and run metadata. Only the JSON subset json.dump produces is
//! required (no comments, `\uXXXX` escapes supported).
//!
//! The wire layer ([`FrameKind`], [`encode_frame`], [`decode_frame`],
//! [`read_frame`], [`write_frame`]) defines the length-prefixed,
//! checksummed little-endian frames that carry shard messages between a
//! CD-GraB coordinator and its workers; the message-level payload codecs
//! live in `ordering::transport::codec`. See `rust/README.md` for the
//! documented frame layout.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`, like json.dump emits).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, key-sorted for deterministic output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Read + parse a JSON file.
    pub fn from_file(path: &Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text)
    }

    // -- typed accessors ---------------------------------------------------

    /// Object member lookup; errors on non-objects / missing keys.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > 9_007_199_254_740_992.0 {
            bail!("not a non-negative integer: {x}");
        }
        // audit: allow(W01, reason = "f64 -> usize has no try_from; range-checked to [0, 2^53] above so the cast is exact")
        Ok(x as usize)
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    // audit: allow(W01, reason = "f64 -> i64 has no try_from; fract == 0 and |x| < 1e15 < 2^53 make the cast exact")
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if u32::from(c) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", u32::from(c));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let bytes = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    s.push_str(std::str::from_utf8(bytes)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("invalid number {text:?} at offset {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// CSV writer
// ---------------------------------------------------------------------------

/// Simple CSV writer with header enforcement. Used by the experiment
/// harness: one file per figure/table under `results/`.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    ncols: usize,
}

impl CsvWriter {
    /// Create (truncate) `path`, write `header`, fix the column count.
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = CsvWriter {
            file: std::io::BufWriter::new(file),
            ncols: header.len(),
        };
        w.write_raw(header)?;
        Ok(w)
    }

    /// Write one row; errors if the cell count mismatches the header.
    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        anyhow::ensure!(
            cells.len() == self.ncols,
            "row has {} cells, header has {}",
            cells.len(),
            self.ncols
        );
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        self.write_raw(&refs)
    }

    fn write_raw(&mut self, cells: &[&str]) -> Result<()> {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                line.push('"');
                line.push_str(&c.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(c);
            }
        }
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Binary wire frames (CD-GraB socket transport)
// ---------------------------------------------------------------------------

/// Wire protocol version stamped into every frame header. Bumped on any
/// incompatible layout change; peers reject mismatches with
/// [`WireError::BadVersion`] instead of misparsing.
///
/// History: 1 = the original frame set; 2 = `Hello` grew a `u32`
/// topology generation (elastic re-handshakes), so a v1 peer must be
/// turned away at the version check rather than die in `decode_hello`;
/// 3 = `Register`/`Lease` frames for the order-service daemon's
/// worker registry (workers dial in instead of being dialed).
pub const WIRE_VERSION: u8 = 3;

/// Bytes of the fixed frame header preceding every payload.
pub const FRAME_HEADER_LEN: usize = 12;

/// Hard upper bound on a frame payload (256 MiB). A corrupted or hostile
/// length prefix beyond this is rejected *before* any allocation, so a
/// bad header cannot make the receiver try to reserve terabytes.
pub const MAX_FRAME_PAYLOAD: usize = 1 << 28;

/// Frame type discriminant (header byte 1).
///
/// The `Hello`/`Ack` pair is the per-connection handshake; `Block` and
/// `EpochEnd` mirror the two coordinator→worker `ShardMsg` variants;
/// `Report` carries the worker→coordinator epoch-order report; `Seed`
/// restores a resumed shard balancer's next local order (checkpoint
/// resume — docs/determinism.md contract 8). `Register`/`Lease` are
/// the order-service daemon's worker-registry handshake: a worker
/// dials the daemon and registers once, then the daemon runs the
/// ordinary `Hello` session over the held socket each time the worker
/// is leased to a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Coordinator → worker: open a shard link (`local_n`, `d`).
    Hello = 1,
    /// Worker → coordinator: handshake accepted.
    Ack = 2,
    /// Coordinator → worker: one gathered `[rows × d]` gradient block.
    Block = 3,
    /// Coordinator → worker: epoch boundary — finalize and report.
    EpochEnd = 4,
    /// Worker → coordinator: the shard's next local epoch order.
    Report = 5,
    /// Coordinator → worker: re-seed the balancer's next local order
    /// from a checkpoint (only legal between epochs).
    Seed = 6,
    /// Worker → daemon: join the worker registry (capacity, name).
    Register = 7,
    /// Daemon → worker: registration accepted (worker id, registry
    /// generation).
    Lease = 8,
}

impl FrameKind {
    /// Decode a frame-kind byte; unknown values are a [`WireError`].
    pub fn from_byte(b: u8) -> Result<FrameKind, WireError> {
        Ok(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Ack,
            3 => FrameKind::Block,
            4 => FrameKind::EpochEnd,
            5 => FrameKind::Report,
            6 => FrameKind::Seed,
            7 => FrameKind::Register,
            8 => FrameKind::Lease,
            other => return Err(WireError::BadKind(other)),
        })
    }

    /// Encode this frame kind as its wire byte.
    pub const fn byte(self) -> u8 {
        // audit: allow(W01, reason = "fieldless repr(u8) enum to its declared discriminant; the cast is lossless by construction")
        self as u8
    }
}

/// Typed decode failures of the wire layer. Every malformed input maps to
/// one of these — decoding never panics and never partially applies.
#[derive(Debug, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the declared header or payload length.
    Truncated {
        /// Bytes required to finish the header/payload being read.
        needed: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// Header version byte differs from [`WIRE_VERSION`].
    BadVersion(u8),
    /// Unknown frame-kind byte.
    BadKind(u8),
    /// Header/payload checksum mismatch (corruption in transit).
    BadChecksum {
        /// Checksum stored in the header.
        stored: u32,
        /// Checksum recomputed over the received bytes.
        computed: u32,
    },
    /// Declared payload length exceeds [`MAX_FRAME_PAYLOAD`].
    Oversized {
        /// The declared payload length.
        declared: usize,
        /// The enforced maximum.
        max: usize,
    },
    /// Payload contents inconsistent with the message-level schema
    /// (wrong length for the declared row count, bad field value, …).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, got } => write!(
                f,
                "truncated frame: needed {needed} bytes, got {got}"
            ),
            WireError::BadVersion(v) => write!(
                f,
                "bad wire version {v} (expected {WIRE_VERSION})"
            ),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::BadChecksum { stored, computed } => write!(
                f,
                "frame checksum mismatch: stored {stored:#010x}, \
                 computed {computed:#010x}"
            ),
            WireError::Oversized { declared, max } => write!(
                f,
                "frame payload of {declared} bytes exceeds the \
                 {max}-byte cap"
            ),
            WireError::Malformed(why) => {
                write!(f, "malformed payload: {why}")
            }
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// Checked wire-width conversions
// ---------------------------------------------------------------------------
//
// The wire layers (this module, `ordering::transport::codec`,
// `service::http`) never use bare `as` casts between integer widths —
// audit rule W01 (`grab audit`, docs/audit.md). Widenings that are
// lossless on every supported target are concentrated in the two const
// fns below (the only waived casts); narrowings go through the checked
// helpers and surface a typed [`WireError`].

const _: () = assert!(usize::BITS >= 32, "wire layer assumes usize >= 32 bits");
const _: () = assert!(usize::BITS <= 64, "wire layer assumes usize <= 64 bits");

/// Lossless `u32` → `usize` widening (`usize` is at least 32 bits on
/// every supported target — const-asserted above).
pub const fn usize_from_u32(v: u32) -> usize {
    // audit: allow(W01, reason = "lossless widening: usize is at least 32 bits on every supported target (const-asserted)")
    v as usize
}

/// Lossless `usize` → `u64` widening (`usize` is at most 64 bits on
/// every supported target — const-asserted above).
pub const fn u64_from_usize(v: usize) -> u64 {
    // audit: allow(W01, reason = "lossless widening: usize is at most 64 bits on every supported target (const-asserted)")
    v as u64
}

/// Checked `u64` → `usize` narrowing; values over `usize::MAX` are a
/// [`WireError::Malformed`] (only reachable on 32-bit targets).
pub fn usize_from_u64(v: u64) -> Result<usize, WireError> {
    usize::try_from(v).map_err(|_| {
        WireError::Malformed(format!(
            "value {v} exceeds usize::MAX on this target"
        ))
    })
}

/// Checked `usize` → `u32` narrowing; values over `u32::MAX` are a
/// [`WireError::Oversized`].
pub fn u32_from_usize(v: usize) -> Result<u32, WireError> {
    u32::try_from(v).map_err(|_| WireError::Oversized {
        declared: v,
        max: usize_from_u32(u32::MAX),
    })
}

/// FNV-1a 32-bit hash — the frame checksum. Not cryptographic; it exists
/// to catch truncation, bit flips, and framing desync, and it keeps the
/// wire layer dependency-free. (Checkpoint files use the in-tree crc32
/// in `train::checkpoint` for the same integrity job; FNV-1a is used
/// here because the frame checksum must stream across header + payload
/// without a table, at a few instructions per byte.)
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    fnv1a32_continue(0x811c_9dc5, bytes)
}

/// Continue an FNV-1a stream from a previous hash state. The frame
/// checksum spans header and payload without materializing their
/// concatenation: `fnv1a32_continue(fnv1a32(header), payload)`.
pub fn fnv1a32_continue(seed: u32, bytes: &[u8]) -> u32 {
    let mut h = seed;
    for &b in bytes {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Append one frame (header + `payload`) to `out`.
///
/// Layout (all little-endian):
///
/// ```text
/// [0]      u8   version   = WIRE_VERSION
/// [1]      u8   kind      (FrameKind)
/// [2..4]   u16  reserved  = 0
/// [4..8]   u32  payload_len
/// [8..12]  u32  checksum  = fnv1a32(header[0..8] ++ payload)
/// [12..]   payload
/// ```
pub fn encode_frame(kind: FrameKind, payload: &[u8], out: &mut Vec<u8>) {
    assert!(
        payload.len() <= MAX_FRAME_PAYLOAD,
        "frame payload over protocol cap"
    );
    let start = out.len();
    out.push(WIRE_VERSION);
    out.push(kind.byte());
    out.extend_from_slice(&0u16.to_le_bytes());
    let len = u32_from_usize(payload.len())
        .expect("frame payload over protocol cap");
    out.extend_from_slice(&len.to_le_bytes());
    let sum =
        fnv1a32_continue(fnv1a32(&out[start..start + 8]), payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(payload);
}

/// Decode one frame from the front of `bytes`. Returns the kind, the
/// payload slice, and the total bytes consumed. Purely positional — the
/// caller can parse back-to-back frames from one buffer.
pub fn decode_frame(
    bytes: &[u8],
) -> Result<(FrameKind, &[u8], usize), WireError> {
    if bytes.len() < FRAME_HEADER_LEN {
        return Err(WireError::Truncated {
            needed: FRAME_HEADER_LEN,
            got: bytes.len(),
        });
    }
    if bytes[0] != WIRE_VERSION {
        return Err(WireError::BadVersion(bytes[0]));
    }
    let kind = FrameKind::from_byte(bytes[1])?;
    let len =
        usize_from_u32(u32::from_le_bytes(bytes[4..8].try_into().unwrap()));
    if len > MAX_FRAME_PAYLOAD {
        return Err(WireError::Oversized {
            declared: len,
            max: MAX_FRAME_PAYLOAD,
        });
    }
    let total = FRAME_HEADER_LEN + len;
    if bytes.len() < total {
        return Err(WireError::Truncated {
            needed: total,
            got: bytes.len(),
        });
    }
    let stored = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let payload = &bytes[FRAME_HEADER_LEN..total];
    let computed = fnv1a32_continue(fnv1a32(&bytes[0..8]), payload);
    if stored != computed {
        return Err(WireError::BadChecksum { stored, computed });
    }
    Ok((kind, payload, total))
}

/// Write one frame to an [`std::io::Write`] (single `write_all`, so a
/// frame is never interleaved with another writer on the same stream).
pub fn write_frame<W: std::io::Write>(
    w: &mut W,
    kind: FrameKind,
    payload: &[u8],
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    scratch.clear();
    encode_frame(kind, payload, scratch);
    w.write_all(scratch)
}

/// Errors produced by [`read_frame`]: transport-level I/O failures and
/// wire-level decode failures, kept distinct so callers can tell a dead
/// peer from a corrupt one.
#[derive(Debug)]
pub enum FrameReadError {
    /// The underlying reader failed (includes clean EOF mid-frame).
    Io(std::io::Error),
    /// The bytes arrived but do not form a valid frame.
    Wire(WireError),
}

impl std::fmt::Display for FrameReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameReadError::Io(e) => write!(f, "frame read i/o: {e}"),
            FrameReadError::Wire(e) => write!(f, "frame decode: {e}"),
        }
    }
}

impl std::error::Error for FrameReadError {}

/// Read exactly one frame from a blocking reader into `buf` (reused
/// across calls; grows to the largest frame seen). Returns the kind —
/// the payload is `buf[FRAME_HEADER_LEN..]`.
///
/// The header is validated *before* the payload is read, so an oversized
/// or wrong-version header fails fast without consuming the declared
/// payload length.
pub fn read_frame<R: std::io::Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
) -> Result<FrameKind, FrameReadError> {
    buf.clear();
    buf.resize(FRAME_HEADER_LEN, 0);
    r.read_exact(buf).map_err(FrameReadError::Io)?;
    if buf[0] != WIRE_VERSION {
        return Err(FrameReadError::Wire(WireError::BadVersion(buf[0])));
    }
    let kind =
        FrameKind::from_byte(buf[1]).map_err(FrameReadError::Wire)?;
    let len =
        usize_from_u32(u32::from_le_bytes(buf[4..8].try_into().unwrap()));
    if len > MAX_FRAME_PAYLOAD {
        return Err(FrameReadError::Wire(WireError::Oversized {
            declared: len,
            max: MAX_FRAME_PAYLOAD,
        }));
    }
    buf.resize(FRAME_HEADER_LEN + len, 0);
    r.read_exact(&mut buf[FRAME_HEADER_LEN..])
        .map_err(FrameReadError::Io)?;
    match decode_frame(buf) {
        Ok((k, _, _)) => Ok(k),
        Err(e) => Err(FrameReadError::Wire(e)),
    }
}

// ---------------------------------------------------------------------------
// Little-endian payload cursor (checkpoint snapshots, policy-state blobs)
// ---------------------------------------------------------------------------

/// Append a `u32` little-endian.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u64` little-endian.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append an `f64` as its IEEE-754 bit pattern.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Append a length-prefixed (`u64`) `f32` slice as raw bit patterns, so
/// NaN payloads and signed zeros round-trip bit-identically.
pub fn put_f32_slice(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, u64_from_usize(v.len()));
    for &x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Append a length-prefixed (`u64`) `usize` slice as `u64`s.
pub fn put_usize_slice(out: &mut Vec<u8>, v: &[usize]) {
    put_u64(out, u64_from_usize(v.len()));
    for &x in v {
        put_u64(out, u64_from_usize(x));
    }
}

/// Sequential little-endian reader over a serialized payload. Every
/// accessor returns a typed [`WireError`] on truncation — reading never
/// panics — and [`ByteReader::finish`] rejects trailing bytes, so a
/// payload parses exactly or not at all.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Start reading at the front of `buf`.
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    /// Consume the next `n` raw bytes ([`WireError::Truncated`] if
    /// fewer remain).
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated {
            needed: usize::MAX,
            got: self.buf.len(),
        })?;
        let s = self.buf.get(self.pos..end).ok_or(WireError::Truncated {
            needed: end,
            got: self.buf.len(),
        })?;
        self.pos = end;
        Ok(s)
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u64` and narrow it to `usize`, rejecting values over
    /// `max` (guards hostile length prefixes before any allocation).
    pub fn len(&mut self, max: usize) -> Result<usize, WireError> {
        let v = self.u64()?;
        if v > u64_from_usize(max) {
            return Err(WireError::Malformed(format!(
                "length prefix {v} exceeds the {max} cap"
            )));
        }
        usize_from_u64(v)
    }

    /// Read an `f64` bit pattern.
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a slice written by [`put_f32_slice`], capped at `max`
    /// elements.
    pub fn f32_slice(&mut self, max: usize) -> Result<Vec<f32>, WireError> {
        let n = self.len(max.min(self.remaining() / 4))?;
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// Read a slice written by [`put_usize_slice`], capped at `max`
    /// elements.
    pub fn usize_slice(&mut self, max: usize) -> Result<Vec<usize>, WireError> {
        let n = self.len(max.min(self.remaining() / 8))?;
        let bytes = self.take(n * 8)?;
        bytes
            .chunks_exact(8)
            .map(|c| usize_from_u64(u64::from_le_bytes(c.try_into().unwrap())))
            .collect()
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Consume and return every remaining byte (nested payloads that
    /// carry their own framing).
    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after the payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Format a float for CSV/tables with sensible precision.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 || x.abs() < 0.001 {
        format!("{x:.4e}")
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"models": [{"name": "logreg", "dim": 7850}], "ok": true}"#,
        )
        .unwrap();
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(
            models[0].get("name").unwrap().as_str().unwrap(),
            "logreg"
        );
        assert_eq!(models[0].get("dim").unwrap().as_usize().unwrap(), 7850);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn frame_roundtrips_and_reports_consumed_length() {
        let payload = [1u8, 2, 3, 250, 0, 9];
        let mut out = Vec::new();
        encode_frame(FrameKind::Block, &payload, &mut out);
        encode_frame(FrameKind::EpochEnd, &[], &mut out);
        let (kind, body, used) = decode_frame(&out).unwrap();
        assert_eq!(kind, FrameKind::Block);
        assert_eq!(body, &payload);
        assert_eq!(used, FRAME_HEADER_LEN + payload.len());
        let (kind2, body2, used2) = decode_frame(&out[used..]).unwrap();
        assert_eq!(kind2, FrameKind::EpochEnd);
        assert!(body2.is_empty());
        assert_eq!(used2, FRAME_HEADER_LEN);
    }

    #[test]
    fn frame_decode_rejects_each_corruption_mode() {
        let mut out = Vec::new();
        encode_frame(FrameKind::Report, &[7u8; 16], &mut out);

        // Truncated: any prefix shorter than the full frame.
        for cut in [0, 3, FRAME_HEADER_LEN, out.len() - 1] {
            assert!(matches!(
                decode_frame(&out[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // Bad version byte.
        let mut bad = out.clone();
        bad[0] = 0x7f;
        assert_eq!(
            decode_frame(&bad).unwrap_err(),
            WireError::BadVersion(0x7f)
        );
        // Unknown kind.
        let mut bad = out.clone();
        bad[1] = 99;
        assert_eq!(decode_frame(&bad).unwrap_err(), WireError::BadKind(99));
        // Flipped payload bit -> checksum mismatch.
        let mut bad = out.clone();
        *bad.last_mut().unwrap() ^= 1;
        assert!(matches!(
            decode_frame(&bad),
            Err(WireError::BadChecksum { .. })
        ));
        // Oversized length prefix rejected before any payload read.
        let mut bad = out.clone();
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_frame(&bad),
            Err(WireError::Oversized { .. })
        ));
    }

    #[test]
    fn read_frame_round_trips_through_io() {
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        write_frame(&mut wire, FrameKind::Hello, &[9, 9], &mut scratch)
            .unwrap();
        write_frame(&mut wire, FrameKind::Ack, &[], &mut scratch).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let mut buf = Vec::new();
        assert_eq!(
            read_frame(&mut cursor, &mut buf).unwrap(),
            FrameKind::Hello
        );
        assert_eq!(&buf[FRAME_HEADER_LEN..], &[9, 9]);
        assert_eq!(
            read_frame(&mut cursor, &mut buf).unwrap(),
            FrameKind::Ack
        );
        // Stream exhausted: clean EOF surfaces as an Io error.
        assert!(matches!(
            read_frame(&mut cursor, &mut buf),
            Err(FrameReadError::Io(_))
        ));
    }

    #[test]
    fn fnv1a32_matches_reference_vectors() {
        // Public FNV-1a test vectors.
        assert_eq!(fnv1a32(b""), 0x811c9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c292c);
        assert_eq!(fnv1a32(b"foobar"), 0xbf9cf968);
    }

    #[test]
    fn byte_reader_roundtrips_and_rejects_truncation() {
        let mut out = Vec::new();
        put_u32(&mut out, 7);
        put_u64(&mut out, u64::MAX);
        put_f64(&mut out, -0.0);
        put_f32_slice(&mut out, &[f32::NAN, 1.5]);
        put_usize_slice(&mut out, &[3, 1, 2]);
        let mut r = ByteReader::new(&out);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        let f = r.f32_slice(16).unwrap();
        assert_eq!(f.len(), 2);
        assert!(f[0].is_nan() && f[1] == 1.5);
        assert_eq!(r.usize_slice(16).unwrap(), vec![3, 1, 2]);
        r.finish().unwrap();

        // Truncation at every prefix is a typed error, never a panic.
        for cut in 0..out.len() {
            let mut r = ByteReader::new(&out[..cut]);
            let result = (|| -> Result<(), WireError> {
                r.u32()?;
                r.u64()?;
                r.f64()?;
                r.f32_slice(16)?;
                r.usize_slice(16)?;
                r.finish()
            })();
            assert!(result.is_err(), "cut={cut}");
        }
        // Hostile length prefix is rejected before allocation.
        let mut bad = Vec::new();
        put_u64(&mut bad, u64::MAX);
        assert!(ByteReader::new(&bad).f32_slice(16).is_err());
        // Trailing bytes are rejected.
        let mut extra = Vec::new();
        put_u32(&mut extra, 1);
        extra.push(0);
        let mut r = ByteReader::new(&extra);
        r.u32().unwrap();
        assert!(r.finish().is_err());
        assert_eq!(r.remaining(), 1);
    }

    #[test]
    fn csv_writer_quotes() {
        let dir = std::env::temp_dir().join("grab_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["x,y".to_string(), "plain".to_string()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",plain\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
