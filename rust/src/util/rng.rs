//! Deterministic PRNG substrate: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component in the system (dataset synthesis, Random
//! Reshuffling, Shuffle Once, the self-balancing walk of Algorithm 6) draws
//! from this generator, so any experiment is exactly reproducible from its
//! `seed` config field.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce
        // four zeros from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Snapshot the raw xoshiro state for checkpointing. The cached
    /// Box–Muller spare is *not* part of the snapshot: restore points
    /// are epoch boundaries, where ordering RNGs only ever consume
    /// uniform draws, so the spare is always empty there.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the stream
    /// continues exactly where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        let mut s = s;
        if s == [0, 0, 0, 0] {
            s[0] = 1; // all-zero state is invalid for xoshiro
        }
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 bits (upper half of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` via Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform index in `[0, bound)` — [`Rng::gen_range`] with the
    /// `usize` conversions done once here, so W01-scoped wire-layer
    /// tests can draw sizes without bare `as` casts.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (second value cached).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A fresh uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample from a categorical distribution given (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 17, 100] {
            let mut p = rng.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn shuffle_is_uniform_ish() {
        // Chi-square-ish sanity: position of element 0 over many shuffles.
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..5000 {
            let p = rng.permutation(5);
            counts[p.iter().position(|&v| v == 0).unwrap()] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(13);
        let mut hits = [0usize; 3];
        for _ in 0..9000 {
            hits[rng.categorical(&[1.0, 2.0, 6.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(0);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_snapshot_resumes_the_stream_exactly() {
        let mut rng = Rng::new(42);
        for _ in 0..17 {
            rng.next_u64();
        }
        let snap = rng.state();
        let tail: Vec<u64> = (0..32).map(|_| rng.next_u64()).collect();
        let mut resumed = Rng::from_state(snap);
        let tail2: Vec<u64> = (0..32).map(|_| resumed.next_u64()).collect();
        assert_eq!(tail, tail2);
    }
}
