//! Unique, self-cleaning temporary directories for tests.
//!
//! `cargo test` runs tests from one binary concurrently and runs
//! several test binaries (lib + each `tests/*.rs`) as separate
//! processes, so any test writing to a *fixed* path under
//! `std::env::temp_dir()` can collide with itself. [`TestDir`] makes
//! each call site unique — process id + an in-process counter + a
//! human-readable tag — and removes the tree on drop, so a panicking
//! test still cleans up when its guard unwinds.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

static COUNTER: AtomicUsize = AtomicUsize::new(0);

/// A freshly-created unique temp directory, deleted on drop.
pub struct TestDir(PathBuf);

impl TestDir {
    /// Create `<tmp>/grab-test-<pid>-<seq>-<tag>` (the tag names the
    /// test for post-mortem inspection of leaked trees).
    pub fn new(tag: &str) -> TestDir {
        let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "grab-test-{}-{}-{}",
            std::process::id(),
            seq,
            tag
        ));
        std::fs::create_dir_all(&dir)
            .unwrap_or_else(|e| panic!("creating {}: {e}", dir.display()));
        TestDir(dir)
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
