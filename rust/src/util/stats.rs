//! Statistics substrate: summaries, online accumulation, regression fits.
//!
//! Used by the bench harness (timing summaries), the experiment harness
//! (scaling-law slope fits for Statement 1 / Table 1), and metric tracking.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (linear interpolation).
    pub p50: f64,
    /// 95th percentile (linear interpolation).
    pub p95: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    ///
    /// Never panics, whatever the sample contains: ordering uses
    /// [`f64::total_cmp`], under which NaN with a cleared sign bit
    /// sorts *above* `+inf` and NaN with a set sign bit sorts *below*
    /// `-inf`. So a positive NaN sample lands in `max` (and can bleed
    /// into `p95`/`p50` by interpolation), a negative NaN lands in
    /// `min`, and `mean`/`std` are NaN whenever any sample is — the
    /// poison stays visible in the summary instead of killing the
    /// whole bench/metrics path.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Welford's online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// An empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one observation into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Running population variance (0 below two observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Running population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b, r2)`.
///
/// Statement 1 / Table 1 use this on log-log data to estimate scaling
/// exponents (e.g. greedy herding objective ~ n^1 vs random ~ n^0.5).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Log-log scaling exponent: fit `log(y) = a + b*log(x)` and return `b`.
pub fn scaling_exponent(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.max(1e-300).ln()).collect();
    linear_fit(&lx, &ly).1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_survives_nan_and_infinities() {
        // Regression: a single NaN timing/loss sample used to panic the
        // whole summary via `partial_cmp().unwrap()`. With total_cmp a
        // positive-bit NaN sorts above +inf (so it surfaces in `max`),
        // a negative-bit NaN sorts below -inf (so it surfaces in
        // `min`), and the moments go NaN instead of aborting.
        let s = Summary::of(&[1.0, f64::NAN, 2.0, 3.0]);
        assert_eq!(s.n, 4);
        assert!(s.mean.is_nan() && s.std.is_nan());
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());

        let s = Summary::of(&[f64::NEG_INFINITY, -1.0, f64::INFINITY]);
        assert_eq!(s.min, f64::NEG_INFINITY);
        assert_eq!(s.max, f64::INFINITY);
        assert_eq!(s.p50, -1.0);

        // NaN with the sign bit set lands at the bottom, not the top.
        let neg_nan = f64::from_bits(0xfff8_0000_0000_0001);
        let s = Summary::of(&[neg_nan, 0.0, 5.0, f64::INFINITY]);
        assert!(s.min.is_nan());
        assert_eq!(s.max, f64::INFINITY);

        // An all-NaN sample is still a summary, not a panic.
        let s = Summary::of(&[f64::NAN, f64::NAN]);
        assert!(s.min.is_nan() && s.max.is_nan() && s.p50.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn scaling_exponent_recovers_power_law() {
        let xs: Vec<f64> = (1..50).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(0.5)).collect();
        let b = scaling_exponent(&xs, &ys);
        assert!((b - 0.5).abs() < 1e-9, "b={b}");
    }
}
