//! Declarative CLI flag parser (clap is not in the vendored dep closure).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: positionals plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Arguments that are not `--key value` options or `--flag`s.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option names consumed via typed accessors (for unknown-flag checks).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I, S>(items: I) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut it = items.into_iter().map(Into::into).peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positional.
                    args.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment, skipping the program name.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// Whether boolean `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// The value of `--key`, if present.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    /// The value of `--key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt_str(key).unwrap_or_else(|| default.to_string())
    }

    /// The value of `--key`; errors when absent.
    pub fn req_str(&self, key: &str) -> Result<String> {
        self.opt_str(key)
            .ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// `--key` parsed as `usize`, or `default`; errors on non-integers.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt_str(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `--key` parsed as `u64`, or `default`; errors on non-integers.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt_str(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// `--key` parsed as `f64`, or `default`; errors on non-floats.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt_str(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a float, got {v:?}")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.opt_str(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Error on options/flags that were never consumed — catches typos.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.opts.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !seen.iter().any(|s| s == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        // NOTE: a bare `--flag value-like` pair binds as option+value, so
        // boolean flags go last or use another `--` after them.
        let a = Args::parse([
            "train", "extra", "--task", "mnist", "--epochs=5", "--verbose",
        ])
        .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.str_or("task", "x"), "mnist");
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_required_errors() {
        let a = Args::parse(["x"]).unwrap();
        assert!(a.req_str("task").is_err());
    }

    #[test]
    fn bad_int_errors() {
        let a = Args::parse(["--n", "abc"]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(["--orders", "rr, grab,so"]).unwrap();
        assert_eq!(a.list_or("orders", &[]), vec!["rr", "grab", "so"]);
    }

    #[test]
    fn unknown_rejected() {
        let a = Args::parse(["--task", "mnist", "--oops", "1"]).unwrap();
        let _ = a.str_or("task", "");
        assert!(a.reject_unknown().is_err());
        let _ = a.opt_str("oops");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn double_dash_terminator() {
        let a = Args::parse(["--a", "1", "--", "--b", "2"]).unwrap();
        assert_eq!(a.positional, vec!["--b", "2"]);
    }
}
