//! Foundation utilities built in-tree (the vendored dependency closure only
//! covers the `xla` crate, so PRNG, serialization, CLI parsing and stats are
//! first-class substrates of this repo rather than external crates).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod ser;
pub mod stats;
pub mod testdir;
pub mod timer;
