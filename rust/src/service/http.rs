//! Minimal HTTP/1.1 layer for the daemon's control plane.
//!
//! Hand-rolled over `std::net` because the vendored dependency closure
//! has no HTTP crate — and the control plane needs almost nothing:
//! request line + headers + optional `Content-Length` body in, one
//! `Connection: close` response out, one TCP connection per exchange.
//! The same file carries the tiny blocking client used by
//! `grab exp cdgrab --service`, the tests, and the CI smoke (instead
//! of curl, where curl is not guaranteed).
//!
//! Deliberate non-goals: keep-alive, chunked encoding, TLS, header
//! continuation lines. Requests are capped (16 KiB of headers, 1 MiB
//! of body) so a hostile peer cannot make the daemon buffer without
//! bound.

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::util::ser::Json;

/// Max bytes of request line + headers the server will buffer.
const MAX_HEAD: usize = 16 * 1024;
/// Max request body bytes the server will buffer.
const MAX_BODY: usize = 1024 * 1024;

/// A parsed inbound request: method, path, raw body.
#[derive(Debug)]
pub struct Request {
    /// Uppercase method token (`GET`, `POST`, …).
    pub method: String,
    /// Request target as sent (e.g. `/jobs/3`); query strings are not
    /// split off because no route uses them.
    pub path: String,
    /// Raw request body (`Content-Length` bytes; empty when absent).
    pub body: Vec<u8>,
}

/// Read one request off `stream`. Errors on malformed request lines,
/// over-cap heads/bodies, or a peer that hangs up mid-request; the
/// caller answers errors with a `400` (or just drops the socket).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    // Accumulate until the blank line separating head from body.
    let mut buf: Vec<u8> = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    let head_end = loop {
        if let Some(pos) =
            buf.windows(4).position(|w| w == b"\r\n\r\n")
        {
            break pos + 4;
        }
        if buf.len() > MAX_HEAD {
            bail!("request head over {MAX_HEAD} bytes");
        }
        let got = stream.read(&mut chunk).context("reading request")?;
        if got == 0 {
            bail!("peer closed mid-request ({} bytes in)", buf.len());
        }
        buf.extend_from_slice(&chunk[..got]);
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = match parts.next() {
        Some(m) if !m.is_empty() => m.to_ascii_uppercase(),
        _ => bail!("empty request line"),
    };
    let path = match parts.next() {
        Some(p) => p.to_string(),
        None => bail!("request line has no path: {request_line:?}"),
    };
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        other => bail!("not an HTTP/1.x request: {other:?}"),
    }

    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .context("unparseable Content-Length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("request body of {content_length} bytes over {MAX_BODY} cap");
    }

    let mut body = buf[head_end..].to_vec();
    while body.len() < content_length {
        let got = stream.read(&mut chunk).context("reading body")?;
        if got == 0 {
            bail!(
                "peer closed mid-body ({} of {content_length} bytes)",
                body.len()
            );
        }
        body.extend_from_slice(&chunk[..got]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body })
}

/// The reason phrase for the handful of statuses the daemon emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

/// Write a complete `Connection: close` response and flush it. The
/// caller drops the stream afterwards; the close is the end-of-response
/// marker the client relies on.
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// [`respond`] with a JSON body.
pub fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    body: &Json,
) -> Result<()> {
    respond(
        stream,
        status,
        "application/json",
        body.to_string().as_bytes(),
    )
}

/// Blocking one-shot client request: returns `(status, body)`. Reads
/// to EOF (the server closes after each response).
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("dialing control plane {addr}"))?;
    stream.set_nodelay(true)?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;

    let mut raw = Vec::new();
    stream
        .read_to_end(&mut raw)
        .context("reading response")?;
    let text = String::from_utf8_lossy(&raw);
    let head_end = text
        .find("\r\n\r\n")
        .context("response has no header terminator")?;
    let status_line = text.lines().next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("response has no status code")?
        .parse()
        .context("unparseable status code")?;
    Ok((status, text[head_end + 4..].to_string()))
}

/// `GET path` against `addr`.
pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
    request(addr, "GET", path, None)
}

/// `POST path` with a JSON string body against `addr`.
pub fn post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    request(addr, "POST", path, Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    // Every test here drives a real loopback socket, which Miri cannot
    // model — hence the `cfg_attr(miri, ignore)` gates. The pure
    // parsing layers these tests exercise are covered under Miri via
    // the codec and ser unit suites.

    /// One server turn: parse a request, apply `f`, send its response.
    fn serve_once<F>(f: F) -> String
    where
        F: FnOnce(Result<Request>, &mut TcpStream) + Send + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream);
            f(req, &mut stream);
        });
        addr
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn request_and_response_round_trip() {
        let addr = serve_once(|req, stream| {
            let req = req.unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/jobs");
            assert_eq!(req.body, b"{\"n\":8}");
            respond_json(
                stream,
                202,
                &crate::util::ser::obj(vec![(
                    "job",
                    Json::Num(0.0),
                )]),
            )
            .unwrap();
        });
        let (status, body) = post(&addr, "/jobs", "{\"n\":8}").unwrap();
        assert_eq!(status, 202);
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("job").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn get_carries_no_body_and_any_status_parses() {
        let addr = serve_once(|req, stream| {
            let req = req.unwrap();
            assert_eq!(req.method, "GET");
            assert!(req.body.is_empty());
            respond(stream, 404, "text/plain", b"nope").unwrap();
        });
        let (status, body) = get(&addr, "/missing").unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, "nope");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn garbage_request_line_is_rejected_not_panicked() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            read_request(&mut stream).is_err()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"not http at all\r\n\r\n").unwrap();
        drop(c);
        assert!(h.join().unwrap(), "garbage must parse as an error");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn oversized_head_is_refused() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            read_request(&mut stream).is_err()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // A request line that never terminates its head.
        let junk = vec![b'a'; MAX_HEAD + 1024];
        let _ = c.write_all(b"GET /");
        let _ = c.write_all(&junk);
        let _ = c.flush();
        assert!(h.join().unwrap(), "oversized head must be an error");
    }
}
