//! `grab exp cdgrab --service ADDR` — submit a CD-GraB job to a
//! running `grab serve` daemon and *gate* the result: the daemon's
//! per-epoch order hashes must be bit-equal to a local in-process
//! synchronous run of the same `(n, d, block, W, seed)` — determinism
//! contract 5 (docs/determinism.md) carried over the registered-worker
//! path — and the daemon's `/metrics` transport counters must cover
//! the job's own reported link totals. Writes `service_job.csv` (one
//! row per epoch: daemon vs local hash + herding bound) to the results
//! directory.
//!
//! The shard count is taken from the daemon's fleet: whatever
//! `workers_available` reports at submission time (the job leases the
//! whole idle fleet). The local reference run uses the same W, so the
//! gate is exact whatever fleet size the daemon happens to hold.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::exp::cdgrab::CdGrabConfig;
use crate::ordering::{OrderPolicy, ShardedOrder};
use crate::service::{http, order_hash, JobKind, JobSpec};
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{fmt_f, CsvWriter, Json};

/// Poll cadence while waiting on the daemon job.
const POLL_EVERY: Duration = Duration::from_millis(100);

/// Run one job against the daemon at `addr` (control-plane address)
/// and verify it against a local reference run. See the module doc.
pub fn run_job_against_daemon(
    addr: &str,
    cfg: &CdGrabConfig,
    out_dir: &Path,
) -> Result<()> {
    // Size the job to the daemon's idle fleet.
    let (status, health) = http::get(addr, "/health")
        .with_context(|| format!("GET /health on {addr}"))?;
    anyhow::ensure!(status == 200, "/health answered {status}: {health}");
    let health = Json::parse(&health).context("parsing /health")?;
    let shards = health.get("workers_available")?.as_usize()?;
    anyhow::ensure!(
        shards >= 1,
        "daemon at {addr} has no registered workers; start some with \
         `grab exp cdgrab --register <registry addr>`"
    );
    let spec = JobSpec {
        kind: JobKind::CdGrab,
        n: cfg.n,
        d: cfg.d,
        epochs: cfg.epochs,
        block: cfg.block,
        shards: shards.min(64).min(cfg.n),
        seed: cfg.seed,
        admit_rate: 0,
    };
    eprintln!(
        "[service] submitting n={} d={} epochs={} block={} W={} to {addr}",
        spec.n, spec.d, spec.epochs, spec.block, spec.shards
    );

    let (status, body) =
        http::post(addr, "/jobs", &spec.to_json().to_string())
            .context("POST /jobs")?;
    anyhow::ensure!(
        status == 202,
        "job submission answered {status}: {body}"
    );
    let job_id = Json::parse(&body)?.get("job")?.as_usize()?;

    // Wait for the job: bounded by the links' own read timeout per
    // epoch plus slack, so a wedged daemon fails loudly instead of
    // hanging the client forever.
    let deadline = Instant::now()
        + Duration::from_secs(
            60 + spec.epochs as u64 * cfg.read_timeout_secs,
        );
    let job = loop {
        anyhow::ensure!(
            Instant::now() < deadline,
            "job {job_id} still not finished at the polling deadline"
        );
        std::thread::sleep(POLL_EVERY);
        let (status, body) =
            http::get(addr, &format!("/jobs/{job_id}"))?;
        anyhow::ensure!(
            status == 200,
            "GET /jobs/{job_id} answered {status}: {body}"
        );
        let job = Json::parse(&body)?;
        match job.get("status")?.as_str()? {
            "running" => continue,
            "done" => break job,
            "failed" => {
                let why = job
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown");
                anyhow::bail!("daemon job {job_id} failed: {why}");
            }
            other => anyhow::bail!("unknown job status {other:?}"),
        }
    };

    let daemon_hashes: Vec<u32> = job
        .get("epoch_hashes")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|x| x as u32))
        .collect::<Result<_>>()?;
    let daemon_herd: Vec<f64> = job
        .get("herd_inf")?
        .as_arr()?
        .iter()
        .map(Json::as_f64)
        .collect::<Result<_>>()?;
    let job_tx = job.get("tx_bytes")?.as_f64()? as u64;
    let job_rx = job.get("rx_bytes")?.as_f64()? as u64;
    anyhow::ensure!(
        daemon_hashes.len() == spec.epochs,
        "daemon reported {} epoch hashes for {} epochs",
        daemon_hashes.len(),
        spec.epochs
    );
    anyhow::ensure!(
        job_tx > 0 && job_rx > 0,
        "daemon job moved no bytes (tx={job_tx}, rx={job_rx}) — the \
         session cannot have run over worker links"
    );

    // Local reference: the synchronous in-process coordinator at the
    // same parameters. Contract 5 says the orders must match the
    // daemon's TCP session bit-for-bit.
    let mut rng = Rng::new(spec.seed);
    let vs = gen::vec_set(&mut rng, spec.n, spec.d);
    let mut flat = vec![0.0f32; spec.n * spec.d];
    let mut policy = ShardedOrder::new(spec.n, spec.d, spec.shards);
    let mut local_hashes = Vec::with_capacity(spec.epochs);
    let mut local_herd = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        crate::ordering::stream_static_epoch(
            &mut policy,
            epoch,
            &vs,
            &mut flat,
            spec.block,
        );
        let order = policy.epoch_order(epoch + 1);
        local_hashes.push(order_hash(order));
        let (inf, _) = crate::herding::herding_bound(&vs, order);
        local_herd.push(inf as f64);
    }

    let mut csv = CsvWriter::create(
        &out_dir.join("service_job.csv"),
        &[
            "epoch",
            "daemon_hash",
            "local_hash",
            "daemon_herd_inf",
            "local_herd_inf",
        ],
    )?;
    for e in 0..spec.epochs {
        csv.row(&[
            e.to_string(),
            format!("{:08x}", daemon_hashes[e]),
            format!("{:08x}", local_hashes[e]),
            fmt_f(daemon_herd[e]),
            fmt_f(local_herd[e]),
        ])?;
    }
    csv.flush()?;

    anyhow::ensure!(
        daemon_hashes == local_hashes,
        "daemon orders diverge from the in-process reference \
         (contract 5 violation): daemon {daemon_hashes:x?} vs local \
         {local_hashes:x?}"
    );
    for (e, (a, b)) in
        daemon_herd.iter().zip(local_herd.iter()).enumerate()
    {
        anyhow::ensure!(
            (a - b).abs() <= 1e-6 * b.abs().max(1.0),
            "epoch {e} herding bound diverges: daemon {a} vs local {b}"
        );
    }

    // The daemon's exported transport counters must cover this job's
    // own totals (they fold in at each job boundary).
    let (status, metrics) = http::get(addr, "/metrics")?;
    anyhow::ensure!(status == 200, "/metrics answered {status}");
    let metric_tx = metric_value(&metrics, "grab_transport_tx_bytes_total")
        .context("missing grab_transport_tx_bytes_total")?;
    let metric_rx = metric_value(&metrics, "grab_transport_rx_bytes_total")
        .context("missing grab_transport_rx_bytes_total")?;
    anyhow::ensure!(
        metric_tx >= job_tx && metric_rx >= job_rx,
        "/metrics transport counters (tx={metric_tx}, rx={metric_rx}) \
         below this job's totals (tx={job_tx}, rx={job_rx})"
    );

    eprintln!(
        "[service] job {job_id} verified: {} epochs bit-equal to the \
         in-process reference at W={}; {} B tx / {} B rx over worker \
         links (results: {})",
        spec.epochs,
        spec.shards,
        job_tx,
        job_rx,
        out_dir.join("service_job.csv").display()
    );
    Ok(())
}

/// Pull one counter/gauge value out of a Prometheus text exposition.
fn metric_value(text: &str, name: &str) -> Option<u64> {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse().ok();
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_values_parse_out_of_exposition_text() {
        let text = "# HELP grab_x Things.\n# TYPE grab_x counter\n\
                    grab_x 42\ngrab_x_total 7\n";
        assert_eq!(metric_value(text, "grab_x"), Some(42));
        assert_eq!(metric_value(text, "grab_x_total"), Some(7));
        assert_eq!(metric_value(text, "grab_y"), None);
    }
}
