//! Worker registry for the order-service daemon.
//!
//! Workers dial the daemon and *register* (a `Register`/`Lease`
//! handshake over the shard wire protocol); the daemon parks each
//! accepted socket here until a job leases it. The registry is pure
//! bookkeeping — generic over the held link type `S` so the daemon can
//! hold `TcpStream`s while the property tests drive the same state
//! machine with `()` links and no sockets at all.
//!
//! Invariants the tests pin down:
//!
//! - worker ids are unique and strictly increasing for the lifetime of
//!   a registry (an id is never reused, even after its socket is gone);
//! - leasing is **all-or-nothing**: a lease that cannot be filled
//!   leaves the available pool untouched;
//! - leases are filled in registration order (FIFO), so a stable fleet
//!   yields a deterministic shard → worker assignment (the order the
//!   coordinator's `Hello`s go out in — docs/determinism.md contract 5
//!   makes the *orders* independent of this, but a deterministic
//!   assignment keeps logs and metrics reproducible);
//! - one lease covers one job session: `complete` forgets the leased
//!   slots instead of returning them (the daemon closes the sockets at
//!   the job boundary and live workers re-register fresh).

use std::fmt;

/// A registered worker: identity plus the held link.
#[derive(Debug)]
pub struct Slot<S> {
    /// Registry-assigned worker id (unique per daemon lifetime).
    pub id: u32,
    /// Self-reported worker name (e.g. `worker-<pid>`).
    pub name: String,
    /// Self-reported shard capacity (currently always 1).
    pub capacity: u32,
    /// The held connection (a `TcpStream` in the daemon; `()` in
    /// state-machine tests).
    pub link: S,
}

/// Typed registry/control-plane failures, surfaced as HTTP error
/// bodies by the daemon.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// A job asked for more workers than are currently registered and
    /// idle.
    NotEnoughWorkers {
        /// Workers available to lease right now.
        have: usize,
        /// Workers the job asked for.
        need: usize,
    },
    /// The daemon is draining and refuses new work.
    Draining,
    /// A job id that the daemon has never issued.
    UnknownJob(u64),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::NotEnoughWorkers { have, need } => write!(
                f,
                "need {need} registered worker(s), have {have} \
                 (start more with `grab exp cdgrab --register ADDR`)"
            ),
            ServiceError::Draining => {
                write!(f, "daemon is draining; not accepting new work")
            }
            ServiceError::UnknownJob(id) => {
                write!(f, "no such job {id}")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// The daemon's worker table: available slots (holding live sockets),
/// lease bookkeeping, and the counters behind `/metrics`.
#[derive(Debug)]
pub struct Registry<S> {
    generation: u32,
    next_id: u32,
    available: Vec<Slot<S>>,
    /// `(job id, worker id, worker name)` per leased slot.
    leased: Vec<(u64, u32, String)>,
    registrations_total: u64,
    registrations_refused: u64,
}

impl<S> Registry<S> {
    /// An empty registry at the given generation (`>= 1`; workers send
    /// generation 0 to mean "fresh registration", so a live registry
    /// can never be at 0).
    pub fn new(generation: u32) -> Registry<S> {
        assert!(generation >= 1, "registry generation 0 is reserved");
        Registry {
            generation,
            next_id: 0,
            available: Vec::new(),
            leased: Vec::new(),
            registrations_total: 0,
            registrations_refused: 0,
        }
    }

    /// The registry generation carried in every `Lease`.
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// The id the *next* successful [`register`](Self::register) will
    /// assign — lets the daemon write the `Lease` reply before moving
    /// the socket into the table (both under one registry lock).
    pub fn next_worker_id(&self) -> u32 {
        self.next_id
    }

    /// Park a worker's link; returns the assigned worker id.
    pub fn register(&mut self, name: &str, capacity: u32, link: S) -> u32 {
        let id = self.next_id;
        self.next_id += 1;
        self.registrations_total += 1;
        self.available.push(Slot {
            id,
            name: name.to_string(),
            capacity,
            link,
        });
        id
    }

    /// Count a refused registration (draining, stale generation, or a
    /// malformed handshake) for `/metrics`.
    pub fn refuse(&mut self) {
        self.registrations_refused += 1;
    }

    /// Lease `count` workers to `job`, all-or-nothing and in
    /// registration (FIFO) order. On success the slots — sockets and
    /// all — move to the caller; the registry keeps only the
    /// `(job, id, name)` bookkeeping until [`complete`](Self::complete).
    pub fn lease(
        &mut self,
        count: usize,
        job: u64,
    ) -> Result<Vec<Slot<S>>, ServiceError> {
        if count == 0 || self.available.len() < count {
            return Err(ServiceError::NotEnoughWorkers {
                have: self.available.len(),
                need: count,
            });
        }
        let slots: Vec<Slot<S>> = self.available.drain(..count).collect();
        for s in &slots {
            self.leased.push((job, s.id, s.name.clone()));
        }
        Ok(slots)
    }

    /// Forget the lease bookkeeping for `job` (its sockets were
    /// consumed by the session and closed at the job boundary); returns
    /// how many slots the job held.
    pub fn complete(&mut self, job: u64) -> usize {
        let before = self.leased.len();
        self.leased.retain(|(j, _, _)| *j != job);
        before - self.leased.len()
    }

    /// Workers registered and idle.
    pub fn available(&self) -> usize {
        self.available.len()
    }

    /// Workers currently leased to running jobs.
    pub fn leased(&self) -> usize {
        self.leased.len()
    }

    /// `(worker id, name)` pairs leased to `job`, in lease order.
    pub fn leased_to(&self, job: u64) -> Vec<(u32, String)> {
        self.leased
            .iter()
            .filter(|(j, _, _)| *j == job)
            .map(|(_, id, name)| (*id, name.clone()))
            .collect()
    }

    /// Successful registrations, lifetime total.
    pub fn registrations_total(&self) -> u64 {
        self.registrations_total
    }

    /// Refused registrations, lifetime total.
    pub fn registrations_refused(&self) -> u64 {
        self.registrations_refused
    }

    /// Take every idle link out of the table (the drain path: dropping
    /// the returned `TcpStream`s is a clean EOF to workers sitting
    /// *between* job sessions — never mid-epoch).
    pub fn drain_links(&mut self) -> Vec<S> {
        self.available.drain(..).map(|s| s.link).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ids_are_unique_and_monotone() {
        let mut r: Registry<()> = Registry::new(1);
        let a = r.register("a", 1, ());
        let b = r.register("b", 1, ());
        assert_eq!((a, b), (0, 1));
        // Lease + complete must not recycle ids.
        let _ = r.lease(2, 0).unwrap();
        r.complete(0);
        let c = r.register("c", 1, ());
        assert_eq!(c, 2);
        assert_eq!(r.next_worker_id(), 3);
    }

    #[test]
    fn lease_is_all_or_nothing_and_fifo() {
        let mut r: Registry<()> = Registry::new(1);
        r.register("w0", 1, ());
        r.register("w1", 1, ());
        // Too many: the pool must be untouched.
        let err = r.lease(3, 7).unwrap_err();
        assert_eq!(err, ServiceError::NotEnoughWorkers { have: 2, need: 3 });
        assert_eq!(r.available(), 2);
        assert_eq!(r.leased(), 0);
        // Zero is a caller bug, also refused.
        assert!(r.lease(0, 7).is_err());
        // FIFO: first registered, first leased.
        let slots = r.lease(2, 7).unwrap();
        let ids: Vec<u32> = slots.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(r.leased_to(7), vec![(0, "w0".into()), (1, "w1".into())]);
        assert_eq!(r.available(), 0);
        assert_eq!(r.complete(7), 2);
        assert_eq!(r.leased(), 0);
    }

    #[test]
    fn counters_track_registrations_and_refusals() {
        let mut r: Registry<()> = Registry::new(3);
        assert_eq!(r.generation(), 3);
        r.register("a", 1, ());
        r.refuse();
        r.register("b", 2, ());
        assert_eq!(r.registrations_total(), 2);
        assert_eq!(r.registrations_refused(), 1);
        assert_eq!(r.drain_links().len(), 2);
        assert_eq!(r.available(), 0);
    }

    /// Random op sequences keep the table's counts consistent and the
    /// id stream strictly increasing — the state-machine property the
    /// daemon's accounting (and `/metrics` gauges) rests on.
    #[test]
    fn random_op_sequences_preserve_invariants() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(0xc0ffee ^ seed);
            let mut r: Registry<()> = Registry::new(1);
            let mut live_jobs: Vec<u64> = Vec::new();
            let mut next_job = 0u64;
            let mut last_id_seen: Option<u32> = None;
            let mut expected_leased = 0usize;
            let mut expected_available = 0usize;
            for _ in 0..200 {
                match rng.gen_range(3) {
                    0 => {
                        let id = r.register("w", 1, ());
                        if let Some(prev) = last_id_seen {
                            assert!(id > prev, "id stream must increase");
                        }
                        last_id_seen = Some(id);
                        expected_available += 1;
                    }
                    1 => {
                        let want = rng.gen_range(4) as usize + 1;
                        let job = next_job;
                        match r.lease(want, job) {
                            Ok(slots) => {
                                assert_eq!(slots.len(), want);
                                next_job += 1;
                                live_jobs.push(job);
                                expected_available -= want;
                                expected_leased += want;
                            }
                            Err(ServiceError::NotEnoughWorkers {
                                have,
                                need,
                            }) => {
                                assert_eq!(have, expected_available);
                                assert_eq!(need, want);
                            }
                            Err(other) => panic!("unexpected {other}"),
                        }
                    }
                    _ => {
                        if let Some(job) = live_jobs.pop() {
                            let freed = r.complete(job);
                            assert!(freed >= 1);
                            expected_leased -= freed;
                        }
                    }
                }
                assert_eq!(r.available(), expected_available);
                assert_eq!(r.leased(), expected_leased);
            }
        }
    }
}
