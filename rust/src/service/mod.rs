//! `grab serve` — the long-running order-service daemon.
//!
//! Inverts PR 3's connection topology: instead of the coordinator
//! dialing worker servers (`--connect`), workers dial the daemon and
//! **register** (`grab exp cdgrab --register ADDR`), and the daemon
//! parks their sockets in a [`registry::Registry`] until a job leases
//! them. Jobs arrive over a dependency-free HTTP/1.1 control plane
//! ([`http`]) and run *inside the daemon*: each leased socket becomes a
//! [`crate::ordering::transport::tcp::TcpTransport`] via `from_stream`
//! (the ordinary `Hello` shard session, just over an already-open
//! connection) and the links compose into a
//! [`crate::ordering::ShardedOrder`] through its public `from_links`
//! constructor. The orders a daemon job produces are therefore
//! bit-equal to the in-process backends at the same `(n, d, block, W)`
//! — docs/determinism.md contract 5 — which `grab exp cdgrab
//! --service` and the service test layer both assert.
//!
//! Jobs come in two kinds ([`JobKind`]): the classic `cdgrab` static
//! epoch loop, and `stream` — a sliding-reservoir
//! [`crate::ordering::StreamOrder`] over the same leased links, driven
//! by a frozen count-neutral [`DriftPlan::steady`] churn schedule
//! (`admit_rate` fresh units per window, FIFO eviction retiring as
//! many). Stream jobs report per-window order hashes and herding
//! bounds through `GET /jobs/<id>` and reservoir counters through
//! `/metrics`; contract 9 (docs/determinism.md) makes them bit-equal
//! to an in-process reservoir replaying the same frozen schedule.
//!
//! Control plane (all responses `Connection: close`):
//!
//! | route                | what                                        |
//! |----------------------|---------------------------------------------|
//! | `GET /health`        | liveness + worker/job gauges (JSON)         |
//! | `GET /metrics`       | Prometheus text exposition                  |
//! | `POST /jobs`         | submit a job (JSON spec) → `202 {job: id}`  |
//! | `GET /jobs`          | id + status of every job (JSON)             |
//! | `GET /jobs/<id>`     | full record: per-epoch order hashes,        |
//! |                      | herding bounds, link counters (JSON)        |
//! | `POST /drain`        | begin drain (same path as SIGTERM)          |
//!
//! Shutdown is drain-then-exit: SIGTERM (or `POST /drain`) stops new
//! registrations and job submissions, lets running jobs finish — a
//! leased socket is only ever closed at the job boundary, so a worker
//! is never detached mid-epoch (contracts 5/6 are per-session) — then
//! closes the idle held sockets (a clean between-sessions EOF) and
//! exits. Registered workers observe the closed socket + refused
//! re-registration and exit 0.

pub mod client;
pub mod http;
pub mod registry;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::herding::herding_bound;
use crate::ordering::topology::Topology;
use crate::ordering::transport::codec::{
    decode_register, encode_lease, Lease,
};
use crate::ordering::stream::{DriftPlan, StreamOrder, StreamStats};
use crate::ordering::transport::tcp;
use crate::ordering::transport::{LinkStats, ShardTransport};
use crate::ordering::{OrderPolicy, ShardedOrder};
use crate::util::cli::Args;
use crate::util::prop::gen;
use crate::util::rng::Rng;
use crate::util::ser::{
    self, obj, read_frame, write_frame, FrameKind, Json, FRAME_HEADER_LEN,
};

/// How long the daemon waits on a dialing worker's `Register` frame
/// before giving up on the handshake (bounds how long a dead dialer
/// can stall the registration accept loop).
const REGISTER_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Daemon launch parameters (`grab serve`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker registration listener (`--listen`, wire protocol).
    pub register_addr: String,
    /// Control-plane listener (`--http`, HTTP/1.1).
    pub http_addr: String,
    /// Per-frame read timeout (seconds) on leased worker links during
    /// a job session (`--read-timeout`).
    pub read_timeout_secs: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            register_addr: "127.0.0.1:7470".to_string(),
            http_addr: "127.0.0.1:7471".to_string(),
            read_timeout_secs: tcp::DEFAULT_READ_TIMEOUT_SECS,
        }
    }
}

/// Which session loop a daemon job runs over its leased links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// The CD-GraB static-gradient epoch loop of `exp cdgrab`.
    CdGrab,
    /// The sliding-reservoir streaming loop: a [`StreamOrder`] over
    /// the leased links driven by a count-neutral
    /// [`DriftPlan::steady`] schedule (`admit_rate` fresh units per
    /// window, FIFO eviction retiring as many), one window per
    /// "epoch". Count-neutrality is what lets the reservoir run over
    /// *fixed* daemon-leased sockets: the live count never changes, so
    /// no boundary ever needs a re-link.
    Stream,
}

impl JobKind {
    /// Stable kind label for JSON/logs.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::CdGrab => "cdgrab",
            JobKind::Stream => "stream",
        }
    }
}

/// What one daemon job runs, at a fixed shard count, over leased
/// worker links: the `exp cdgrab` static epoch loop
/// ([`JobKind::CdGrab`]) or the sliding-reservoir streaming loop
/// ([`JobKind::Stream`]).
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    /// Session loop to run.
    pub kind: JobKind,
    /// Number of static gradient vectors (stream: reservoir capacity
    /// and initial fill).
    pub n: usize,
    /// Gradient dimension.
    pub d: usize,
    /// Epochs (balance passes; stream: windows).
    pub epochs: usize,
    /// Observe block width.
    pub block: usize,
    /// Shard count = leased workers (one shard per worker).
    pub shards: usize,
    /// Seed for the synthetic gradient set (stream: the drift plan).
    pub seed: u64,
    /// Stream jobs only: fresh units admitted per window (FIFO
    /// eviction keeps the live count at `n`). Must be 0 for cdgrab
    /// jobs; 0 on a stream job means a static membership (no churn).
    pub admit_rate: usize,
}

impl JobSpec {
    /// Parse + validate a spec from a `POST /jobs` JSON body. Caps are
    /// deliberate: the daemon allocates `n * d` floats per job, and an
    /// unauthenticated control plane must not be a memory-exhaustion
    /// vector.
    pub fn from_json(v: &Json) -> Result<JobSpec> {
        // `kind`/`admit_rate` are optional so PR-6-era cdgrab clients
        // keep working unchanged.
        let kind = match v.get("kind") {
            Ok(k) => match k.as_str()? {
                "cdgrab" => JobKind::CdGrab,
                "stream" => JobKind::Stream,
                other => anyhow::bail!(
                    "unknown job kind {other:?} (want cdgrab|stream)"
                ),
            },
            Err(_) => JobKind::CdGrab,
        };
        let admit_rate = match v.get("admit_rate") {
            Ok(x) => x.as_usize()?,
            Err(_) => 0,
        };
        let spec = JobSpec {
            kind,
            n: v.get("n")?.as_usize()?,
            d: v.get("d")?.as_usize()?,
            epochs: v.get("epochs")?.as_usize()?,
            block: v.get("block")?.as_usize()?,
            shards: v.get("shards")?.as_usize()?,
            seed: v.get("seed")?.as_f64()? as u64,
            admit_rate,
        };
        anyhow::ensure!(
            (1..=1 << 20).contains(&spec.n),
            "n must be in 1..=2^20, got {}",
            spec.n
        );
        anyhow::ensure!(
            (1..=16384).contains(&spec.d),
            "d must be in 1..=16384, got {}",
            spec.d
        );
        anyhow::ensure!(
            (1..=512).contains(&spec.epochs),
            "epochs must be in 1..=512, got {}",
            spec.epochs
        );
        anyhow::ensure!(spec.block >= 1, "block must be >= 1");
        anyhow::ensure!(
            (1..=64).contains(&spec.shards) && spec.shards <= spec.n,
            "shards must be in 1..=64 and <= n, got {}",
            spec.shards
        );
        match spec.kind {
            JobKind::CdGrab => anyhow::ensure!(
                spec.admit_rate == 0,
                "admit_rate only applies to stream jobs"
            ),
            // A full reservoir admits at most n units per boundary
            // (the admit queue is capacity-bounded), and the
            // count-neutral invariant over fixed links needs the
            // evictions to keep up with the admits.
            JobKind::Stream => anyhow::ensure!(
                spec.admit_rate <= spec.n,
                "admit_rate must be <= n for stream jobs, got {}",
                spec.admit_rate
            ),
        }
        Ok(spec)
    }

    /// The spec as a `POST /jobs` body.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.kind.label().to_string())),
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("block", Json::Num(self.block as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("admit_rate", Json::Num(self.admit_rate as f64)),
        ])
    }
}

/// Lifecycle of a submitted job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Leased its workers; epoch loop in progress.
    Running,
    /// All epochs done; record is final.
    Done,
    /// Session failed (link error, worker loss, bad spec at runtime).
    Failed(String),
}

impl JobStatus {
    /// Stable status label for JSON/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed(_) => "failed",
        }
    }
}

/// Everything the control plane reports about one job.
#[derive(Clone, Debug)]
pub struct JobRecord {
    /// Daemon-assigned job id (dense from 0).
    pub id: u64,
    /// The submitted spec.
    pub spec: JobSpec,
    /// Current lifecycle state.
    pub status: JobStatus,
    /// `(worker id, name)` of each leased worker, shard order.
    pub workers: Vec<(u32, String)>,
    /// FNV-1a hash of each completed epoch's order ([`order_hash`]) —
    /// what `--service` clients compare against a local run
    /// (contract 5 without shipping whole permutations; contract 9 for
    /// stream jobs). For stream jobs each entry hashes the order the
    /// window boundary finalized for the *next* window.
    pub epoch_hashes: Vec<u32>,
    /// Herding ℓ∞ bound after each completed epoch (stream: the
    /// completed window's bound over its cached gradients).
    pub herd_inf: Vec<f64>,
    /// Stream jobs: the reservoir's lifetime counters, refreshed at
    /// every window boundary. `None` for cdgrab jobs.
    pub stream: Option<StreamStats>,
    /// Link counter totals at completion (zeros while running).
    pub stats: LinkStats,
}

impl JobRecord {
    fn to_json(&self) -> Json {
        let workers = self
            .workers
            .iter()
            .map(|(id, name)| {
                obj(vec![
                    ("id", Json::Num(*id as f64)),
                    ("name", Json::Str(name.clone())),
                ])
            })
            .collect();
        let hashes = self
            .epoch_hashes
            .iter()
            .map(|&h| Json::Num(h as f64))
            .collect();
        let herd =
            self.herd_inf.iter().map(|&x| Json::Num(x)).collect();
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("kind", Json::Str(self.spec.kind.label().to_string())),
            ("status", Json::Str(self.status.label().to_string())),
            ("n", Json::Num(self.spec.n as f64)),
            ("d", Json::Num(self.spec.d as f64)),
            ("epochs", Json::Num(self.spec.epochs as f64)),
            ("block", Json::Num(self.spec.block as f64)),
            ("shards", Json::Num(self.spec.shards as f64)),
            ("seed", Json::Num(self.spec.seed as f64)),
            ("admit_rate", Json::Num(self.spec.admit_rate as f64)),
            ("workers", Json::Arr(workers)),
            ("epoch_hashes", Json::Arr(hashes)),
            ("herd_inf", Json::Arr(herd)),
            ("tx_bytes", Json::Num(self.stats.tx_bytes as f64)),
            ("rx_bytes", Json::Num(self.stats.rx_bytes as f64)),
            ("stalls", Json::Num(self.stats.stalls as f64)),
        ];
        if let Some(s) = &self.stream {
            fields.push(("windows", Json::Num(s.windows as f64)));
            fields.push(("admits", Json::Num(s.admits as f64)));
            fields.push(("evictions", Json::Num(s.evictions as f64)));
            fields.push(("replans", Json::Num(s.replans as f64)));
            fields.push((
                "last_window_inf",
                Json::Num(s.last_window_inf as f64),
            ));
        }
        if let JobStatus::Failed(why) = &self.status {
            fields.push(("error", Json::Str(why.clone())));
        }
        obj(fields)
    }
}

/// FNV-1a over an order's unit ids as little-endian `u32`s — the
/// compact per-epoch fingerprint daemon jobs report and `--service`
/// clients recompute locally. Two equal-length orders collide only if
/// the hash does (32-bit, fine for an 8-epoch acceptance gate).
pub fn order_hash(order: &[usize]) -> u32 {
    let mut bytes = Vec::with_capacity(order.len() * 4);
    for &u in order {
        bytes.extend_from_slice(&(u as u32).to_le_bytes());
    }
    ser::fnv1a32(&bytes)
}

/// Shared daemon state behind the accept loops, handler threads, and
/// job threads.
struct State {
    registry: Mutex<registry::Registry<TcpStream>>,
    jobs: Mutex<Vec<JobRecord>>,
    next_job_id: AtomicU64,
    draining: AtomicBool,
    shutdown: AtomicBool,
    jobs_running: AtomicU64,
    jobs_completed: AtomicU64,
    jobs_failed: AtomicU64,
    epochs_total: AtomicU64,
    /// Stream-job reservoir counters: windows advance live (one per
    /// boundary), admits/evictions fold in at the job boundary like
    /// the transport counters below.
    stream_windows: AtomicU64,
    stream_admits: AtomicU64,
    stream_evictions: AtomicU64,
    /// Link counter totals folded in as jobs complete (`/metrics`
    /// counters stay monotone; a running job's bytes land at its
    /// boundary, mirroring how `TransportStats::retired` folds).
    tx_bytes: AtomicU64,
    rx_bytes: AtomicU64,
    stalls: AtomicU64,
    read_timeout: Duration,
}

/// A running daemon: two listeners plus the threads behind them.
/// Constructed by [`OrderService::start`]; tests run it in-process on
/// port 0, `grab serve` wraps it in [`run_serve`].
pub struct OrderService {
    state: Arc<State>,
    register_addr: SocketAddr,
    http_addr: SocketAddr,
    accept_threads: Vec<std::thread::JoinHandle<()>>,
    job_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl OrderService {
    /// Bind both listeners and start the accept loops. Port 0 binds an
    /// ephemeral port; read it back via
    /// [`register_addr`](Self::register_addr) / [`http_addr`](Self::http_addr).
    pub fn start(cfg: &ServeConfig) -> Result<OrderService> {
        anyhow::ensure!(
            cfg.read_timeout_secs >= 1,
            "read timeout must be >= 1 second"
        );
        let reg_listener = TcpListener::bind(&cfg.register_addr)
            .with_context(|| {
                format!("binding registration listener {}", cfg.register_addr)
            })?;
        let http_listener = TcpListener::bind(&cfg.http_addr)
            .with_context(|| {
                format!("binding control listener {}", cfg.http_addr)
            })?;
        let register_addr = reg_listener.local_addr()?;
        let http_addr = http_listener.local_addr()?;
        let state = Arc::new(State {
            registry: Mutex::new(registry::Registry::new(1)),
            jobs: Mutex::new(Vec::new()),
            next_job_id: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            jobs_running: AtomicU64::new(0),
            jobs_completed: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            epochs_total: AtomicU64::new(0),
            stream_windows: AtomicU64::new(0),
            stream_admits: AtomicU64::new(0),
            stream_evictions: AtomicU64::new(0),
            tx_bytes: AtomicU64::new(0),
            rx_bytes: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            read_timeout: Duration::from_secs(cfg.read_timeout_secs),
        });
        let job_threads = Arc::new(Mutex::new(Vec::new()));
        let mut accept_threads = Vec::new();
        {
            let state = Arc::clone(&state);
            accept_threads.push(std::thread::spawn(move || {
                registration_loop(reg_listener, state)
            }));
        }
        {
            let state = Arc::clone(&state);
            let job_threads = Arc::clone(&job_threads);
            accept_threads.push(std::thread::spawn(move || {
                http_loop(http_listener, state, job_threads)
            }));
        }
        Ok(OrderService {
            state,
            register_addr,
            http_addr,
            accept_threads,
            job_threads,
        })
    }

    /// Actual registration listener address (resolves port 0).
    pub fn register_addr(&self) -> String {
        self.register_addr.to_string()
    }

    /// Actual control-plane address (resolves port 0).
    pub fn http_addr(&self) -> String {
        self.http_addr.to_string()
    }

    /// Whether a drain has begun (SIGTERM or `POST /drain`).
    pub fn is_draining(&self) -> bool {
        self.state.draining.load(Ordering::SeqCst)
    }

    /// Jobs currently running.
    pub fn running_jobs(&self) -> u64 {
        self.state.jobs_running.load(Ordering::SeqCst)
    }

    /// Begin (or continue) a drain and block until it completes:
    /// refuse new registrations/jobs, join the running job threads —
    /// leased sockets close only at their job boundary — then close
    /// the idle held sockets. Idempotent.
    pub fn drain(&self) {
        self.state.draining.store(true, Ordering::SeqCst);
        let handles: Vec<_> =
            std::mem::take(&mut *self.job_threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // Idle workers get a clean between-sessions EOF.
        let links = self.state.registry.lock().unwrap().drain_links();
        drop(links);
    }

    /// Drain, then stop both accept loops and join them. Consumes the
    /// service; in-process control/registration addresses stop
    /// answering once this returns.
    pub fn shutdown(mut self) {
        self.drain();
        self.state.shutdown.store(true, Ordering::SeqCst);
        // Unblock the two accept() calls with one throwaway dial each.
        let _ = TcpStream::connect(self.register_addr);
        let _ = TcpStream::connect(self.http_addr);
        for h in self.accept_threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Accept loop for the worker registration listener.
fn registration_loop(listener: TcpListener, state: Arc<State>) {
    loop {
        let conn = listener.accept();
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let stream = match conn {
            Ok((stream, _)) => stream,
            Err(e) => {
                eprintln!("[serve] registration accept failed: {e}");
                // A broken listener must not spin the core.
                std::thread::sleep(Duration::from_millis(100));
                continue;
            }
        };
        if let Err(e) = handle_registration(&state, stream) {
            eprintln!("[serve] registration refused: {e}");
        }
    }
}

/// One registration handshake: `Register` in, `Lease` out, socket into
/// the registry. Any error drops the socket (the worker sees EOF and
/// retries or exits).
fn handle_registration(state: &State, mut stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(REGISTER_HANDSHAKE_TIMEOUT))?;
    let mut buf = Vec::new();
    let kind = read_frame(&mut stream, &mut buf)?;
    anyhow::ensure!(
        kind == FrameKind::Register,
        "expected register frame, got {kind:?}"
    );
    let reg = decode_register(&buf[FRAME_HEADER_LEN..])?;
    let mut registry = state.registry.lock().unwrap();
    if state.draining.load(Ordering::SeqCst) {
        registry.refuse();
        anyhow::bail!("draining; {:?} turned away", reg.name);
    }
    let generation = registry.generation();
    if reg.generation != 0 && reg.generation != generation {
        registry.refuse();
        anyhow::bail!(
            "stale registry generation {} from {:?} (current {})",
            reg.generation,
            reg.name,
            generation
        );
    }
    // Reply while holding the lock so the lease's worker id and the
    // table's assignment cannot diverge; on a failed write the socket
    // never enters the table.
    let id = registry.next_worker_id();
    let mut payload = Vec::new();
    encode_lease(Lease { worker_id: id, generation }, &mut payload);
    let mut scratch = Vec::new();
    write_frame(&mut stream, FrameKind::Lease, &payload, &mut scratch)?;
    // Job sessions manage their own timeouts via `tcp::from_stream`;
    // an idle held socket must be allowed to sit quiet indefinitely.
    stream.set_read_timeout(None)?;
    let assigned = registry.register(&reg.name, reg.capacity, stream);
    debug_assert_eq!(assigned, id);
    eprintln!(
        "[serve] worker {id} registered: {:?} (capacity {})",
        reg.name, reg.capacity
    );
    Ok(())
}

/// Accept loop for the control plane; each connection gets a short
/// handler thread so one slow client cannot stall `/health`.
fn http_loop(
    listener: TcpListener,
    state: Arc<State>,
    job_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
) {
    loop {
        let conn = listener.accept();
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let stream = match conn {
            Ok((stream, _)) => stream,
            Err(e) => {
                eprintln!("[serve] control accept failed: {e}");
                std::thread::sleep(Duration::from_millis(100));
                continue;
            }
        };
        let state = Arc::clone(&state);
        let job_threads = Arc::clone(&job_threads);
        std::thread::spawn(move || {
            if let Err(e) = handle_http(&state, &job_threads, stream) {
                eprintln!("[serve] control request failed: {e}");
            }
        });
    }
}

/// Route one control-plane request.
fn handle_http(
    state: &Arc<State>,
    job_threads: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    mut stream: TcpStream,
) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let req = match http::read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let body = obj(vec![("error", Json::Str(format!("{e:#}")))]);
            return http::respond_json(&mut stream, 400, &body);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => {
            http::respond_json(&mut stream, 200, &health_json(state))
        }
        ("GET", "/metrics") => http::respond(
            &mut stream,
            200,
            "text/plain; version=0.0.4",
            metrics_text(state).as_bytes(),
        ),
        ("GET", "/jobs") => {
            let jobs = state.jobs.lock().unwrap();
            let list = jobs
                .iter()
                .map(|r| {
                    obj(vec![
                        ("id", Json::Num(r.id as f64)),
                        (
                            "status",
                            Json::Str(r.status.label().to_string()),
                        ),
                    ])
                })
                .collect();
            drop(jobs);
            http::respond_json(
                &mut stream,
                200,
                &obj(vec![("jobs", Json::Arr(list))]),
            )
        }
        ("POST", "/jobs") => submit_job(state, job_threads, stream, &req),
        ("POST", "/drain") => {
            state.draining.store(true, Ordering::SeqCst);
            eprintln!("[serve] drain requested via control plane");
            http::respond_json(
                &mut stream,
                200,
                &obj(vec![("status", Json::Str("draining".into()))]),
            )
        }
        ("GET", path) if path.starts_with("/jobs/") => {
            let body = match path["/jobs/".len()..].parse::<u64>() {
                Ok(id) => {
                    let jobs = state.jobs.lock().unwrap();
                    jobs.iter().find(|r| r.id == id).map(JobRecord::to_json)
                }
                Err(_) => None,
            };
            match body {
                Some(v) => http::respond_json(&mut stream, 200, &v),
                None => http::respond_json(
                    &mut stream,
                    404,
                    &obj(vec![(
                        "error",
                        Json::Str(
                            registry::ServiceError::UnknownJob(0)
                                .to_string(),
                        ),
                    )]),
                ),
            }
        }
        (_, "/health" | "/metrics" | "/jobs" | "/drain") => {
            http::respond_json(
                &mut stream,
                405,
                &obj(vec![(
                    "error",
                    Json::Str(format!(
                        "method {} not allowed on {}",
                        req.method, req.path
                    )),
                )]),
            )
        }
        _ => http::respond_json(
            &mut stream,
            404,
            &obj(vec![(
                "error",
                Json::Str(format!("no such route {}", req.path)),
            )]),
        ),
    }
}

/// `POST /jobs`: validate, lease, spawn the job thread, answer 202.
fn submit_job(
    state: &Arc<State>,
    job_threads: &Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    mut stream: TcpStream,
    req: &http::Request,
) -> Result<()> {
    let spec = std::str::from_utf8(&req.body)
        .map_err(anyhow::Error::from)
        .and_then(Json::parse)
        .and_then(|v| JobSpec::from_json(&v));
    let spec = match spec {
        Ok(spec) => spec,
        Err(e) => {
            let body = obj(vec![("error", Json::Str(format!("{e:#}")))]);
            return http::respond_json(&mut stream, 400, &body);
        }
    };
    if state.draining.load(Ordering::SeqCst) {
        let body = obj(vec![(
            "error",
            Json::Str(registry::ServiceError::Draining.to_string()),
        )]);
        return http::respond_json(&mut stream, 503, &body);
    }
    // Allocate the job id only once the lease is sure to succeed (both
    // under the registry lock), so a refused submission burns neither
    // an id nor the submitted-jobs counter.
    let leased = {
        let mut registry = state.registry.lock().unwrap();
        if registry.available() >= spec.shards {
            let job_id = state.next_job_id.fetch_add(1, Ordering::SeqCst);
            registry
                .lease(spec.shards, job_id)
                .map(|slots| (job_id, slots))
        } else {
            Err(registry::ServiceError::NotEnoughWorkers {
                have: registry.available(),
                need: spec.shards,
            })
        }
    };
    let (job_id, slots) = match leased {
        Ok(x) => x,
        Err(e) => {
            let body = obj(vec![("error", Json::Str(e.to_string()))]);
            return http::respond_json(&mut stream, 409, &body);
        }
    };
    let workers: Vec<(u32, String)> =
        slots.iter().map(|s| (s.id, s.name.clone())).collect();
    state.jobs.lock().unwrap().push(JobRecord {
        id: job_id,
        spec,
        status: JobStatus::Running,
        workers: workers.clone(),
        epoch_hashes: Vec::new(),
        herd_inf: Vec::new(),
        stream: None,
        stats: LinkStats::default(),
    });
    state.jobs_running.fetch_add(1, Ordering::SeqCst);
    eprintln!(
        "[serve] job {job_id} ({}): n={} d={} epochs={} W={} over \
         workers {:?}",
        spec.kind.label(),
        spec.n,
        spec.d,
        spec.epochs,
        spec.shards,
        workers.iter().map(|(id, _)| *id).collect::<Vec<_>>()
    );
    {
        let state = Arc::clone(state);
        let handle =
            std::thread::spawn(move || run_job(state, job_id, spec, slots));
        job_threads.lock().unwrap().push(handle);
    }
    let worker_ids = workers
        .iter()
        .map(|(id, _)| Json::Num(*id as f64))
        .collect();
    http::respond_json(
        &mut stream,
        202,
        &obj(vec![
            ("job", Json::Num(job_id as f64)),
            ("workers", Json::Arr(worker_ids)),
        ]),
    )
}

/// Job thread body: run the session, then settle the record and the
/// daemon counters whatever happened (including a panic somewhere in
/// the ordering stack — a lost job must not wedge `jobs_running`).
fn run_job(
    state: Arc<State>,
    id: u64,
    spec: JobSpec,
    slots: Vec<registry::Slot<TcpStream>>,
) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || run_job_inner(&state, id, &spec, slots),
    ));
    let outcome: Result<LinkStats, String> = match result {
        Ok(Ok(stats)) => Ok(stats),
        Ok(Err(e)) => Err(format!("{e:#}")),
        Err(panic) => {
            let msg = panic
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("job thread panicked");
            Err(msg.to_string())
        }
    };
    {
        let mut jobs = state.jobs.lock().unwrap();
        let rec = jobs
            .iter_mut()
            .find(|r| r.id == id)
            .expect("job record exists for its whole lifetime");
        match outcome {
            Ok(stats) => {
                rec.status = JobStatus::Done;
                rec.stats = stats;
                state.jobs_completed.fetch_add(1, Ordering::SeqCst);
                state.tx_bytes.fetch_add(stats.tx_bytes, Ordering::SeqCst);
                state.rx_bytes.fetch_add(stats.rx_bytes, Ordering::SeqCst);
                state.stalls.fetch_add(stats.stalls, Ordering::SeqCst);
                eprintln!("[serve] job {id} done");
            }
            Err(why) => {
                eprintln!("[serve] job {id} failed: {why}");
                rec.status = JobStatus::Failed(why);
                state.jobs_failed.fetch_add(1, Ordering::SeqCst);
            }
        }
    }
    state.registry.lock().unwrap().complete(id);
    state.jobs_running.fetch_sub(1, Ordering::SeqCst);
}

/// The actual session: leased sockets → `Hello` handshakes →
/// `ShardedOrder` → the job kind's loop (the `exp cdgrab` epoch loop,
/// or a sliding reservoir over the same links), recording a hash and
/// herding bound per epoch/window. Dropping the policy at the end
/// closes the sockets — the job boundary — and live workers
/// re-register.
fn run_job_inner(
    state: &State,
    id: u64,
    spec: &JobSpec,
    slots: Vec<registry::Slot<TcpStream>>,
) -> Result<LinkStats> {
    // Daemon jobs run a *static* equal-weight topology: determinism
    // contracts 5/9 (orders independent of transport) are the
    // service's acceptance gate, and they only bind at a fixed
    // topology. Stream jobs keep it fixed by construction — the
    // steady drift schedule is count-neutral, so no boundary resizes.
    let topology = Topology::plan(spec.n, 0, &vec![1u64; spec.shards]);
    let mut links: Vec<Box<dyn ShardTransport>> =
        Vec::with_capacity(spec.shards);
    for (w, slot) in slots.into_iter().enumerate() {
        let label = format!("{} ({})", slot.id, slot.name);
        let link = tcp::from_stream(
            slot.link,
            topology.sizes[w],
            spec.d,
            0,
            state.read_timeout,
        )
        .with_context(|| format!("hello to worker {label} (shard {w})"))?;
        links.push(Box::new(link));
    }
    let inner = ShardedOrder::from_links(
        spec.n, spec.d, topology, links, "tcp", None,
    );
    match spec.kind {
        JobKind::CdGrab => run_cdgrab_job(state, id, spec, inner),
        JobKind::Stream => run_stream_job(state, id, spec, inner),
    }
}

/// [`JobKind::CdGrab`] session body: the static-gradient epoch loop.
fn run_cdgrab_job(
    state: &State,
    id: u64,
    spec: &JobSpec,
    mut policy: ShardedOrder,
) -> Result<LinkStats> {
    let mut rng = Rng::new(spec.seed);
    let vs = gen::vec_set(&mut rng, spec.n, spec.d);
    let mut flat = vec![0.0f32; spec.n * spec.d];
    for epoch in 0..spec.epochs {
        crate::ordering::stream_static_epoch(
            &mut policy,
            epoch,
            &vs,
            &mut flat,
            spec.block,
        );
        // Hash the order the boundary just finalized for epoch + 1 —
        // keyed to the real epoch index, so an epoch-keyed policy
        // would replay correctly too.
        let order = policy.epoch_order(epoch + 1);
        let hash = order_hash(order);
        let (inf, _) = herding_bound(&vs, order);
        let mut jobs = state.jobs.lock().unwrap();
        let rec = jobs
            .iter_mut()
            .find(|r| r.id == id)
            .expect("job record exists for its whole lifetime");
        rec.epoch_hashes.push(hash);
        rec.herd_inf.push(inf as f64);
        drop(jobs);
        state.epochs_total.fetch_add(1, Ordering::SeqCst);
    }
    Ok(policy
        .transport_stats()
        .map(|s| s.total())
        .unwrap_or_default())
}

/// [`JobKind::Stream`] session body: wrap the leased-link coordinator
/// in a [`StreamOrder`] reservoir and drive `spec.epochs` windows of a
/// frozen [`DriftPlan::steady`] schedule. On a full reservoir that
/// schedule is count-neutral (every admit FIFO-evicts the oldest
/// unit), so the fixed links never need a re-link — `relink: None`
/// enforces exactly that. Per window we record the hash of the next
/// window's order and the completed window's herding bound, which is
/// what a local channel-backed reference reproduces bit-for-bit
/// (contract 9).
fn run_stream_job(
    state: &State,
    id: u64,
    spec: &JobSpec,
    inner: ShardedOrder,
) -> Result<LinkStats> {
    let units: Vec<u64> = (0..spec.n as u64).collect();
    let mut policy =
        StreamOrder::sharded(spec.n, spec.d, &units, inner, None);
    let drift = DriftPlan::steady(spec.seed, spec.admit_rate);
    let mut next_unit = spec.n as u64;
    for window in 0..spec.epochs {
        policy.drive_window(&drift, &mut next_unit, spec.block);
        let stats = policy.stats();
        let hash = order_hash(policy.epoch_order(window + 1));
        let mut jobs = state.jobs.lock().unwrap();
        let rec = jobs
            .iter_mut()
            .find(|r| r.id == id)
            .expect("job record exists for its whole lifetime");
        rec.epoch_hashes.push(hash);
        rec.herd_inf.push(stats.last_window_inf as f64);
        rec.stream = Some(stats);
        drop(jobs);
        state.epochs_total.fetch_add(1, Ordering::SeqCst);
        state.stream_windows.fetch_add(1, Ordering::SeqCst);
    }
    let stats = policy.stats();
    state.stream_admits.fetch_add(stats.admits, Ordering::SeqCst);
    state
        .stream_evictions
        .fetch_add(stats.evictions, Ordering::SeqCst);
    Ok(policy
        .transport_stats()
        .map(|s| s.total())
        .unwrap_or_default())
}

/// `GET /health` body.
fn health_json(state: &State) -> Json {
    let registry = state.registry.lock().unwrap();
    let status = if state.draining.load(Ordering::SeqCst) {
        "draining"
    } else {
        "ok"
    };
    obj(vec![
        ("status", Json::Str(status.to_string())),
        (
            "workers_available",
            Json::Num(registry.available() as f64),
        ),
        ("workers_leased", Json::Num(registry.leased() as f64)),
        (
            "jobs_running",
            Json::Num(state.jobs_running.load(Ordering::SeqCst) as f64),
        ),
        ("generation", Json::Num(registry.generation() as f64)),
    ])
}

/// `GET /metrics` body — Prometheus text exposition. The
/// `grab_transport_*` counters are [`crate::ordering::transport::TransportStats`]
/// totals folded in at each job boundary, so they are monotone and
/// match the per-job `tx_bytes`/`rx_bytes`/`stalls` fields exactly.
fn metrics_text(state: &State) -> String {
    let (available, leased, generation, reg_total, reg_refused) = {
        let registry = state.registry.lock().unwrap();
        (
            registry.available(),
            registry.leased(),
            registry.generation(),
            registry.registrations_total(),
            registry.registrations_refused(),
        )
    };
    let mut out = String::new();
    let mut metric = |name: &str, kind: &str, help: &str, value: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
        ));
    };
    metric(
        "grab_workers_available",
        "gauge",
        "Registered workers not leased to a job.",
        available as u64,
    );
    metric(
        "grab_workers_leased",
        "gauge",
        "Workers leased to running jobs.",
        leased as u64,
    );
    metric(
        "grab_registry_generation",
        "gauge",
        "Registry generation carried in every lease.",
        generation as u64,
    );
    metric(
        "grab_registrations_total",
        "counter",
        "Successful worker registrations.",
        reg_total,
    );
    metric(
        "grab_registrations_refused_total",
        "counter",
        "Registrations refused (draining, stale generation, bad frame).",
        reg_refused,
    );
    metric(
        "grab_jobs_submitted_total",
        "counter",
        "Jobs accepted by POST /jobs.",
        state.next_job_id.load(Ordering::SeqCst),
    );
    metric(
        "grab_jobs_completed_total",
        "counter",
        "Jobs that finished every epoch.",
        state.jobs_completed.load(Ordering::SeqCst),
    );
    metric(
        "grab_jobs_failed_total",
        "counter",
        "Jobs that failed (link error or panic).",
        state.jobs_failed.load(Ordering::SeqCst),
    );
    metric(
        "grab_jobs_running",
        "gauge",
        "Jobs currently running.",
        state.jobs_running.load(Ordering::SeqCst),
    );
    metric(
        "grab_job_epochs_total",
        "counter",
        "Epochs completed across all jobs (stream windows included).",
        state.epochs_total.load(Ordering::SeqCst),
    );
    metric(
        "grab_stream_windows_total",
        "counter",
        "Reservoir windows completed across stream jobs.",
        state.stream_windows.load(Ordering::SeqCst),
    );
    metric(
        "grab_stream_admits_total",
        "counter",
        "Units admitted across stream jobs (completed jobs' totals).",
        state.stream_admits.load(Ordering::SeqCst),
    );
    metric(
        "grab_stream_evictions_total",
        "counter",
        "Units FIFO-evicted across stream jobs (completed jobs' \
         totals).",
        state.stream_evictions.load(Ordering::SeqCst),
    );
    metric(
        "grab_transport_tx_bytes_total",
        "counter",
        "Coordinator-to-worker payload bytes (completed jobs' \
         TransportStats totals).",
        state.tx_bytes.load(Ordering::SeqCst),
    );
    metric(
        "grab_transport_rx_bytes_total",
        "counter",
        "Worker-to-coordinator payload bytes (completed jobs' \
         TransportStats totals).",
        state.rx_bytes.load(Ordering::SeqCst),
    );
    metric(
        "grab_transport_stalls_total",
        "counter",
        "Link backpressure stalls (completed jobs' TransportStats \
         totals; 0 for pure-TCP links).",
        state.stalls.load(Ordering::SeqCst),
    );
    metric(
        "grab_draining",
        "gauge",
        "1 once a drain has begun.",
        state.draining.load(Ordering::SeqCst) as u64,
    );
    out
}

/// SIGTERM/SIGINT latch. Raw `signal(2)` binding because the vendored
/// dependency closure has no `libc` crate; an `AtomicBool` store is
/// async-signal-safe.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    pub fn install() {
        // SAFETY: FFI call to POSIX `signal(2)` with valid constant
        // signal numbers and a handler that only performs an atomic
        // store — async-signal-safe, no allocation, no locks, no
        // reentrancy hazard. Replacing a previous disposition is fine:
        // the daemon installs these once at startup.
        unsafe {
            signal(SIGTERM, on_term);
            signal(SIGINT, on_term);
        }
    }

    pub fn requested() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// `grab serve` entry point: parse flags, start the daemon, wait for a
/// drain trigger (SIGTERM/SIGINT on unix, `POST /drain` anywhere),
/// drain, exit 0.
pub fn run_serve(args: &Args) -> Result<()> {
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        register_addr: args.str_or("listen", &defaults.register_addr),
        http_addr: args.str_or("http", &defaults.http_addr),
        read_timeout_secs: {
            let rt = args
                .u64_or("read-timeout", tcp::DEFAULT_READ_TIMEOUT_SECS)?;
            anyhow::ensure!(
                rt >= 1,
                "--read-timeout must be >= 1 second"
            );
            rt
        },
    };
    args.reject_unknown()?;

    #[cfg(unix)]
    sig::install();

    let service = OrderService::start(&cfg)?;
    eprintln!(
        "[serve] worker registry on {} (wire v{}; register with \
         `grab exp cdgrab --register {}`)",
        service.register_addr(),
        ser::WIRE_VERSION,
        service.register_addr()
    );
    eprintln!(
        "[serve] control plane on http://{} \
         (/health /metrics /jobs /drain)",
        service.http_addr()
    );
    loop {
        std::thread::sleep(Duration::from_millis(200));
        #[cfg(unix)]
        if sig::requested() {
            eprintln!("[serve] SIGTERM: draining");
            break;
        }
        if service.is_draining() && service.running_jobs() == 0 {
            eprintln!("[serve] drain requested; no jobs left");
            break;
        }
    }
    service.shutdown();
    eprintln!("[serve] drained; all workers detached at job boundaries");
    Ok(())
}
