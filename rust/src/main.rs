//! `grab` — CLI launcher for the GraB reproduction.
//!
//! ```text
//! grab train  [--config f.toml] [--task mnist|cifar|wiki|glue]
//!             [--ordering rr|so|flipflop|greedy|grab|grab-1step|pair|
//!              cd-grab|stream|seq] [--shards W] [--queue-depth N]
//!             [--transport channel|tcp] [--connect HOST:PORT]
//!             [--balancer alg5|alg6|kernel] [--epochs N] [--n N]
//!             [--lr F] [--seed N] [--metrics-out f.csv] [--pipeline]
//!             [--async-shards] [--stream] [--window N]
//!             [--checkpoint-dir DIR] [--checkpoint-every N] [--resume]
//! grab exp    fig1|fig2|fig3|fig4|table1|statement1|granularity|
//!             cdgrab|stream|all [options]
//!             (cdgrab: --listen HOST:PORT serves shard workers,
//!              --connect HOST:PORT dials a remote worker server,
//!              --register HOST:PORT joins a `grab serve` daemon,
//!              --service HOST:PORT submits the job to a daemon)
//! grab serve  [--listen HOST:PORT] [--http HOST:PORT]
//!             [--read-timeout SECS]   # order-service daemon
//! grab bench  [--out BENCH.json] [--quick] [--kernels LIST]
//!             # balance-kernel perf trajectory (docs/perf.md)
//! grab audit  [--root DIR] [--list]    # determinism/safety lint pass
//!             # (docs/audit.md); non-zero exit on violations
//! grab inspect [--artifacts DIR]       # artifact/manifest summary
//! ```

use anyhow::{bail, Result};

use grab::config::TrainConfig;
use grab::pipeline::PipelineTrainer;
use grab::runtime::Runtime;
use grab::train::Trainer;
use grab::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "exp" => grab::exp::run_from_cli(&args),
        "serve" => grab::service::run_serve(&args),
        "bench" => grab::bench::run_from_cli(&args),
        "audit" => grab::audit::run_from_cli(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `grab help`"),
    }
}

const HELP: &str = "\
grab — GraB: provably better data permutations than random reshuffling
  (Lu, Guo & De Sa, NeurIPS 2022) — rust + JAX/Pallas reproduction

USAGE:
  grab train [options]     train one run (task x ordering)
  grab exp <id> [options]  regenerate a paper artifact
                           (fig1|fig2|fig3|fig4|table1|statement1|
                            granularity|cdgrab|stream|all)
  grab serve [options]     run the order-service daemon: workers dial in
                           and register; jobs run over the held sockets;
                           HTTP control plane (docs/service.md)
  grab bench [options]     run the balance/ordering benchmark cases and
                           emit versioned JSON (docs/perf.md)
  grab audit [options]     lint src/tests/benches against the
                           determinism/safety rules (docs/audit.md);
                           prints path:line findings, exits non-zero on
                           any violation
  grab inspect             show artifact manifest / model layouts
  grab help

TRAIN OPTIONS:
  --config FILE            TOML run config (flags overlay on top)
  --task mnist|cifar|wiki|glue
  --ordering rr|so|flipflop|greedy|grab|grab-1step|pair|cd-grab|seq
  --shards W               CD-GraB worker count (with --ordering cd-grab)
  --async-shards           run CD-GraB shard balancers on worker threads
                           (same epoch orders as sync; boolean flag, put
                           it last or before another --flag)
  --queue-depth N          per-shard block-queue depth for --async-shards
                           (default: 4)
  --transport channel|tcp  CD-GraB order-exchange transport: in-process
                           channels (default) or the socket wire protocol
                           (bit-equal orders either way)
  --connect ADDR[,ADDR…]   dial remote shard worker server(s) instead of
                           spawning loopback workers (needs --transport
                           tcp; start each server with
                           `grab exp cdgrab --listen HOST:PORT`; shard w
                           dials address w mod the list, falling through
                           the list when a server is unreachable)
  --weights W1,W2,…        uneven (weighted) CD-GraB topology: shard
                           sizes proportional to the integer weights
                           (sets the shard count; replay a recorded
                           elastic run by pinning its logged weights)
  --elastic                re-plan the CD-GraB topology at epoch
                           boundaries from measured per-link cost, and
                           survive a mid-run worker loss by re-splitting
                           over the remaining shards (needs
                           --async-shards or --transport tcp; per-epoch
                           plans are recorded for exact replay)
  --kernels auto|scalar|simd|simd+par
                           balance-kernel dispatch tier (default: auto =
                           probe AVX2 once; every tier emits bit-identical
                           epoch orders — docs/determinism.md contract 7)
  --stream                 sugar for --ordering stream: pair balancing
                           through the sliding-reservoir policy; with
                           the trainer the reservoir spans the whole
                           dataset, one window per epoch, bit-equal to
                           --ordering pair (docs/determinism.md
                           contract 9; boolean flag, put it last or
                           before another --flag)
  --window N               reservoir capacity in units (with --stream;
                           must cover the dataset here — sliding
                           windows run through `grab exp stream` and
                           daemon stream jobs, docs/streaming.md)
  --balancer alg5|alg6|kernel
  --epochs N --n N --n-eval N --accum N
  --lr F --momentum F --wd F --seed N
  --metrics-out FILE.csv   stream per-epoch metrics
  --pipeline               threaded streaming pipeline (overlapped stages)
  --artifacts DIR          artifact directory (default: artifacts)
  --checkpoint-dir DIR     durable run directory: versioned manifest +
                           per-epoch snapshots (params, momentum, ordering
                           state, schedule) — docs/determinism.md
                           contract 8
  --checkpoint-every N     snapshot cadence in epochs (default: 1; the
                           final epoch is always snapshotted)
  --resume                 resume from the latest snapshot in
                           --checkpoint-dir; refuses on a config
                           fingerprint mismatch (boolean flag, put it
                           last or before another --flag)
  --read-timeout SECS      per-frame read timeout on remote shard links
                           (default: 120; a silent peer surfaces as a
                           typed link timeout at the epoch boundary)

SERVE OPTIONS (order-service daemon — docs/service.md):
  --listen HOST:PORT       worker registration listener (wire protocol;
                           default: 127.0.0.1:7470); workers join with
                           `grab exp cdgrab --register HOST:PORT`
  --http HOST:PORT         HTTP/1.1 control plane (default:
                           127.0.0.1:7471): GET /health, GET /metrics
                           (Prometheus text), POST /jobs, GET /jobs[/ID],
                           POST /drain
  --read-timeout SECS      per-frame read timeout on leased worker links
                           during a job session (default: 120)
                           SIGTERM drains: running jobs finish, workers
                           detach only at job boundaries, then exit 0

EXP OPTIONS (see DESIGN.md experiment index):
  --out DIR                results directory (default: results)
  --scale small|paper      dataset/epoch scale (default: small)
  --listen HOST:PORT       (cdgrab) run as a blocking shard worker server
  --connect HOST:PORT      (cdgrab) point the sweep's TCP policies at a
                           remote worker server instead of loopback
  --register HOST:PORT     (cdgrab) dial a `grab serve` daemon's registry
                           and serve job sessions until it drains
  --service HOST:PORT      (cdgrab) submit one job to a daemon's control
                           plane, verify its orders bit-equal a local
                           in-process run, write service_job.csv
  --read-timeout SECS      (cdgrab) per-frame read timeout on remote
                           worker links (default: 120)
  --max-conns N            (with --listen) exit after serving N links
  --checkpoint-dir DIR     (cdgrab) per-policy run directories with
                           epoch snapshots of each policy's ordering
                           state
  --checkpoint-every N     (cdgrab) snapshot cadence (default: 1)
  --resume                 (cdgrab) resume every policy from its latest
                           snapshot; remaining epochs are bit-equal to
                           the uninterrupted sweep (boolean flag)
  --admit-rate R           (stream) fresh units admitted per window on
                           the churn schedules; FIFO eviction keeps the
                           full reservoir count-neutral
                           (docs/streaming.md)
  --epochs N               (stream) windows per scenario

BENCH OPTIONS:
  --out FILE.json          where to write results (default: stdout)
  --kernels k1,k2,…        kernel tiers to measure
                           (default: scalar,simd,simd+par)
  --quick                  reduced iteration budget (CI smoke mode;
                           boolean flag, put it last)

AUDIT OPTIONS:
  --root DIR               crate root to scan (default: auto-detect
                           rust/ or .)
  --list                   print the rule table and exit
";

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => TrainConfig::from_toml(
            &grab::config::TomlDoc::from_file(std::path::Path::new(&path))?,
        )?,
        None => TrainConfig::default(),
    };
    cfg.apply_args(args)?;
    args.reject_unknown()?;

    // Install the configured kernel tier before any ordering policy
    // snapshots it (policies pin their tier at construction).
    grab::tensor::set_default_kernel(cfg.kernels.resolve());
    eprintln!(
        "[grab] run {} (artifacts: {}, kernels: {})",
        cfg.run_id(),
        cfg.artifacts_dir,
        cfg.kernels.resolve().name()
    );
    let rt = Runtime::open(&cfg.artifacts_dir)?;
    eprintln!("[grab] PJRT platform: {}", rt.platform());

    if cfg.use_pipeline {
        let mut t = PipelineTrainer::new(cfg, &rt)?;
        let result = t.run()?;
        for m in &result.epochs {
            println!("{}", m.line(&result.run_id));
        }
        eprintln!(
            "[grab] pipeline stats: {} batches, {} loader stalls, \
             {} grad stalls",
            t.stats.batches, t.stats.loader_stalls, t.stats.grad_stalls
        );
    } else {
        let mut t = Trainer::new(cfg, &rt, None)?;
        let result = t.run()?;
        for m in &result.epochs {
            println!("{}", m.line(&result.run_id));
        }
        eprintln!(
            "[grab] done; ordering state: {} bytes",
            result.order_state_bytes
        );
        if let Some(stats) = &result.transport {
            let total = stats.total();
            eprintln!(
                "[grab] shard links ({}): {} shards, {} stalls, \
                 {} B tx, {} B rx",
                stats.transport,
                stats.per_shard.len(),
                total.stalls,
                total.tx_bytes,
                total.rx_bytes
            );
        }
        if let Some(log) = &result.topology {
            // The log's trailing entry is the *next* epoch's plan (it
            // never ran); summarize the last executed epoch instead.
            let ran = log.len().saturating_sub(2);
            if let Some(last) = log.get(ran) {
                eprintln!(
                    "[grab] topology: {} shards, weights {}, \
                     {} re-plan(s); per-epoch plans recorded \
                     (replay with --weights)",
                    last.num_shards(),
                    last.weights_label(),
                    log.last().map(|t| t.generation).unwrap_or(0)
                );
            }
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;
    let rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {dir}");
    for m in &rt.manifest.models {
        println!("{}", grab::model::describe(m));
    }
    for b in &rt.manifest.balance {
        println!("balance kernel d={} ({})", b.dim, b.hlo);
    }
    Ok(())
}
