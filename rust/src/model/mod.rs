//! Model registry: binds a [`Task`](crate::config::Task) to its L2 artifact
//! family and its dataset substrate, and provides parameter-layout helpers
//! (named views into the flat parameter vector).

use anyhow::Result;

use crate::config::{Task, TrainConfig};
use crate::data::{synth, text, Dataset};
use crate::runtime::{ModelEntry, ParamSpec};

/// Build the train/eval datasets for a task, sized per config. Train and
/// eval share the task *structure* (class means / chain / topic weights
/// live in the low 16 seed bits) but use disjoint sample randomness
/// (high bits), i.e. a real train/test split of one distribution.
pub fn build_datasets(cfg: &TrainConfig) -> (Dataset, Dataset) {
    let n = cfg.n_examples;
    let ne = cfg.n_eval;
    let s = cfg.seed;
    match cfg.task {
        Task::Mnist => (
            synth::mnist_like(n, s),
            synth::mnist_like(ne, s ^ 0xE7A1_0000),
        ),
        Task::Cifar => (
            synth::cifar_like(n, s),
            synth::cifar_like(ne, s ^ 0xE7A1_0000),
        ),
        Task::Wiki => {
            let spec = text::CorpusSpec::default();
            (
                text::lm_dataset(&spec, n, s),
                text::lm_dataset(&spec, ne, s ^ 0xE7A1_0000),
            )
        }
        Task::Glue => (
            synth::glue_like(n, 32, 64, s),
            synth::glue_like(ne, 32, 64, s ^ 0xE7A1_0000),
        ),
    }
}

/// A named view into the flat parameter vector.
pub struct ParamView<'a> {
    /// Layout entry describing this block.
    pub spec: &'a ParamSpec,
    /// The block's values within the flat vector.
    pub values: &'a [f32],
}

/// Look up a named parameter block in a flat vector.
pub fn param_view<'a>(
    entry: &'a ModelEntry,
    flat: &'a [f32],
    name: &str,
) -> Result<ParamView<'a>> {
    let spec = entry
        .param_layout
        .iter()
        .find(|p| p.name == name)
        .ok_or_else(|| anyhow::anyhow!("no param {name:?}"))?;
    anyhow::ensure!(flat.len() == entry.dim, "flat len mismatch");
    Ok(ParamView {
        spec,
        values: &flat[spec.offset..spec.offset + spec.size],
    })
}

/// Human-readable parameter summary (used by `grab inspect`).
pub fn describe(entry: &ModelEntry) -> String {
    let mut out = format!(
        "model {} — d={} params, grad batch B={}, eval batch E={}\n",
        entry.name, entry.dim, entry.batch, entry.eval_batch
    );
    for p in &entry.param_layout {
        out.push_str(&format!(
            "  {:<12} {:?} (offset {}, {} elems)\n",
            p.name, p.shape, p.offset, p.size
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    #[test]
    fn datasets_match_task_geometry() {
        let mut cfg = TrainConfig::default();
        cfg.n_examples = 32;
        cfg.n_eval = 16;
        for task in [Task::Mnist, Task::Cifar, Task::Wiki, Task::Glue] {
            cfg.task = task;
            let (train, eval) = build_datasets(&cfg);
            assert_eq!(train.len(), 32, "{task:?}");
            assert_eq!(eval.len(), 16, "{task:?}");
        }
    }

    #[test]
    fn train_eval_differ() {
        let mut cfg = TrainConfig::default();
        cfg.n_examples = 8;
        cfg.n_eval = 8;
        let (train, eval) = build_datasets(&cfg);
        let (crate::data::Features::F32 { data: a, .. },
             crate::data::Features::F32 { data: b, .. }) =
            (&train.x, &eval.x)
        else {
            panic!()
        };
        assert_ne!(a, b);
    }
}
