//! Vector balancing — the engine room of GraB.
//!
//! Given vectors arriving online, a [`Balancer`] assigns each a sign
//! ε ∈ {−1, +1} so the signed prefix sums stay small (Spencer's balancing
//! game). Two algorithms from the paper:
//!
//! * [`DeterministicBalancer`] — Algorithm 5: ε = +1 iff ‖s+v‖ < ‖s−v‖.
//!   Norm-invariant (only sign⟨s, v⟩ matters), hyperparameter-free; the
//!   paper's practical recommendation and our default.
//! * [`WalkBalancer`] — Algorithm 6 (Alweiss, Liu & Sawhney): the
//!   self-balancing random walk with the Õ(1) high-probability bound of
//!   Theorem 4, including the paper's fail/restart semantics.
//!
//! [`reorder`] is Algorithm 3 (Harvey & Samadi): turn balanced signs into a
//! new permutation (positives in order, then negatives reversed), which
//! halves the herding bound per pass (Theorem 2).

use crate::tensor;
use crate::util::rng::Rng;

/// Online sign-assignment over a running signed sum `s` owned by the caller.
pub trait Balancer {
    /// Decide the sign for centered vector `c` given the current signed
    /// running sum `s`. Implementations must not mutate `s` (the caller
    /// applies `s += eps * c` so it can fuse the update).
    fn sign(&mut self, s: &[f32], c: &[f32]) -> f32;

    /// Reset any internal state for a fresh sequence.
    fn reset(&mut self) {}

    /// Epoch-boundary checkpoint state. [`Balancer::reset`] already
    /// clears the per-epoch walk state at every boundary, so the only
    /// thing that carries across epochs is a stochastic balancer's RNG
    /// stream position; stateless balancers return `None`.
    fn save_rng(&self) -> Option<[u64; 4]> {
        None
    }

    /// Restore the stream position captured by [`Balancer::save_rng`]
    /// (no-op for stateless balancers).
    fn restore_rng(&mut self, _s: [u64; 4]) {}

    /// True when `sign(s, c)` equals `+1 iff <s, c> < 0` (Algorithm 5's
    /// decision rule). Callers may then use the fused/batched centered-dot
    /// kernels (`tensor::dot_centered`, `tensor::dot_centered_block`)
    /// without materializing `c` or dispatching per example.
    fn uses_centered_dot(&self) -> bool {
        false
    }

    /// Short algorithm name for logs and tables.
    fn name(&self) -> &'static str;
}

/// Algorithm 5 — deterministic, normalization-invariant balancing.
///
/// ‖s+c‖² − ‖s−c‖² = 4⟨s, c⟩, so the decision is just the sign of one dot
/// product; ties resolve to −1 exactly like the paper's pseudocode
/// (`+1 if ||s+v|| < ||s-v|| else -1`).
#[derive(Clone, Debug, Default)]
pub struct DeterministicBalancer;

impl Balancer for DeterministicBalancer {
    #[inline]
    fn sign(&mut self, s: &[f32], c: &[f32]) -> f32 {
        if tensor::dot(s, c) < 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn uses_centered_dot(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "alg5-deterministic"
    }
}

/// Algorithm 6 — probabilistic self-balancing walk.
///
/// Requires ‖z‖ ≤ 1; we therefore track a running normalizer (max input
/// norm seen so far, the "large enough constant" the paper says must be
/// estimated) and feed the walk z = c / normalizer. If the preconditions
/// |⟨s̃, z⟩| ≤ c or ‖s̃‖∞ ≤ c fail, the algorithm *fails* per the paper; we
/// count the failure and restart the internal scaled sum (the paper's
/// "restart on failure" offline conversion), falling back to the
/// deterministic sign for that step so training never stalls.
#[derive(Clone, Debug)]
pub struct WalkBalancer {
    /// Theorem 4's c = 30·log(nd/δ); pick via [`WalkBalancer::theorem_c`]
    /// or supply directly.
    pub c: f64,
    rng: Rng,
    /// Internal *scaled* signed sum s̃ = Σ ε_i z_i (the walk's own state —
    /// distinct from the caller's unscaled sum).
    s_scaled: Vec<f32>,
    normalizer: f32,
    /// Precondition failures observed (each restarts the scaled sum).
    pub failures: usize,
}

impl WalkBalancer {
    /// A walk balancer with constant `c` and its own RNG stream.
    pub fn new(c: f64, seed: u64) -> WalkBalancer {
        assert!(c > 0.0, "walk c must be positive");
        WalkBalancer {
            c,
            rng: Rng::new(seed),
            s_scaled: Vec::new(),
            normalizer: 1e-12,
            failures: 0,
        }
    }

    /// Theorem 4's recommended constant for `n` vectors in `d` dims at
    /// failure probability `delta`.
    pub fn theorem_c(n: usize, d: usize, delta: f64) -> f64 {
        30.0 * ((n.max(1) as f64) * (d.max(1) as f64) / delta).ln()
    }
}

impl Balancer for WalkBalancer {
    fn sign(&mut self, _s: &[f32], c_vec: &[f32]) -> f32 {
        if self.s_scaled.len() != c_vec.len() {
            self.s_scaled = vec![0.0; c_vec.len()];
        }
        let norm = tensor::norm2(c_vec);
        if norm > self.normalizer {
            self.normalizer = norm;
        }
        let inv = 1.0 / self.normalizer;
        // z = c / normalizer; dot with the scaled sum.
        let dot = tensor::dot(&self.s_scaled, c_vec) as f64 * inv as f64;
        let sinf = tensor::norm_inf(&self.s_scaled) as f64;
        let eps = if dot.abs() > self.c || sinf > self.c {
            // Paper line 3: Fail. Restart the walk, fall back to Alg 5 for
            // this step.
            self.failures += 1;
            tensor::zero(&mut self.s_scaled);
            if dot < 0.0 { 1.0 } else { -1.0 }
        } else {
            let p_plus = 0.5 - dot / (2.0 * self.c);
            if self.rng.bernoulli(p_plus.clamp(0.0, 1.0)) {
                1.0
            } else {
                -1.0
            }
        };
        // Advance the internal walk with the *scaled* vector.
        for (sv, cv) in self.s_scaled.iter_mut().zip(c_vec) {
            *sv += eps as f32 * cv * inv;
        }
        eps as f32
    }

    fn reset(&mut self) {
        tensor::zero(&mut self.s_scaled);
        self.failures = 0;
        self.normalizer = 1e-12;
    }

    fn save_rng(&self) -> Option<[u64; 4]> {
        Some(self.rng.state())
    }

    fn restore_rng(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    fn name(&self) -> &'static str {
        "alg6-walk"
    }
}

/// Algorithm 3 — reorder by balanced signs: positives keep their relative
/// order at the front; negatives are appended in *reverse* order.
///
/// `order[i]` is the item visited at step i; `signs[i]` its sign. Returns
/// the new permutation (same index space as `order`).
pub fn reorder(order: &[usize], signs: &[f32]) -> Vec<usize> {
    assert_eq!(order.len(), signs.len());
    let mut out = Vec::with_capacity(order.len());
    for (i, &s) in signs.iter().enumerate() {
        if s > 0.0 {
            out.push(order[i]);
        }
    }
    for (i, &s) in signs.iter().enumerate().rev() {
        if s <= 0.0 {
            out.push(order[i]);
        }
    }
    debug_assert_eq!(out.len(), order.len());
    out
}

/// Run one full balancing pass over `vs` (visited in `order`, centered at
/// `center`) and return (signs, max signed-prefix ℓ∞, max signed-prefix ℓ2).
/// Shared by the offline herding driver and the fig1/fig4 experiments.
pub fn balance_pass(
    balancer: &mut dyn Balancer,
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (Vec<f32>, f32, f32) {
    let d = center.len();
    let mut s = vec![0.0f32; d];
    let mut c = vec![0.0f32; d];
    let mut signs = Vec::with_capacity(order.len());
    let mut max_inf = 0.0f32;
    let mut max_l2 = 0.0f32;
    for &i in order {
        tensor::sub_into(&vs[i], center, &mut c);
        let eps = balancer.sign(&s, &c);
        tensor::axpy(eps, &c, &mut s);
        signs.push(eps);
        max_inf = max_inf.max(tensor::norm_inf(&s));
        max_l2 = max_l2.max(tensor::norm2(&s));
    }
    (signs, max_inf, max_l2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, gen};

    #[test]
    fn deterministic_sign_matches_norm_comparison() {
        prop::forall("alg5 == norm comparison", 64, |rng| {
            let (_, d) = gen::small_dims(rng, 1, 64);
            let s = gen::gauss_vec(rng, d, 1.0);
            let c = gen::gauss_vec(rng, d, 1.0);
            let mut b = DeterministicBalancer;
            let eps = b.sign(&s, &c);
            let mut plus = s.clone();
            let mut minus = s.clone();
            tensor::axpy(1.0, &c, &mut plus);
            tensor::axpy(-1.0, &c, &mut minus);
            let want = if tensor::norm2(&plus) < tensor::norm2(&minus) {
                1.0
            } else {
                -1.0
            };
            // Near-ties can flip under f32; only check clear cases.
            if (tensor::norm2(&plus) - tensor::norm2(&minus)).abs() > 1e-4 {
                if eps != want {
                    return Err(format!("eps={eps} want={want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_is_scale_invariant() {
        prop::forall("alg5 scale invariance", 32, |rng| {
            let d = 32;
            let s = gen::gauss_vec(rng, d, 1.0);
            let c = gen::gauss_vec(rng, d, 1.0);
            let mut b = DeterministicBalancer;
            let e1 = b.sign(&s, &c);
            let s2: Vec<f32> = s.iter().map(|x| x * 100.0).collect();
            let c2: Vec<f32> = c.iter().map(|x| x * 100.0).collect();
            let e2 = b.sign(&s2, &c2);
            if e1 != e2 {
                return Err("not scale invariant".into());
            }
            Ok(())
        });
    }

    #[test]
    fn alg5_prefix_sums_stay_bounded_on_random_vectors() {
        // The signed prefix sum under Alg 5 should grow much slower than
        // the unsigned sum (which grows like sqrt(n) per coordinate).
        let mut rng = Rng::new(0);
        let (n, d) = (2000, 16);
        let vs = gen::vec_set(&mut rng, n, d);
        let center = vec![0.0f32; d];
        let order: Vec<usize> = (0..n).collect();
        let mut b = DeterministicBalancer;
        let (_, max_inf, _) = balance_pass(&mut b, &vs, &center, &order);
        // Unsigned prefix reaches ~sqrt(n) per coordinate ≈ 44; balanced
        // should stay way below.
        let (unsigned_inf, _) = tensor::prefix_bounds(&vs, &center, &order);
        assert!(
            max_inf < unsigned_inf / 2.0,
            "balanced {max_inf} vs unsigned {unsigned_inf}"
        );
    }

    #[test]
    fn walk_balancer_bounded_and_counts_failures() {
        let mut rng = Rng::new(1);
        let (n, d) = (1000, 16);
        let vs = gen::vec_set(&mut rng, n, d);
        let center = vec![0.0f32; d];
        let order: Vec<usize> = (0..n).collect();
        let c = WalkBalancer::theorem_c(n, d, 0.01);
        let mut b = WalkBalancer::new(c, 7);
        let (signs, _, _) = balance_pass(&mut b, &vs, &center, &order);
        assert_eq!(signs.len(), n);
        assert!(signs.iter().all(|&s| s == 1.0 || s == -1.0));
        // With Theorem-4 c, failures should be rare (typically zero).
        assert!(b.failures <= n / 100, "failures={}", b.failures);
    }

    #[test]
    fn reorder_positives_then_reversed_negatives() {
        let order = [10usize, 11, 12, 13, 14];
        let signs = [1.0f32, -1.0, 1.0, -1.0, -1.0];
        assert_eq!(reorder(&order, &signs), vec![10, 12, 14, 13, 11]);
    }

    #[test]
    fn reorder_is_permutation() {
        prop::forall("reorder permutation", 64, |rng| {
            let n = 1 + rng.gen_range(200) as usize;
            let order: Vec<usize> = rng.permutation(n);
            let signs: Vec<f32> = (0..n)
                .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
                .collect();
            let new = reorder(&order, &signs);
            let mut sorted = new.clone();
            sorted.sort_unstable();
            let mut want = order.clone();
            want.sort_unstable();
            if sorted != want {
                return Err("not a permutation of input".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reorder_all_positive_is_identity() {
        let order = [3usize, 1, 2];
        let signs = [1.0f32, 1.0, 1.0];
        assert_eq!(reorder(&order, &signs), vec![3, 1, 2]);
    }

    #[test]
    fn reorder_all_negative_is_reverse() {
        let order = [3usize, 1, 2];
        let signs = [-1.0f32, -1.0, -1.0];
        assert_eq!(reorder(&order, &signs), vec![2, 1, 3]);
    }
}
