//! Shard topology plans — how a CD-GraB coordinator lays its `n`
//! ordering units out over W shard balancers, and how that layout may
//! change between epochs.
//!
//! CD-GraB (Cooper et al. 2023) assumes W equally-sized, always-healthy
//! workers. Production workers are neither: throughput is uneven,
//! links drop mid-run, and fleets resize. The GraB guarantee (Lu et
//! al. 2022) only needs every example balanced once per epoch — the
//! shard *partition* is free to change at epoch boundaries. This module
//! supplies the pieces that make that safe and replayable:
//!
//! * [`split_units_weighted`] — deterministic largest-remainder
//!   apportionment of `0..n` into contiguous ranges proportional to
//!   integer shard weights (the equal-weight case reproduces the
//!   classic sizes-differ-by-at-most-one split exactly);
//! * [`Topology`] — one epoch's frozen plan (generation counter,
//!   weights, sizes, base offsets), recorded per epoch by
//!   [`crate::ordering::ShardedOrder`] and surfaced through
//!   `TrainResult` and the `exp cdgrab` CSV so any elastic run can be
//!   re-executed from its recorded weight schedule;
//! * [`ElasticPlanner`] — derives the next epoch's weights from the
//!   coordinator's observed per-shard link costs (EWMA over per-row
//!   blocked time, which includes queue-stall waits), **quantized** to
//!   small integers with a hysteresis band so healthy symmetric runs
//!   never re-plan — frozen weights keep an elastic run bit-identical
//!   to the equivalent static topology (determinism contract 6,
//!   `docs/determinism.md`);
//! * [`WeightSource`] — where an elastic coordinator's next weights
//!   come from: measured (production) or a pinned per-epoch schedule
//!   (replay of a recorded run, tests).
//!
//! Weights are plain integers so plans serialize losslessly ("1:1:4")
//! and replay is exact; wall-clock measurement only ever enters through
//! the planner, whose output is recorded.

/// Upper quantization bucket for measured weights: the fastest shard
/// maps to this weight, slower shards to proportionally smaller
/// integers (minimum 1). Small enough that plans stay readable and
/// stable, large enough to express an 8× throughput skew.
pub const WEIGHT_SCALE: u64 = 8;

/// Minimum per-row cost ratio (slowest / fastest shard) before the
/// measured planner moves weight toward the fast shards. Below this
/// the skew is treated as noise — the hysteresis that keeps contract
/// 6's "frozen weights ≡ static topology" the common case.
pub const IMBALANCE_THRESHOLD: f64 = 1.5;

/// Ratio at or below which a previously skewed plan is considered
/// *recovered* and snapped back to equal weights. Strictly less than
/// [`IMBALANCE_THRESHOLD`], so a skew hovering near one threshold
/// holds the current plan instead of oscillating between re-plans
/// (each re-plan resets balancer state); without this lower edge a
/// single noisy epoch's skew would ratchet in forever.
pub const RECOVERY_THRESHOLD: f64 = 1.2;

/// Absolute noise floor on the per-row blocked-time EWMA (seconds).
/// When even the *slowest* shard sits below this, the links are
/// keeping up and the measured "skew" is scheduler/clock jitter — a
/// ratio over microsecond-scale residue must not re-plan (each re-plan
/// resets balancer state). Sub-floor epochs are treated as healthy:
/// the plan snaps to (or stays at) equal weights.
pub const MIN_SIGNAL_PER_ROW: f64 = 1e-6;

/// Split `n` units into `weights.len()` contiguous ranges with sizes
/// proportional to the weights, by largest-remainder apportionment.
/// Returns `(sizes, bases)` with `bases[w]` the global id of shard
/// `w`'s local unit 0.
///
/// Deterministic and stable: exact quotas `n·w/Σw` are floored, then
/// the leftover units go to the largest fractional remainders (ties to
/// the lower shard index). An all-zero weight vector is treated as all
/// ones. When `n >= W`, zero-sized shards (zero or tiny weights) are
/// clamped up to one unit, taken from the largest shard — every live
/// shard owns at least one unit so its balancer participates; when
/// `n < W` the trailing shards stay empty, as in the equal split.
pub fn split_units_weighted(
    n: usize,
    weights: &[u64],
) -> (Vec<usize>, Vec<usize>) {
    let w_count = weights.len();
    assert!(w_count >= 1, "need at least one shard");
    let ones;
    let eff: &[u64] = if weights.iter().all(|&w| w == 0) {
        ones = vec![1u64; w_count];
        &ones
    } else {
        weights
    };
    let sum: u128 = eff.iter().map(|&w| w as u128).sum();
    let mut sizes = vec![0usize; w_count];
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(w_count);
    let mut allocated = 0usize;
    for (w, &weight) in eff.iter().enumerate() {
        let num = n as u128 * weight as u128;
        sizes[w] = (num / sum) as usize;
        allocated += sizes[w];
        rems.push((num % sum, w));
    }
    // Largest remainder first; ties broken by the lower shard index so
    // the apportionment is a pure function of (n, weights).
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, w) in rems.iter().take(n - allocated) {
        sizes[w] += 1;
    }
    // Clamp: with at least one unit per shard available, no shard may
    // end up empty (a zero/tiny weight still owns one unit). The donor
    // is always the current largest shard, which must hold >= 2 units
    // while any shard holds 0 and n >= W.
    if n >= w_count {
        loop {
            let Some(zero) = sizes.iter().position(|&s| s == 0) else {
                break;
            };
            let mut donor = 0usize;
            for (w, &s) in sizes.iter().enumerate() {
                if s > sizes[donor] {
                    donor = w;
                }
            }
            debug_assert!(sizes[donor] >= 2);
            sizes[donor] -= 1;
            sizes[zero] += 1;
        }
    }
    let mut bases = Vec::with_capacity(w_count);
    let mut start = 0usize;
    for &s in &sizes {
        bases.push(start);
        start += s;
    }
    debug_assert_eq!(start, n);
    (sizes, bases)
}

/// One epoch's frozen shard layout: which weights were in force, the
/// sizes/bases they apportioned, and the re-plan generation. Recording
/// one `Topology` per epoch is what makes elastic runs replayable —
/// re-running with the recorded weight schedule reproduces every merge
/// bit for bit (contract 6).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Monotone re-plan counter: 0 for the construction-time plan,
    /// bumped every time the coordinator re-splits and re-handshakes.
    /// Carried in the TCP `Hello` so workers can tell a re-handshake
    /// from a duplicate connection.
    pub generation: u64,
    /// Integer shard weights the sizes were apportioned from.
    pub weights: Vec<u64>,
    /// Units owned by each shard (sums to the coordinator's `n`).
    pub sizes: Vec<usize>,
    /// Global unit id of each shard's local unit 0.
    pub bases: Vec<usize>,
}

impl Topology {
    /// Plan a topology: apportion `n` units over `weights` at the given
    /// generation.
    pub fn plan(n: usize, generation: u64, weights: &[u64]) -> Topology {
        let (sizes, bases) = split_units_weighted(n, weights);
        Topology {
            generation,
            weights: weights.to_vec(),
            sizes,
            bases,
        }
    }

    /// The classic CD-GraB layout: `num_shards` equal weights at
    /// generation 0 (sizes differ by at most one).
    pub fn equal(n: usize, num_shards: usize) -> Topology {
        Topology::plan(n, 0, &vec![1u64; num_shards])
    }

    /// Number of shards (CD-GraB's W) in this plan.
    pub fn num_shards(&self) -> usize {
        self.weights.len()
    }

    /// The weights as a compact `"1:1:4"` label (CSV / log column).
    pub fn weights_label(&self) -> String {
        let parts: Vec<String> =
            self.weights.iter().map(|w| w.to_string()).collect();
        parts.join(":")
    }
}

/// Parse a `"1:1:4"` / `"1,1,4"` weights label back into a weight
/// vector (the inverse of [`Topology::weights_label`]; also the parser
/// behind the `--weights` CLI flag and the `weights` TOML key).
pub fn parse_weights(s: &str) -> Result<Vec<u64>, String> {
    let parts: Vec<&str> = s
        .split(|c| c == ':' || c == ',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .collect();
    if parts.is_empty() {
        return Err("empty weights list".to_string());
    }
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        match p.parse::<u64>() {
            Ok(w) => out.push(w),
            Err(_) => {
                return Err(format!(
                    "weight {p:?} is not a non-negative integer"
                ))
            }
        }
    }
    if out.iter().all(|&w| w == 0) {
        return Err("weights must not be all zero".to_string());
    }
    Ok(out)
}

/// Derives the next epoch's integer shard weights from the
/// coordinator's measured per-shard link costs.
///
/// Per epoch the coordinator reports, for each shard, the seconds it
/// spent blocked on that shard's link (scratch acquisition + block
/// sends — queue stalls and full socket buffers both land here) and
/// the rows it shipped. The planner folds per-row cost into an EWMA,
/// inverts it into a relative speed, and quantizes speeds onto
/// `1..=WEIGHT_SCALE` (gcd-reduced). Two stabilizers keep plans
/// replayable and calm:
///
/// * **two-threshold hysteresis** — weight moves toward the fast
///   shards only when the slowest/fastest per-row cost ratio exceeds
///   [`IMBALANCE_THRESHOLD`], snaps back to equal weights once the
///   ratio falls to [`RECOVERY_THRESHOLD`] or below (a past skew does
///   not ratchet in forever), and holds the current plan in between —
///   so a healthy symmetric run never re-plans (contract 6's frozen
///   case) and a skew hovering near one threshold cannot oscillate;
/// * **quantization** — output weights are small integers, so the
///   recorded per-epoch plan replays exactly via
///   [`WeightSource::Schedule`].
#[derive(Clone, Debug)]
pub struct ElasticPlanner {
    /// EWMA of per-row blocked seconds per live shard, in shard order.
    ewma: Vec<f64>,
    /// EWMA smoothing factor in (0, 1]: weight of the newest epoch.
    alpha: f64,
}

impl ElasticPlanner {
    /// A planner over `num_shards` initial shards with the default
    /// smoothing factor.
    pub fn new(num_shards: usize) -> ElasticPlanner {
        ElasticPlanner { ewma: vec![0.0; num_shards], alpha: 0.4 }
    }

    /// The smoothed per-row cost per live shard, in shard order — the
    /// planner's full mutable state, exposed so an elastic
    /// coordinator's snapshot can carry it across a resume
    /// (docs/determinism.md contract 8).
    pub fn ewma(&self) -> &[f64] {
        &self.ewma
    }

    /// Rebuild a planner from a snapshotted [`ElasticPlanner::ewma`]
    /// vector. The restored planner folds future epochs exactly as the
    /// snapshotted one would have: same smoothing factor, same
    /// history-in-aggregate.
    pub fn from_ewma(ewma: Vec<f64>) -> ElasticPlanner {
        ElasticPlanner { ewma, alpha: 0.4 }
    }

    /// Fold one epoch of observations and return the next epoch's
    /// weights **over the surviving shards**, in shard order.
    ///
    /// `costs[w]` / `rows[w]` are the epoch's blocked seconds and
    /// shipped rows for shard `w`; `alive[w]` is false for a shard
    /// whose link failed this epoch (its entry is dropped from the
    /// planner's state and from the returned weights). `current` is
    /// the weight vector in force. All slices must have the planner's
    /// current shard count.
    pub fn plan(
        &mut self,
        costs: &[f64],
        rows: &[usize],
        alive: &[bool],
        current: &[u64],
    ) -> Vec<u64> {
        assert_eq!(costs.len(), self.ewma.len());
        assert_eq!(rows.len(), self.ewma.len());
        assert_eq!(alive.len(), self.ewma.len());
        assert_eq!(current.len(), self.ewma.len());
        for w in 0..self.ewma.len() {
            if alive[w] && rows[w] > 0 {
                let per_row = costs[w] / rows[w] as f64;
                self.ewma[w] = if self.ewma[w] == 0.0 {
                    per_row
                } else {
                    self.alpha * per_row
                        + (1.0 - self.alpha) * self.ewma[w]
                };
            }
        }
        // Compact to the survivors.
        let mut surv_ewma = Vec::new();
        let mut surv_current = Vec::new();
        for w in 0..self.ewma.len() {
            if alive[w] {
                surv_ewma.push(self.ewma[w]);
                surv_current.push(current[w]);
            }
        }
        self.ewma = surv_ewma;
        // No confident signal (a shard without measurements yet):
        // keep the current weights.
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for &e in &self.ewma {
            lo = lo.min(e);
            hi = hi.max(e);
        }
        if self.ewma.is_empty()
            || lo <= 0.0
            || !lo.is_finite()
            || !hi.is_finite()
        {
            return surv_current;
        }
        let ratio = hi / lo;
        if hi < MIN_SIGNAL_PER_ROW || ratio <= RECOVERY_THRESHOLD {
            // Healthy fleet — links keeping up (sub-floor residue) or
            // skew inside the recovery band: snap a previously skewed
            // plan back to equal weights (no-op when already equal).
            return vec![1; self.ewma.len()];
        }
        if ratio < IMBALANCE_THRESHOLD {
            // Inside the hysteresis band: hold the current plan.
            return surv_current;
        }
        // Quantize relative speeds (1/cost) onto 1..=WEIGHT_SCALE.
        let max_speed = 1.0 / lo;
        let mut weights: Vec<u64> = self
            .ewma
            .iter()
            .map(|&e| {
                let s = (1.0 / e) / max_speed;
                ((s * WEIGHT_SCALE as f64).round() as u64)
                    .clamp(1, WEIGHT_SCALE)
            })
            .collect();
        let g = weights.iter().copied().fold(0, gcd);
        if g > 1 {
            for w in weights.iter_mut() {
                *w /= g;
            }
        }
        weights
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// One window's frozen reservoir membership — the streaming analogue
/// of [`Topology`]. Where `Topology` re-plans the unit *ranges* a fixed
/// set of `n` units is split into at each epoch boundary, a
/// `ReservoirPlan` re-plans the unit *set* itself at each window
/// boundary: which external units are live, which slot each occupies,
/// and which were admitted, retired, or evicted by the boundary's
/// events. [`crate::ordering::StreamOrder`] records one plan per
/// window, so a streamed run replays bit-for-bit from its logged event
/// schedule — the same discipline that makes elastic topologies
/// replayable (contract 6), extended to membership (contract 9,
/// `docs/determinism.md`).
///
/// Slot discipline (what keeps balancer state meaningful across a
/// boundary): surviving units **keep their slot**, admitted units fill
/// the lowest freed slots first (inheriting the departed unit's
/// position in the balancer's next order), overflow admits append new
/// slots, and only a net shrink compacts slots downward (ascending, so
/// survivor order is preserved). Eviction is FIFO by admission
/// sequence number: when admits would push the live count past
/// `capacity`, the oldest-admitted survivors leave first.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReservoirPlan {
    /// Monotone membership-change counter: 0 for the initial fill,
    /// bumped at every boundary whose events changed the live set.
    pub generation: u64,
    /// External unit id living in each slot (`units[slot]`); slots are
    /// the reservoir's contiguous balancing indices `0..len`.
    pub units: Vec<u64>,
    /// Admission sequence number of each slot's unit (FIFO eviction
    /// key; unique per admission, never reused).
    pub admit_seq: Vec<u64>,
    /// Next admission sequence number to hand out.
    pub next_seq: u64,
    /// Units admitted by the boundary that produced this plan.
    pub admitted: Vec<u64>,
    /// Units retired (explicitly removed) by that boundary.
    pub retired: Vec<u64>,
    /// Units evicted (FIFO overflow) by that boundary.
    pub evicted: Vec<u64>,
}

/// The result of advancing a [`ReservoirPlan`] across one window
/// boundary: the next plan plus the slot relabeling the balancer needs
/// to carry its state (next order, cached gradients, signs) across the
/// membership change.
#[derive(Debug)]
pub struct ReservoirStep {
    /// The next window's plan.
    pub plan: ReservoirPlan,
    /// `slot_map[old_slot]` is the unit's new slot, or `None` when the
    /// old slot's unit departed and no admit back-filled the slot.
    /// Identity (modulo `None`s) unless the boundary shrank the
    /// reservoir.
    pub slot_map: Vec<Option<usize>>,
    /// New slots beyond the old reservoir length, occupied by overflow
    /// admits (ascending). These units have no position in the old
    /// order and are appended at the back of the next window's order.
    pub appended: Vec<usize>,
    /// Whether the live set changed at all (admit, retire, or evict).
    pub changed: bool,
    /// Whether the live *count* changed — a resized reservoir forces
    /// the balancer to rebuild over the new slot range.
    pub resized: bool,
}

impl ReservoirPlan {
    /// The initial fill: `units` occupy slots `0..len` with admission
    /// sequence numbers `0..len`. Unit ids must be distinct.
    pub fn initial(units: &[u64]) -> ReservoirPlan {
        for (i, u) in units.iter().enumerate() {
            assert!(
                !units[..i].contains(u),
                "duplicate unit {u} in initial reservoir"
            );
        }
        ReservoirPlan {
            generation: 0,
            units: units.to_vec(),
            admit_seq: (0..units.len() as u64).collect(),
            next_seq: units.len() as u64,
            admitted: units.to_vec(),
            retired: Vec::new(),
            evicted: Vec::new(),
        }
    }

    /// Number of live units (occupied slots).
    pub fn len(&self) -> usize {
        self.units.len()
    }

    /// Whether the reservoir is empty.
    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Slot of `unit`, if live.
    pub fn slot_of(&self, unit: u64) -> Option<usize> {
        self.units.iter().position(|&u| u == unit)
    }

    /// Compact `"+a/-r/~e"` label of the boundary's events (admits /
    /// retires / evictions) for logs and CSV columns.
    pub fn events_label(&self) -> String {
        format!(
            "+{}/-{}/~{}",
            self.admitted.len(),
            self.retired.len(),
            self.evicted.len()
        )
    }

    /// Advance the membership across one window boundary: apply
    /// `retires` (each must name a live unit), then `admits` (each must
    /// be fresh — not live and not retiring this boundary), evicting
    /// the oldest-admitted survivors FIFO whenever the live count would
    /// exceed `capacity`. Pure in its inputs — the same (plan, events,
    /// capacity) always produce the same step, which is what makes a
    /// frozen admit/retire schedule replay bit-for-bit.
    pub fn advance(
        &self,
        admits: &[u64],
        retires: &[u64],
        capacity: usize,
    ) -> ReservoirStep {
        assert!(capacity >= 1, "reservoir capacity must be positive");
        let old_n = self.units.len();
        // Slot state while applying events: Some((unit, seq)) = occupied.
        let mut slots: Vec<Option<(u64, u64)>> = self
            .units
            .iter()
            .zip(&self.admit_seq)
            .map(|(&u, &s)| Some((u, s)))
            .collect();
        let mut retired = Vec::new();
        for &r in retires {
            let slot = slots
                .iter()
                .position(|e| matches!(e, Some((u, _)) if *u == r))
                .unwrap_or_else(|| {
                    panic!("retire of unit {r} which is not live")
                });
            slots[slot] = None;
            retired.push(r);
        }
        for (i, a) in admits.iter().enumerate() {
            assert!(
                !admits[..i].contains(a),
                "duplicate admit of unit {a}"
            );
            assert!(
                !slots
                    .iter()
                    .any(|e| matches!(e, Some((u, _)) if u == a)),
                "admit of unit {a} which is already live"
            );
        }
        // FIFO eviction: make room for the admits within capacity.
        let live = slots.iter().filter(|e| e.is_some()).count();
        let over = (live + admits.len()).saturating_sub(capacity);
        let mut evicted = Vec::new();
        for _ in 0..over {
            let oldest = slots
                .iter()
                .enumerate()
                .filter_map(|(i, e)| e.map(|(u, s)| (s, i, u)))
                .min()
                .expect("eviction from an empty reservoir");
            slots[oldest.1] = None;
            evicted.push(oldest.2);
        }
        // Admits fill the lowest freed slots first, then append.
        let mut next_seq = self.next_seq;
        for &a in admits {
            let seq = next_seq;
            next_seq += 1;
            match slots.iter().position(|e| e.is_none()) {
                Some(free) => slots[free] = Some((a, seq)),
                None => slots.push(Some((a, seq))),
            }
        }
        // Compact remaining holes (net shrink) ascending; otherwise the
        // relabeling is the identity on occupied slots.
        let mut slot_map = vec![None; old_n];
        let mut appended = Vec::new();
        let mut units = Vec::new();
        let mut admit_seq = Vec::new();
        for (old_slot, entry) in slots.iter().enumerate() {
            let Some((u, s)) = entry else { continue };
            let new_slot = units.len();
            if old_slot < old_n {
                slot_map[old_slot] = Some(new_slot);
            } else {
                appended.push(new_slot);
            }
            units.push(*u);
            admit_seq.push(*s);
        }
        let changed = !(retired.is_empty()
            && evicted.is_empty()
            && admits.is_empty());
        let resized = units.len() != old_n;
        ReservoirStep {
            plan: ReservoirPlan {
                generation: self.generation + u64::from(changed),
                units,
                admit_seq,
                next_seq,
                admitted: admits.to_vec(),
                retired,
                evicted,
            },
            slot_map,
            appended,
            changed,
            resized,
        }
    }
}

/// Where an elastic coordinator's next-epoch weights come from.
pub enum WeightSource {
    /// Measure link costs and re-plan when the skew is sustained (the
    /// production mode behind `--elastic`).
    Measured(ElasticPlanner),
    /// A pinned per-epoch weight schedule: entry `e` is the weight
    /// vector for epoch `e` (the last entry repeats). This is how a
    /// recorded elastic run — including mid-run shard-count changes —
    /// is replayed bit-for-bit, and how contract-6 tests freeze the
    /// plan deterministically.
    Schedule(Vec<Vec<u64>>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Reference equal split (the pre-elastic `split_units` semantics:
    /// sizes differ by at most one, larger shards first).
    fn equal_split(n: usize, w: usize) -> Vec<usize> {
        (0..w).map(|i| n / w + usize::from(i < n % w)).collect()
    }

    #[test]
    fn equal_weights_reproduce_the_classic_split() {
        prop::forall("weighted split equal == classic", 64, |rng| {
            let n = rng.gen_range(200) as usize;
            let w = 1 + rng.gen_range(12) as usize;
            let (sizes, bases) =
                split_units_weighted(n, &vec![1u64; w]);
            if sizes != equal_split(n, w) {
                return Err(format!(
                    "n={n} w={w}: {sizes:?} != {:?}",
                    equal_split(n, w)
                ));
            }
            let mut start = 0;
            for (b, s) in bases.iter().zip(&sizes) {
                if *b != start {
                    return Err(format!("bases not contiguous: {bases:?}"));
                }
                start += s;
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_split_covers_disjointly_and_proportionally() {
        // Satellite property test: disjoint cover of 0..n, exact weight
        // proportions up to rounding (quota within 1 unit before any
        // >=1 clamping), deterministic stable ordering.
        prop::forall("weighted split cover + proportion", 128, |rng| {
            let n = rng.gen_range(500) as usize;
            let w = 1 + rng.gen_range(9) as usize;
            let weights: Vec<u64> =
                (0..w).map(|_| rng.gen_range(17)).collect();
            let (sizes, bases) = split_units_weighted(n, &weights);
            let (sizes2, bases2) = split_units_weighted(n, &weights);
            if sizes != sizes2 || bases != bases2 {
                return Err("split is not deterministic".to_string());
            }
            // Disjoint contiguous cover of 0..n.
            let mut start = 0usize;
            for (b, s) in bases.iter().zip(&sizes) {
                if *b != start {
                    return Err(format!(
                        "shard base {b} != running start {start}"
                    ));
                }
                start += s;
            }
            if start != n {
                return Err(format!("cover ends at {start}, n={n}"));
            }
            // Proportionality: when no clamping was needed (every
            // apportioned shard nonzero or n < w), each size is within
            // one unit of its exact quota.
            let sum: f64 = if weights.iter().all(|&x| x == 0) {
                w as f64
            } else {
                weights.iter().sum::<u64>() as f64
            };
            let quota = |i: usize| -> f64 {
                let wi = if weights.iter().all(|&x| x == 0) {
                    1.0
                } else {
                    weights[i] as f64
                };
                n as f64 * wi / sum
            };
            let clamped = n >= w
                && (0..w).any(|i| (quota(i).floor() as usize) == 0);
            if !clamped {
                for (i, &s) in sizes.iter().enumerate() {
                    let q = quota(i);
                    if (s as f64 - q).abs() >= 1.0 {
                        return Err(format!(
                            "shard {i}: size {s} vs quota {q} \
                             (weights {weights:?}, n={n})"
                        ));
                    }
                }
            }
            // Clamp invariant: with n >= w every shard owns >= 1 unit.
            if n >= w && sizes.iter().any(|&s| s == 0) {
                return Err(format!(
                    "empty shard despite n={n} >= w={w}: {sizes:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_split_edge_cases() {
        // Fewer units than shards: trailing shards empty, like the
        // equal split.
        let (sizes, _) = split_units_weighted(2, &[1, 1, 1, 1]);
        assert_eq!(sizes.iter().sum::<usize>(), 2);
        assert_eq!(sizes.iter().filter(|&&s| s > 0).count(), 2);
        // A zero-weight shard is clamped to one unit when n >= W.
        let (sizes, _) = split_units_weighted(10, &[0, 1, 1]);
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes[0] >= 1, "zero-weight shard got {sizes:?}");
        // All-zero weights degrade to the equal split.
        let (sizes, _) = split_units_weighted(9, &[0, 0, 0]);
        assert_eq!(sizes, vec![3, 3, 3]);
        // Heavy skew: proportions hold.
        let (sizes, bases) = split_units_weighted(60, &[1, 1, 4]);
        assert_eq!(sizes, vec![10, 10, 40]);
        assert_eq!(bases, vec![0, 10, 20]);
        // W shrinking between epochs: the same n re-splits cleanly
        // over fewer shards (the mid-run shard-loss path).
        let (s4, _) = split_units_weighted(13, &[1, 1, 1, 1]);
        let (s3, b3) = split_units_weighted(13, &[1, 1, 1]);
        assert_eq!(s4.iter().sum::<usize>(), 13);
        assert_eq!(s3.iter().sum::<usize>(), 13);
        assert_eq!(b3, vec![0, 5, 9]);
        // Single shard owns everything.
        let (sizes, bases) = split_units_weighted(7, &[3]);
        assert_eq!((sizes, bases), (vec![7], vec![0]));
    }

    #[test]
    fn weights_label_roundtrip() {
        let t = Topology::plan(60, 2, &[1, 1, 4]);
        assert_eq!(t.weights_label(), "1:1:4");
        assert_eq!(parse_weights("1:1:4").unwrap(), vec![1, 1, 4]);
        assert_eq!(parse_weights("2,3").unwrap(), vec![2, 3]);
        assert!(parse_weights("").is_err());
        assert!(parse_weights("0,0").is_err());
        assert!(parse_weights("a,b").is_err());
        assert_eq!(t.num_shards(), 3);
        assert_eq!(t.generation, 2);
    }

    #[test]
    fn planner_freezes_inside_the_hysteresis_band() {
        // Near-identical per-row costs: the plan must not move off the
        // current weights (contract 6's frozen case).
        let mut p = ElasticPlanner::new(3);
        let current = vec![1u64, 1, 1];
        for _ in 0..5 {
            let w = p.plan(
                &[1.0e-3, 1.05e-3, 0.97e-3],
                &[100, 100, 100],
                &[true, true, true],
                &current,
            );
            assert_eq!(w, current);
        }
    }

    #[test]
    fn planner_recovers_to_equal_weights_after_a_transient_skew() {
        // A plan skewed by a past noisy epoch must not ratchet in: once
        // the measured ratio is back under the recovery threshold the
        // weights snap back to equal.
        let mut p = ElasticPlanner::new(2);
        let w = p.plan(
            &[1.0e-3, 1.02e-3],
            &[100, 100],
            &[true, true],
            &[1, 4], // inherited skew from an earlier epoch
        );
        assert_eq!(w, vec![1, 1], "healthy fleet must re-balance");
        // In the dead band between recovery and imbalance thresholds,
        // the current plan holds (no oscillation).
        let mut p = ElasticPlanner::new(2);
        let w = p.plan(
            &[1.3e-3, 1.0e-3],
            &[100, 100],
            &[true, true],
            &[1, 2],
        );
        assert_eq!(w, vec![1, 2], "dead band must hold the plan");
    }

    #[test]
    fn planner_quantizes_a_sustained_skew() {
        // One shard 4x slower per row: after the EWMA settles the plan
        // must shift weight away from it, with integer weights.
        let mut p = ElasticPlanner::new(2);
        let mut w = vec![1u64, 1];
        for _ in 0..8 {
            w = p.plan(
                &[4.0e-3, 1.0e-3],
                &[100, 100],
                &[true, true],
                &w,
            );
        }
        assert!(w[1] > w[0], "fast shard must outweigh slow: {w:?}");
        assert!(w.iter().all(|&x| (1..=WEIGHT_SCALE).contains(&x)));
    }

    #[test]
    fn planner_ignores_sub_floor_jitter() {
        // Microsecond-scale blocked-time residue on an unloaded
        // machine: even a large *ratio* over sub-floor costs must not
        // skew the plan — that would quantize clock jitter.
        let mut p = ElasticPlanner::new(2);
        let w = p.plan(
            &[3.0e-8, 1.0e-8], // 3x ratio, but ~0 absolute
            &[100, 100],
            &[true, true],
            &[1, 1],
        );
        assert_eq!(w, vec![1, 1], "jitter must not re-plan: {w:?}");
    }

    #[test]
    fn planner_drops_lost_shards() {
        let mut p = ElasticPlanner::new(3);
        let w = p.plan(
            &[1.0e-3, 1.0e-3, 1.0e-3],
            &[10, 10, 10],
            &[true, false, true],
            &[1, 1, 1],
        );
        assert_eq!(w.len(), 2, "lost shard must be dropped: {w:?}");
        // Next epoch's slices have the shrunken length.
        let w = p.plan(
            &[1.0e-3, 1.0e-3],
            &[10, 10],
            &[true, true],
            &w,
        );
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn planner_restored_from_ewma_plans_like_the_original() {
        // Contract 8 for the elastic planner: a planner rebuilt from a
        // snapshotted EWMA vector must produce the same plan sequence
        // as the one that kept running — a resume must not forget the
        // smoothed skew history. (Before the fix, restore_state
        // replaced the planner with a cold one, so the first
        // post-resume epoch re-planned from scratch.)
        let mut live = ElasticPlanner::new(2);
        let mut w = vec![1u64, 1];
        for _ in 0..4 {
            w = live.plan(
                &[4.0e-3, 1.0e-3],
                &[100, 100],
                &[true, true],
                &w,
            );
        }
        let mut resumed = ElasticPlanner::from_ewma(live.ewma().to_vec());
        assert_eq!(resumed.ewma(), live.ewma());
        let mut wl = w.clone();
        let mut wr = w;
        for (costs, rows) in [
            ([4.0e-3, 1.0e-3], [100usize, 100]),
            ([1.0e-3, 1.0e-3], [100, 100]),
            ([2.0e-3, 1.0e-3], [50, 150]),
        ] {
            wl = live.plan(&costs, &rows, &[true, true], &wl);
            wr = resumed.plan(&costs, &rows, &[true, true], &wr);
            assert_eq!(wl, wr, "resumed planner diverged");
        }
        // A cold planner does NOT match — the history matters, which
        // is exactly why the snapshot carries it.
        let mut cold = ElasticPlanner::new(2);
        let wc = cold.plan(
            &[1.0e-3, 1.0e-3],
            &[100, 100],
            &[true, true],
            &[1, 4],
        );
        assert_eq!(wc, vec![1, 1], "cold planner re-balances instantly");
    }

    #[test]
    fn reservoir_static_boundary_is_identity() {
        let plan = ReservoirPlan::initial(&[10, 11, 12, 13]);
        let step = plan.advance(&[], &[], 4);
        assert!(!step.changed);
        assert!(!step.resized);
        assert_eq!(step.plan.generation, 0);
        assert_eq!(step.plan.units, vec![10, 11, 12, 13]);
        assert_eq!(
            step.slot_map,
            vec![Some(0), Some(1), Some(2), Some(3)]
        );
        assert!(step.appended.is_empty());
    }

    #[test]
    fn reservoir_admit_fills_lowest_freed_slot() {
        // Retire the unit in slot 1; the admit inherits that slot (and
        // with it the departed unit's position in the next order).
        let plan = ReservoirPlan::initial(&[10, 11, 12, 13]);
        let step = plan.advance(&[99], &[11], 4);
        assert!(step.changed);
        assert!(!step.resized, "count-neutral boundary keeps the size");
        assert_eq!(step.plan.units, vec![10, 99, 12, 13]);
        // The back-filled slot stays mapped (the admit inherits the
        // departed unit's order position); StreamOrder zeroes the
        // slot's gradient/sign caches via `plan.admitted`.
        assert_eq!(
            step.slot_map,
            vec![Some(0), Some(1), Some(2), Some(3)]
        );
        assert_eq!(step.plan.retired, vec![11]);
        assert_eq!(step.plan.admitted, vec![99]);
        assert_eq!(step.plan.generation, 1);
    }

    #[test]
    fn reservoir_evicts_fifo_when_full() {
        // Admitting into a full reservoir evicts the oldest-admitted
        // unit; the admit takes its freed slot, so the size holds.
        let plan = ReservoirPlan::initial(&[10, 11, 12]);
        let step = plan.advance(&[20], &[], 3);
        assert_eq!(step.plan.evicted, vec![10]);
        assert_eq!(step.plan.units, vec![20, 11, 12]);
        // A second boundary evicts the next-oldest (11), not the fresh
        // admit in slot 0 — FIFO is by admission sequence, not slot.
        let step2 = step.plan.advance(&[21], &[], 3);
        assert_eq!(step2.plan.evicted, vec![11]);
        assert_eq!(step2.plan.units, vec![20, 21, 12]);
    }

    #[test]
    fn reservoir_shrink_compacts_slots_ascending() {
        let plan = ReservoirPlan::initial(&[10, 11, 12, 13, 14]);
        let step = plan.advance(&[], &[11, 13], 5);
        assert!(step.resized);
        assert_eq!(step.plan.units, vec![10, 12, 14]);
        assert_eq!(
            step.slot_map,
            vec![Some(0), None, Some(1), None, Some(2)]
        );
        // Growth back up: one admit fills slot order at the end.
        let step2 = step.plan.advance(&[30, 31], &[], 5);
        assert!(step2.resized);
        assert_eq!(step2.plan.units, vec![10, 12, 14, 30, 31]);
        assert_eq!(step2.appended, vec![3, 4]);
    }

    #[test]
    fn reservoir_advance_is_pure() {
        let plan = ReservoirPlan::initial(&[1, 2, 3, 4, 5, 6]);
        let a = plan.advance(&[7, 8], &[2, 5], 6);
        let b = plan.advance(&[7, 8], &[2, 5], 6);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.slot_map, b.slot_map);
        assert_eq!(plan.events_label(), "+6/-0/~0");
        assert_eq!(a.plan.events_label(), "+2/-2/~0");
    }

    #[test]
    #[should_panic(expected = "not live")]
    fn reservoir_rejects_unknown_retires() {
        let plan = ReservoirPlan::initial(&[1, 2, 3]);
        let _ = plan.advance(&[], &[9], 3);
    }

    #[test]
    fn seeded_schedules_are_pure() {
        // Determinism spot-check used by replay: the same (n, weights)
        // always plan the same topology.
        let mut rng = Rng::new(11);
        for _ in 0..32 {
            let n = 1 + rng.gen_range(300) as usize;
            let w = 1 + rng.gen_range(6) as usize;
            let weights: Vec<u64> =
                (0..w).map(|_| rng.gen_range(9)).collect();
            assert_eq!(
                Topology::plan(n, 1, &weights),
                Topology::plan(n, 1, &weights)
            );
        }
    }
}
