//! GraB — Algorithm 4: SGD with Online Gradient Balancing, block-streamed.
//!
//! Per epoch k, for each visited unit (position t, dataset index
//! σ_k(t), fresh gradient g):
//!
//! 1. center with the *stale* mean of epoch k−1:  c = g − m_k      (line 6)
//! 2. accumulate the fresh mean: m_{k+1} += g / n                   (line 6)
//! 3. sign from the balancer:    ε = Balancing(s, c)                (line 7)
//! 4. two-ended order construction (lines 8–12):
//!      ε = +1 → σ_{k+1}(l) = σ_k(t), l += 1   (front, original order)
//!      ε = −1 → σ_{k+1}(r) = σ_k(t), r −= 1   (back → reversed order)
//!    and s += ε·c.
//!
//! This implements Algorithm 3's reorder *online*, so total ordering state
//! is s, m_k, m_{k+1} (3 d-vectors) plus two permutations — O(d + n), vs
//! Greedy Ordering's O(nd).
//!
//! **Block semantics.** [`GraBOrder::observe_block`] is the request-path
//! hot spot (benches/ordering_overhead.rs). With the deterministic
//! balancer it uses *batched balancing* in the GraB-sampler deployment
//! shape (Wei 2023): all B decision dots of a block are computed against
//! one refresh of the running sum s (`tensor::dot_centered_block`), and
//! the s / fresh-mean folds are deferred to once per block
//! (`tensor::sign_sum_accum` + `tensor::fold_signed_block`). A 1-row
//! block — the [`OrderPolicy::observe`] compatibility shim — reproduces
//! Algorithm 4's per-example semantics bit for bit; larger blocks trade
//! an O(√B) within-block balancing slack (self-correcting across blocks,
//! still far below random reshuffling's O(√n)) for ~1.6× fewer
//! flops/loads per example. Non-deterministic balancers (the Alg. 6 walk)
//! keep exact per-row sequencing, with the balancer dispatch hoisted out
//! of the row loop and a reused centering scratch instead of the old
//! per-example allocation.

use std::ops::Range;

use crate::balance::Balancer;
use crate::ordering::{GradBlock, OrderPolicy};
use crate::tensor::{self, Kernel};

/// The paper's GraB policy (Algorithm 4), block-streamed — see the
/// module docs for the balancing/reorder mechanics.
pub struct GraBOrder {
    n: usize,
    d: usize,
    balancer: Box<dyn Balancer + Send>,
    /// σ_k — the order being followed this epoch.
    current: Vec<usize>,
    /// σ_{k+1} under construction.
    next: Vec<usize>,
    /// Front / back fill pointers (paper's l and r).
    l: usize,
    r: usize,
    /// Signed running sum s.
    s: Vec<f32>,
    /// Stale mean m_k (centering) and fresh accumulator m_{k+1}.
    stale_mean: Vec<f32>,
    fresh_mean: Vec<f32>,
    /// Block scratch: per-row decision dots against the block-entry s.
    dots: Vec<f32>,
    /// Block scratch: Σ ε_i g_i over the current block.
    blk_signed: Vec<f32>,
    /// Block scratch: Σ g_i over the current block (fresh-mean fold).
    blk_sum: Vec<f32>,
    /// Block scratch: per-row signs of the current block.
    eps_buf: Vec<f32>,
    /// Centering scratch for non-deterministic balancers.
    scratch_c: Vec<f32>,
    /// Kernel tier the batched observe path dispatches through
    /// (bit-identical across tiers — determinism contract 7).
    kernel: Kernel,
    /// Diagnostics: max ‖s‖∞ observed this epoch (the balancing bound A),
    /// sampled once per block when a multiple of 16 observations is
    /// crossed (a full ℓ∞ scan per step would cost an extra pass over s).
    pub epoch_balance_inf: f32,
    /// Count of +1 signs this epoch (for tests/metrics).
    pub plus_signs: usize,
    observed: usize,
}

impl GraBOrder {
    /// A GraB policy over `n` units of dimension `d` using `balancer`
    /// for the sign decisions, dispatching through the process-default
    /// kernel tier ([`tensor::default_kernel`]).
    pub fn new(n: usize, d: usize, balancer: Box<dyn Balancer + Send>)
        -> GraBOrder {
        Self::with_kernel(n, d, balancer, tensor::default_kernel())
    }

    /// [`GraBOrder::new`] with an explicit kernel tier — used by the
    /// contract-7 equivalence tests and the bench runner (tests must
    /// not touch the process-global default).
    pub fn with_kernel(
        n: usize,
        d: usize,
        balancer: Box<dyn Balancer + Send>,
        kernel: Kernel,
    ) -> GraBOrder {
        // Only the scratch the active observe path needs is allocated
        // (and therefore reported by state_bytes): the batched path uses
        // the block accumulators, the sequential path one centering
        // vector.
        let batched = balancer.uses_centered_dot();
        GraBOrder {
            n,
            d,
            balancer,
            current: (0..n).collect(), // σ_1 = identity (any init works)
            next: vec![0; n],
            l: 0,
            r: n,
            s: vec![0.0; d],
            stale_mean: vec![0.0; d], // m_1 = 0 (paper line 1)
            fresh_mean: vec![0.0; d],
            dots: Vec::new(),
            blk_signed: if batched { vec![0.0; d] } else { Vec::new() },
            blk_sum: if batched { vec![0.0; d] } else { Vec::new() },
            eps_buf: Vec::new(),
            scratch_c: if batched { Vec::new() } else { vec![0.0; d] },
            kernel,
            epoch_balance_inf: 0.0,
            plus_signs: 0,
            observed: 0,
        }
    }

    /// The kernel tier this policy dispatches through (for logs).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The balancer's name (for logs).
    pub fn balancer_name(&self) -> &'static str {
        self.balancer.name()
    }

    /// Two-ended placement (Algorithm 4 lines 8–12).
    #[inline]
    fn place(&mut self, pos: usize, eps: f32) {
        let unit = self.current[pos];
        if eps > 0.0 {
            self.next[self.l] = unit;
            self.l += 1;
            self.plus_signs += 1;
        } else {
            self.r -= 1;
            self.next[self.r] = unit;
        }
    }

    /// Peek at the order under construction (tests only).
    #[cfg(test)]
    fn next_order_built(&self) -> &[usize] {
        &self.next
    }
}

impl OrderPolicy for GraBOrder {
    fn name(&self) -> &'static str {
        "grab"
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.current
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        let rows = block.rows();
        if rows == 0 {
            return;
        }
        debug_assert_eq!(block.dim(), self.d);
        debug_assert_eq!(range.len(), rows, "range/block row mismatch");
        debug_assert!(range.end <= self.n, "positions out of range");
        let inv_n = 1.0 / self.n as f32;

        if self.balancer.uses_centered_dot() {
            // Batched path: B decisions against one refresh of s, then a
            // single fold of s and the fresh mean for the whole block.
            // Every tensor pass dispatches through the selected kernel
            // tier; all tiers are bit-identical (contract 7), so the
            // signs — and therefore the orders — never depend on it.
            self.kernel.dot_centered_block(
                &self.s,
                &self.stale_mean,
                block.data(),
                self.d,
                &mut self.dots,
            );
            self.eps_buf.clear();
            let mut net = 0.0f32;
            for i in 0..rows {
                // ε = +1 iff <s, g − m> < 0, ties to −1 (Algorithm 5).
                let eps = if self.dots[i] < 0.0 { 1.0f32 } else { -1.0 };
                self.eps_buf.push(eps);
                net += eps;
                self.place(range.start + i, eps);
            }
            tensor::zero(&mut self.blk_signed);
            tensor::zero(&mut self.blk_sum);
            self.kernel.accum_signed_sum(
                &self.eps_buf,
                block.data(),
                self.d,
                &mut self.blk_signed,
                &mut self.blk_sum,
            );
            // s += Σ ε_i (g_i − m) and m_{k+1} += Σ g_i / n.
            self.kernel.fold_signed_block(
                &self.blk_signed,
                net,
                &self.stale_mean,
                &mut self.s,
            );
            self.kernel.axpy(inv_n, &self.blk_sum, &mut self.fresh_mean);
        } else {
            // Exact sequential path for stateful balancers (Alg. 6 walk):
            // dispatch hoisted to once per block, centering scratch reused.
            for (i, row) in block.iter_rows().enumerate() {
                tensor::sub_into(row, &self.stale_mean, &mut self.scratch_c);
                let eps = self.balancer.sign(&self.s, &self.scratch_c);
                tensor::axpy(eps, &self.scratch_c, &mut self.s);
                tensor::axpy(inv_n, row, &mut self.fresh_mean);
                self.place(range.start + i, eps);
            }
        }

        self.observed += rows;
        // Balance-bound diagnostic: sample ~every 16 observations (and at
        // the epoch boundary), once per block.
        if self.observed % 16 < rows || self.observed == self.n {
            let inf = tensor::norm_inf(&self.s);
            if inf > self.epoch_balance_inf {
                self.epoch_balance_inf = inf;
            }
        }
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "GraB epoch_end before observing all {} units", self.n
        );
        assert_eq!(self.l, self.r, "two-ended construction must meet");
        std::mem::swap(&mut self.current, &mut self.next);
        std::mem::swap(&mut self.stale_mean, &mut self.fresh_mean);
        tensor::zero(&mut self.fresh_mean);
        tensor::zero(&mut self.s);
        self.balancer.reset();
        self.l = 0;
        self.r = self.n;
        self.observed = 0;
        self.plus_signs = 0;
        self.epoch_balance_inf = 0.0;
    }

    fn state_bytes(&self) -> usize {
        // Algorithm state, matching the paper's Table 1 accounting and
        // the module doc: s, m_k, m_{k+1} (3 d-vectors) + 2
        // permutations. Per-block scratch (the active path's block
        // accumulators / centering vector, O(d), recomputed every
        // block) is transient and excluded.
        3 * self.d * std::mem::size_of::<f32>()
            + 2 * self.n * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // Epoch-boundary state: the order to follow next plus the stale
        // mean it was balanced against (s, the fresh accumulator, and
        // the fill pointers are all reset by `epoch_end`). A stochastic
        // balancer additionally carries its RNG stream position.
        let mut out = Vec::new();
        crate::util::ser::put_u64(&mut out, self.n as u64);
        crate::util::ser::put_u64(&mut out, self.d as u64);
        crate::util::ser::put_usize_slice(&mut out, &self.current);
        crate::util::ser::put_f32_slice(&mut out, &self.stale_mean);
        match self.balancer.save_rng() {
            Some(s) => {
                crate::util::ser::put_u32(&mut out, 1);
                for w in s {
                    crate::util::ser::put_u64(&mut out, w);
                }
            }
            None => crate::util::ser::put_u32(&mut out, 0),
        }
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let n = r.u64()? as usize;
            let d = r.u64()? as usize;
            let current = r.usize_slice(self.n)?;
            let stale = r.f32_slice(self.d)?;
            let rng = match r.u32()? {
                0 => None,
                _ => Some([r.u64()?, r.u64()?, r.u64()?, r.u64()?]),
            };
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((
                n, d, current, stale, rng,
            ))
        })();
        let (n, d, current, stale, rng) =
            parse.map_err(|e| format!("grab state: {e}"))?;
        if n != self.n || d != self.d {
            return Err(format!(
                "grab state shape mismatch: snapshot {n}x{d}, \
                 policy {}x{}",
                self.n, self.d
            ));
        }
        if stale.len() != self.d {
            return Err(format!(
                "grab stale mean has {} entries, expected {}",
                stale.len(),
                self.d
            ));
        }
        if !self.restore_order(&current) {
            return Err(format!(
                "grab state order is not a permutation of 0..{}",
                self.n
            ));
        }
        self.stale_mean.copy_from_slice(&stale);
        if let Some(s) = rng {
            self.balancer.restore_rng(s);
        }
        Ok(())
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        if !crate::ordering::is_permutation_of(order, self.n) {
            return false;
        }
        self.current.clear();
        self.current.extend_from_slice(order);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::DeterministicBalancer;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn grab(n: usize, d: usize) -> GraBOrder {
        GraBOrder::new(n, d, Box::new(DeterministicBalancer))
    }

    #[test]
    fn first_epoch_is_identity() {
        let mut g = grab(5, 2);
        assert_eq!(g.epoch_order(0), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn next_order_is_valid_permutation() {
        prop::forall("grab produces permutations", 24, |rng| {
            let (n, d) = gen::small_dims(rng, 64, 8);
            let mut g = grab(n, d);
            for _epoch in 0..3 {
                let order = g.epoch_order(0).to_vec();
                assert_permutation(&order)?;
                for pos in 0..n {
                    let grad = gen::gauss_vec(rng, d, 1.0);
                    g.observe(pos, &grad);
                }
                g.epoch_end();
            }
            Ok(())
        });
    }

    #[test]
    fn block_observe_covers_epoch_in_chunks() {
        // Streaming an epoch through random-sized contiguous blocks must
        // still produce a valid permutation and meet in the middle.
        prop::forall("grab block streaming", 16, |rng| {
            let n = 8 + rng.gen_range(56) as usize;
            let d = 1 + rng.gen_range(8) as usize;
            let mut g = grab(n, d);
            for _epoch in 0..2 {
                let _ = g.epoch_order(0);
                let flat: Vec<f32> = (0..n * d)
                    .map(|_| rng.gauss() as f32)
                    .collect();
                let mut pos = 0;
                while pos < n {
                    let b = 1 + rng.gen_range(7) as usize;
                    let end = (pos + b).min(n);
                    let blk =
                        GradBlock::new(&flat[pos * d..end * d], d);
                    g.observe_block(pos..end, &blk);
                    pos = end;
                }
                g.epoch_end();
                assert_permutation(g.epoch_order(0))?;
            }
            Ok(())
        });
    }

    #[test]
    fn observe_shim_is_identical_to_explicit_one_row_blocks() {
        // The per-example `observe` shim and an explicit 1-row
        // `observe_block` stream must drive identical state — both are
        // the exact Algorithm 4 (multi-row folds are covered by
        // tensor::block_fold_matches_per_row_updates and the batched
        // herding test below).
        let n = 8;
        let d = 4;
        let mut a = grab(n, d);
        let mut b = grab(n, d);
        let mut rng = Rng::new(11);
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();
        for pos in 0..n {
            let row = &flat[pos * d..(pos + 1) * d];
            a.observe(pos, row);
            b.observe_block(
                pos..pos + 1,
                &GradBlock::new(row, d),
            );
        }
        a.epoch_end();
        b.epoch_end();
        assert_eq!(a.epoch_order(1).to_vec(), b.epoch_order(1).to_vec());
        assert_eq!(a.s, b.s);
        assert_eq!(a.stale_mean, b.stale_mean);
    }

    #[test]
    fn two_ended_construction_matches_algorithm3() {
        // Manually check placement: +1 signs go front (original order),
        // -1 go back (reversed).
        let mut g = grab(4, 1);
        // stale mean is 0 in epoch 1, s starts at 0.
        // grad +1: c=+1, <s,c>=0 -> eps=-1 (tie to -1), s=-1, unit 0 -> back
        // grad +1: c=+1, <s,c>=-1<0 -> eps=+1, s=0, unit 1 -> front
        // grad -1: c=-1, <s,c>=0 -> eps=-1, s=+1, unit 2 -> back
        // grad -1: c=-1, <s,c>=-1<0 -> eps=+1, s=0, unit 3 -> front
        g.observe(0, &[1.0]);
        g.observe(1, &[1.0]);
        g.observe(2, &[-1.0]);
        g.observe(3, &[-1.0]);
        assert_eq!(g.next_order_built(), &[1, 3, 2, 0]);
        g.epoch_end();
        assert_eq!(g.epoch_order(1), &[1, 3, 2, 0]);
    }

    #[test]
    fn stale_mean_rolls_over() {
        let n = 4;
        let mut g = grab(n, 2);
        let grads = [
            [1.0f32, 0.0],
            [0.0, 1.0],
            [1.0, 1.0],
            [2.0, 0.0],
        ];
        for (pos, gr) in grads.iter().enumerate() {
            g.observe(pos, gr);
        }
        g.epoch_end();
        // stale mean for epoch 2 = mean of epoch-1 grads = (1.0, 0.5)
        assert!((g.stale_mean[0] - 1.0).abs() < 1e-6);
        assert!((g.stale_mean[1] - 0.5).abs() < 1e-6);
        // fresh accumulator reset
        assert_eq!(g.fresh_mean, vec![0.0, 0.0]);
        assert_eq!(g.s, vec![0.0, 0.0]);
    }

    #[test]
    fn whole_epoch_block_rolls_mean_identically() {
        // The block-level fresh-mean fold must produce the same stale
        // mean as per-example accumulation.
        let n = 4;
        let flat = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0, 2.0, 0.0];
        let mut g = grab(n, 2);
        g.observe_block(0..4, &GradBlock::new(&flat, 2));
        g.epoch_end();
        assert!((g.stale_mean[0] - 1.0).abs() < 1e-6);
        assert!((g.stale_mean[1] - 0.5).abs() < 1e-6);
        assert_eq!(g.s, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut g = grab(3, 1);
        g.observe(0, &[1.0]);
        g.epoch_end();
    }

    #[test]
    fn repeated_epochs_reduce_herding_bound_on_static_gradients() {
        // With a *fixed* gradient set (convex quadratic intuition), GraB's
        // reordering over epochs must drive the herding objective down,
        // approaching the offline herding quality (paper Challenge II).
        let mut rng = Rng::new(0);
        let n = 512;
        let d = 16;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut g = grab(n, d);
        let identity: Vec<usize> = (0..n).collect();
        let (start_inf, _) = herding_bound(&vs, &identity);
        let mut last_inf = f32::INFINITY;
        for _epoch in 0..10 {
            let order = g.epoch_order(0).to_vec();
            for (pos, &unit) in order.iter().enumerate() {
                g.observe(pos, &vs[unit]);
            }
            g.epoch_end();
            let order = g.epoch_order(0).to_vec();
            (last_inf, _) = herding_bound(&vs, &order);
        }
        assert!(
            last_inf < start_inf / 3.0,
            "start {start_inf} -> after 10 GraB epochs {last_inf}"
        );
    }

    #[test]
    fn batched_blocks_still_beat_random_on_static_gradients() {
        // GraB-sampler-style batched balancing (B=16 here) concedes an
        // O(sqrt(B)) within-block slack but must still land far below
        // random reshuffling's herding bound.
        let mut rng = Rng::new(4);
        let n = 512;
        let d = 16;
        let b = 16;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let p = rng.permutation(n);
            rand_acc += herding_bound(&vs, &p).0;
        }
        let rand_inf = rand_acc / 5.0;
        let mut g = grab(n, d);
        let mut flat = Vec::new();
        for epoch in 0..8 {
            crate::ordering::stream_static_epoch(
                &mut g, epoch, &vs, &mut flat, b,
            );
        }
        let (grab_inf, _) = herding_bound(&vs, g.epoch_order(0));
        assert!(
            grab_inf < rand_inf,
            "batched grab {grab_inf} vs random {rand_inf}"
        );
    }

    #[test]
    fn grab_beats_random_on_static_gradients() {
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        // Average random herding bound.
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let p = rng.permutation(n);
            rand_acc += herding_bound(&vs, &p).0;
        }
        let rand_inf = rand_acc / 5.0;
        let mut g = grab(n, d);
        for _ in 0..8 {
            let order = g.epoch_order(0).to_vec();
            for (pos, &unit) in order.iter().enumerate() {
                g.observe(pos, &vs[unit]);
            }
            g.epoch_end();
        }
        let (grab_inf, _) = herding_bound(&vs, g.epoch_order(0));
        assert!(
            grab_inf < rand_inf,
            "grab {grab_inf} vs random {rand_inf}"
        );
    }

    #[test]
    fn state_bytes_is_o_of_d_plus_n() {
        // 3 algorithm d-vectors + 2 permutations, regardless of which
        // observe path's transient scratch is allocated.
        let g = grab(1000, 50);
        assert_eq!(g.state_bytes(), 3 * 50 * 4 + 2 * 1000 * 8);
        let w = GraBOrder::new(
            1000,
            50,
            Box::new(crate::balance::WalkBalancer::new(10.0, 0)),
        );
        assert_eq!(w.state_bytes(), g.state_bytes());
    }
}
