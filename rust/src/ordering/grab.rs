//! GraB — Algorithm 4: SGD with Online Gradient Balancing.
//!
//! Per epoch k, for each visited unit (position t, dataset index
//! σ_k(t), fresh gradient g):
//!
//! 1. center with the *stale* mean of epoch k−1:  c = g − m_k      (line 6)
//! 2. accumulate the fresh mean: m_{k+1} += g / n                   (line 6)
//! 3. sign from the balancer:    ε = Balancing(s, c)                (line 7)
//! 4. two-ended order construction (lines 8–12):
//!      ε = +1 → σ_{k+1}(l) = σ_k(t), l += 1   (front, original order)
//!      ε = −1 → σ_{k+1}(r) = σ_k(t), r −= 1   (back → reversed order)
//!    and s += ε·c.
//!
//! This implements Algorithm 3's reorder *online*, so total ordering state
//! is s, m_k, m_{k+1} (3 d-vectors) plus two permutations — O(d + n), vs
//! Greedy Ordering's O(nd). `observe` is the request-path hot spot measured
//! in benches/balance_hot.rs; the centered dot and the signed update are
//! fused single-pass loops over `g`/`m`/`s` (see tensor::dot_centered).

use crate::balance::Balancer;
use crate::ordering::OrderPolicy;
use crate::tensor;

pub struct GraBOrder {
    n: usize,
    d: usize,
    balancer: Box<dyn Balancer + Send>,
    /// σ_k — the order being followed this epoch.
    current: Vec<usize>,
    /// σ_{k+1} under construction.
    next: Vec<usize>,
    /// Front / back fill pointers (paper's l and r).
    l: usize,
    r: usize,
    /// Signed running sum s.
    s: Vec<f32>,
    /// Stale mean m_k (centering) and fresh accumulator m_{k+1}.
    stale_mean: Vec<f32>,
    fresh_mean: Vec<f32>,
    /// Diagnostics: max ‖s‖∞ observed this epoch (the balancing bound A).
    pub epoch_balance_inf: f32,
    /// Count of +1 signs this epoch (for tests/metrics).
    pub plus_signs: usize,
    observed: usize,
}

impl GraBOrder {
    pub fn new(n: usize, d: usize, balancer: Box<dyn Balancer + Send>)
        -> GraBOrder {
        GraBOrder {
            n,
            d,
            balancer,
            current: (0..n).collect(), // σ_1 = identity (any init works)
            next: vec![0; n],
            l: 0,
            r: n,
            s: vec![0.0; d],
            stale_mean: vec![0.0; d], // m_1 = 0 (paper line 1)
            fresh_mean: vec![0.0; d],
            epoch_balance_inf: 0.0,
            plus_signs: 0,
            observed: 0,
        }
    }

    /// The balancer's name (for logs).
    pub fn balancer_name(&self) -> &'static str {
        self.balancer.name()
    }

    /// Peek at the order under construction (tests only).
    #[cfg(test)]
    fn next_order_built(&self) -> &[usize] {
        &self.next
    }
}

impl OrderPolicy for GraBOrder {
    fn name(&self) -> &'static str {
        "grab"
    }

    fn epoch_order(&mut self, _epoch: usize) -> Vec<usize> {
        self.current.clone()
    }

    fn observe(&mut self, pos: usize, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.d);
        debug_assert!(pos < self.n, "pos {pos} out of range");
        // ε = Balancing(s, g − m_k). The deterministic balancer only needs
        // sign⟨s, c⟩, computed fused without materializing c.
        let eps = self
            .balancer
            .sign_centered(&self.s, grad, &self.stale_mean);
        // s += ε (g − m_k) and m_{k+1} += g/n in ONE pass over grad
        // (§Perf: saves a full re-read of grad per observe).
        tensor::grab_update(
            eps,
            1.0 / self.n as f32,
            grad,
            &self.stale_mean,
            &mut self.s,
            &mut self.fresh_mean,
        );
        // Two-ended placement.
        let unit = self.current[pos];
        if eps > 0.0 {
            self.next[self.l] = unit;
            self.l += 1;
            self.plus_signs += 1;
        } else {
            self.r -= 1;
            self.next[self.r] = unit;
        }
        self.observed += 1;
        // Balance-bound diagnostic: a full ℓ∞ scan per step costs a whole
        // extra pass over s; sampling every 16th step (plus the final
        // step) keeps the metric useful at ~6% of its former cost (§Perf).
        if self.observed % 16 == 0 || self.observed == self.n {
            let inf = tensor::norm_inf(&self.s);
            if inf > self.epoch_balance_inf {
                self.epoch_balance_inf = inf;
            }
        }
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "GraB epoch_end before observing all {} units", self.n
        );
        assert_eq!(self.l, self.r, "two-ended construction must meet");
        std::mem::swap(&mut self.current, &mut self.next);
        std::mem::swap(&mut self.stale_mean, &mut self.fresh_mean);
        tensor::zero(&mut self.fresh_mean);
        tensor::zero(&mut self.s);
        self.balancer.reset();
        self.l = 0;
        self.r = self.n;
        self.observed = 0;
        self.plus_signs = 0;
        self.epoch_balance_inf = 0.0;
    }

    fn state_bytes(&self) -> usize {
        // 3 d-vectors (s, m_k, m_{k+1}) + 2 permutations.
        3 * self.d * std::mem::size_of::<f32>()
            + 2 * self.n * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        true
    }
}

/// Extension trait so the deterministic balancer can use the fused
/// centered-dot path while other balancers fall back to materializing c.
trait BalancerExt {
    fn sign_centered(&mut self, s: &[f32], g: &[f32], m: &[f32]) -> f32;
}

impl BalancerExt for Box<dyn Balancer + Send> {
    fn sign_centered(&mut self, s: &[f32], g: &[f32], m: &[f32]) -> f32 {
        if self.name() == "alg5-deterministic" {
            // Fused: sign of <s, g - m> without a temporary.
            if tensor::dot_centered(s, g, m) < 0.0 {
                1.0
            } else {
                -1.0
            }
        } else {
            let mut c = vec![0.0f32; g.len()];
            tensor::sub_into(g, m, &mut c);
            self.sign(s, &c)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::DeterministicBalancer;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn grab(n: usize, d: usize) -> GraBOrder {
        GraBOrder::new(n, d, Box::new(DeterministicBalancer))
    }

    #[test]
    fn first_epoch_is_identity() {
        let mut g = grab(5, 2);
        assert_eq!(g.epoch_order(0), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn next_order_is_valid_permutation() {
        prop::forall("grab produces permutations", 24, |rng| {
            let (n, d) = gen::small_dims(rng, 64, 8);
            let mut g = grab(n, d);
            for _epoch in 0..3 {
                let order = g.epoch_order(0);
                assert_permutation(&order)?;
                for pos in 0..n {
                    let grad = gen::gauss_vec(rng, d, 1.0);
                    g.observe(pos, &grad);
                }
                g.epoch_end();
            }
            Ok(())
        });
    }

    #[test]
    fn two_ended_construction_matches_algorithm3() {
        // Manually check placement: +1 signs go front (original order),
        // -1 go back (reversed).
        let mut g = grab(4, 1);
        // stale mean is 0 in epoch 1, s starts at 0.
        // grad +1: c=+1, <s,c>=0 -> eps=-1 (tie to -1), s=-1, unit 0 -> back
        // grad +1: c=+1, <s,c>=-1<0 -> eps=+1, s=0, unit 1 -> front
        // grad -1: c=-1, <s,c>=0 -> eps=-1, s=+1, unit 2 -> back
        // grad -1: c=-1, <s,c>=-1<0 -> eps=+1, s=0, unit 3 -> front
        g.observe(0, &[1.0]);
        g.observe(1, &[1.0]);
        g.observe(2, &[-1.0]);
        g.observe(3, &[-1.0]);
        assert_eq!(g.next_order_built(), &[1, 3, 2, 0]);
        g.epoch_end();
        assert_eq!(g.epoch_order(1), vec![1, 3, 2, 0]);
    }

    #[test]
    fn stale_mean_rolls_over() {
        let n = 4;
        let mut g = grab(n, 2);
        let grads = [
            [1.0f32, 0.0],
            [0.0, 1.0],
            [1.0, 1.0],
            [2.0, 0.0],
        ];
        for (pos, gr) in grads.iter().enumerate() {
            g.observe(pos, gr);
        }
        g.epoch_end();
        // stale mean for epoch 2 = mean of epoch-1 grads = (1.0, 0.5)
        assert!((g.stale_mean[0] - 1.0).abs() < 1e-6);
        assert!((g.stale_mean[1] - 0.5).abs() < 1e-6);
        // fresh accumulator reset
        assert_eq!(g.fresh_mean, vec![0.0, 0.0]);
        assert_eq!(g.s, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut g = grab(3, 1);
        g.observe(0, &[1.0]);
        g.epoch_end();
    }

    #[test]
    fn repeated_epochs_reduce_herding_bound_on_static_gradients() {
        // With a *fixed* gradient set (convex quadratic intuition), GraB's
        // reordering over epochs must drive the herding objective down,
        // approaching the offline herding quality (paper Challenge II).
        let mut rng = Rng::new(0);
        let n = 512;
        let d = 16;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut g = grab(n, d);
        let identity: Vec<usize> = (0..n).collect();
        let (start_inf, _) = herding_bound(&vs, &identity);
        let mut last_inf = f32::INFINITY;
        for _epoch in 0..10 {
            let order = g.epoch_order(0);
            for (pos, &unit) in order.iter().enumerate() {
                g.observe(pos, &vs[unit]);
            }
            g.epoch_end();
            let order = g.epoch_order(0);
            (last_inf, _) = herding_bound(&vs, &order);
        }
        assert!(
            last_inf < start_inf / 3.0,
            "start {start_inf} -> after 10 GraB epochs {last_inf}"
        );
    }

    #[test]
    fn grab_beats_random_on_static_gradients() {
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        // Average random herding bound.
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let p = rng.permutation(n);
            rand_acc += herding_bound(&vs, &p).0;
        }
        let rand_inf = rand_acc / 5.0;
        let mut g = grab(n, d);
        for _ in 0..8 {
            let order = g.epoch_order(0);
            for (pos, &unit) in order.iter().enumerate() {
                g.observe(pos, &vs[unit]);
            }
            g.epoch_end();
        }
        let order = g.epoch_order(0);
        let (grab_inf, _) = herding_bound(&vs, &order);
        assert!(
            grab_inf < rand_inf,
            "grab {grab_inf} vs random {rand_inf}"
        );
    }

    #[test]
    fn state_bytes_is_o_of_d_plus_n() {
        let g = grab(1000, 50);
        let bytes = g.state_bytes();
        assert_eq!(bytes, 3 * 50 * 4 + 2 * 1000 * 8);
    }
}
