//! Greedy Ordering (Lu et al. 2021a) as an online policy: store every
//! stale per-example gradient during the epoch (the O(nd) memory cost the
//! paper measures in Table 1 / the Fig. 2d OOM), then run Algorithm 1's
//! greedy herding at the epoch boundary (O(n²) selection work) to produce
//! the next epoch's order.

use std::ops::Range;

use crate::herding::greedy::greedy_order;
use crate::ordering::{GradBlock, OrderPolicy};

/// Greedy Ordering policy — stores all stale gradients, reorders
/// greedily at the epoch boundary (the paper's O(nd) baseline).
pub struct GreedyOrder {
    n: usize,
    d: usize,
    /// σ_k being followed.
    current: Vec<usize>,
    /// Stale gradients, indexed by *dataset unit* (not visit position).
    grads: Vec<Vec<f32>>,
    observed: usize,
}

impl GreedyOrder {
    /// A greedy-ordering policy over `n` units of dimension `d`.
    pub fn new(n: usize, d: usize) -> GreedyOrder {
        GreedyOrder {
            n,
            d,
            current: (0..n).collect(),
            grads: vec![Vec::new(); n],
            observed: 0,
        }
    }
}

impl OrderPolicy for GreedyOrder {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.current
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(block.dim(), self.d);
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        for (i, row) in block.iter_rows().enumerate() {
            let unit = self.current[range.start + i];
            // The O(nd) storage; per-unit buffers are reused across
            // epochs once grown.
            let slot = &mut self.grads[unit];
            slot.clear();
            slot.extend_from_slice(row);
        }
        self.observed += block.rows();
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "GreedyOrder epoch_end before observing all units"
        );
        // Algorithm 1 over the stale gradients in unit index space: the
        // returned permutation indexes grads[] directly, i.e. dataset units.
        self.current = greedy_order(&self.grads);
        self.observed = 0;
    }

    fn state_bytes(&self) -> usize {
        // n stale gradients of d f32s (+ the permutation).
        self.grads
            .iter()
            .map(|g| g.capacity() * std::mem::size_of::<f32>())
            .sum::<usize>()
            + self.current.len() * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // Epoch-boundary state is just σ_{k+1}: the stale gradient
        // store is rewritten in full by the next epoch's observations
        // before `epoch_end` reads it again, so `current` alone resumes
        // the run bit-identically (the contract-8 carve-out this
        // closes — resume used to silently restart greedy ordering
        // from the identity permutation).
        let mut out = Vec::new();
        crate::util::ser::put_u64(&mut out, self.n as u64);
        crate::util::ser::put_u64(&mut out, self.d as u64);
        crate::util::ser::put_usize_slice(&mut out, &self.current);
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let n = r.u64()? as usize;
            let d = r.u64()? as usize;
            let current = r.usize_slice(self.n)?;
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((n, d, current))
        })();
        let (n, d, current) =
            parse.map_err(|e| format!("greedy state: {e}"))?;
        if n != self.n || d != self.d {
            return Err(format!(
                "greedy state shape mismatch: snapshot {n}x{d}, \
                 policy {}x{}",
                self.n, self.d
            ));
        }
        if !self.restore_order(&current) {
            return Err(format!(
                "greedy state order is not a permutation of 0..{}",
                self.n
            ));
        }
        Ok(())
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        if !crate::ordering::is_permutation_of(order, self.n) {
            return false;
        }
        self.current.clear();
        self.current.extend_from_slice(order);
        self.observed = 0;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    #[test]
    fn produces_permutations() {
        prop::forall("greedy order permutations", 16, |rng| {
            let (n, d) = gen::small_dims(rng, 40, 6);
            let mut p = GreedyOrder::new(n, d);
            for _ in 0..2 {
                let order = p.epoch_order(0).to_vec();
                assert_permutation(&order)?;
                for pos in 0..n {
                    let g = gen::gauss_vec(rng, d, 1.0);
                    p.observe(pos, &g);
                }
                p.epoch_end();
            }
            Ok(())
        });
    }

    #[test]
    fn memory_is_o_nd() {
        let mut p = GreedyOrder::new(100, 32);
        let _ = p.epoch_order(0);
        let flat = vec![1.0f32; 100 * 32];
        p.observe_block(0..100, &GradBlock::new(&flat, 32));
        let bytes = p.state_bytes();
        assert!(bytes >= 100 * 32 * 4, "bytes={bytes}");
    }

    #[test]
    fn greedy_resume_matches_uninterrupted() {
        // Contract 8 for the greedy policy: save_state at an epoch
        // boundary, restore into a fresh policy, and every later epoch
        // order is bit-equal to the uninterrupted run. Before the fix
        // GreedyOrder had no save_state, so a resume silently restarted
        // from the identity permutation.
        let mut rng = Rng::new(7);
        let n = 64;
        let d = 6;
        let vs = gen::vec_set(&mut rng, n, d);
        let feed = |p: &mut GreedyOrder| {
            let order = p.epoch_order(0).to_vec();
            for (pos, &unit) in order.iter().enumerate() {
                p.observe(pos, &vs[unit]);
            }
            p.epoch_end();
        };

        let mut full = GreedyOrder::new(n, d);
        feed(&mut full);
        feed(&mut full);
        let state = full.save_state().expect("greedy must snapshot");
        feed(&mut full);
        feed(&mut full);

        let mut resumed = GreedyOrder::new(n, d);
        resumed.restore_state(&state).unwrap();
        // Replay the full run's epochs 0..2 on the reference copy only
        // happened above; the resumed policy continues from epoch 2.
        let mut reference = GreedyOrder::new(n, d);
        feed(&mut reference);
        feed(&mut reference);
        assert_eq!(
            resumed.epoch_order(0),
            reference.epoch_order(0),
            "restore must hand back the snapshotted permutation"
        );
        feed(&mut resumed);
        feed(&mut resumed);
        assert_eq!(
            resumed.epoch_order(0),
            full.epoch_order(0),
            "resumed greedy run diverged from the uninterrupted one"
        );

        // Negative paths: wrong shape, corrupt permutation, junk bytes.
        let mut other = GreedyOrder::new(n + 1, d);
        assert!(other.restore_state(&state).is_err());
        assert!(GreedyOrder::new(n, d).restore_state(&[1, 2, 3]).is_err());
        assert!(!GreedyOrder::new(n, d).restore_order(&vec![0usize; n]));
    }

    #[test]
    fn greedy_orders_static_gradients_well() {
        let mut rng = Rng::new(2);
        let n = 256;
        let d = 8;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut p = GreedyOrder::new(n, d);
        // One observation epoch, then the next order is greedy-herded.
        let order = p.epoch_order(0).to_vec();
        for (pos, &unit) in order.iter().enumerate() {
            p.observe(pos, &vs[unit]);
        }
        p.epoch_end();
        let herded = p.epoch_order(1).to_vec();
        let (h_inf, _) = herding_bound(&vs, &herded);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            rand_acc += herding_bound(&vs, &rng.permutation(n)).0;
        }
        assert!(
            h_inf < rand_acc / 5.0,
            "greedy {h_inf} vs random {}", rand_acc / 5.0
        );
    }
}
