//! PairBalance — CD-GraB's kernel (Cooper et al. 2023, "Coordinating
//! Distributed Example Orders for Provably Accelerated Training",
//! Algorithm 1 `PairBalance` / Algorithm 5 single-worker ablation).
//!
//! GraB centers every gradient with the *stale* mean of the previous
//! epoch before balancing, which (a) needs an extra d-vector of state,
//! (b) injects a staleness error term into the herding bound, and (c)
//! serializes the data path on one running mean. CD-GraB's observation:
//! balance the *difference of consecutive pairs* instead,
//!
//! ```text
//!   d_t = g_{2t} − g_{2t+1},   ε_t = Balancing(s, d_t),
//!   example 2t   gets sign  ε_t,
//!   example 2t+1 gets sign −ε_t,
//! ```
//!
//! so any common shift — in particular the (unknown, fresh) mean —
//! cancels inside `d_t`. No stale mean, no mean state, and the balancing
//! stream only depends on local pairs, which is what makes the sharded
//! coordinator ([`crate::ordering::ShardedOrder`]) possible: each worker
//! pair-balances its own stream and the server only merges orders
//! (CD-GraB Algorithm 2).
//!
//! Signs feed the same two-ended reorder as GraB (Algorithm 3: +1 front
//! in visit order, −1 back reversed). A trailing unpaired example (odd
//! n) is balanced against an implicit zero partner at the epoch
//! boundary.
//!
//! The observe path is pair-fused: decision and update run over the raw
//! rows with `tensor::dot_diff` / `tensor::axpy_diff`, never
//! materializing `d_t` — roughly 2.5 flops per element per example
//! versus GraB's ~8 (see benches/ordering_overhead.rs).

use std::ops::Range;

use crate::ordering::{GradBlock, OrderPolicy};
use crate::tensor::{self, Kernel};

/// CD-GraB's PairBalance policy (Algorithm 1) — balances consecutive
/// pair differences; see the module docs.
pub struct PairBalance {
    n: usize,
    d: usize,
    /// σ_k — the order being followed this epoch.
    current: Vec<usize>,
    /// σ_{k+1} under construction.
    next: Vec<usize>,
    /// Front / back fill pointers.
    l: usize,
    r: usize,
    /// Signed running sum over pair differences.
    s: Vec<f32>,
    /// First element of a pair straddling a block boundary.
    pending: Vec<f32>,
    pending_pos: usize,
    have_pending: bool,
    /// Diagnostics: max ‖s‖∞ this epoch.
    pub epoch_balance_inf: f32,
    /// Count of +1 signs this epoch (for tests/metrics).
    pub plus_signs: usize,
    /// Sign assigned to each visit position (`+1`/`-1`), fully
    /// overwritten every epoch (each position is placed exactly once).
    /// Read back by the streaming reservoir's carry-out.
    signs: Vec<i8>,
    observed: usize,
    /// Kernel tier for the pair decision/update kernels. The balancing
    /// chain is sequential (each pair reads the `s` the previous pair
    /// wrote), so `SimdPar` behaves as `Simd` here — only the per-pair
    /// kernels vectorize. Bit-identical across tiers (contract 7).
    kernel: Kernel,
}

impl PairBalance {
    /// A pair-balancing policy over `n` units of dimension `d`,
    /// dispatching through the process-default kernel tier
    /// ([`tensor::default_kernel`]).
    pub fn new(n: usize, d: usize) -> PairBalance {
        Self::with_kernel(n, d, tensor::default_kernel())
    }

    /// [`PairBalance::new`] with an explicit kernel tier — used by the
    /// contract-7 equivalence tests and the bench runner (tests must
    /// not touch the process-global default).
    pub fn with_kernel(n: usize, d: usize, kernel: Kernel) -> PairBalance {
        PairBalance {
            n,
            d,
            current: (0..n).collect(),
            next: vec![0; n],
            l: 0,
            r: n,
            s: vec![0.0; d],
            pending: vec![0.0; d],
            pending_pos: 0,
            have_pending: false,
            epoch_balance_inf: 0.0,
            plus_signs: 0,
            signs: vec![0; n],
            observed: 0,
            kernel,
        }
    }

    /// The ±1 sign assigned to each *visit position* of the most
    /// recently completed epoch (entry `p` is the sign of the example
    /// visited at position `p`). Every position is placed exactly once
    /// per epoch, so the buffer is fully overwritten each epoch; before
    /// the first `epoch_end` the entries are 0. The streaming reservoir
    /// ([`crate::ordering::StreamOrder`]) uses these to carry an evicted
    /// unit's signed contribution out of its survivor accumulator.
    pub fn last_epoch_signs(&self) -> &[i8] {
        &self.signs
    }

    /// The `state_bytes` a freshly constructed balancer over `n` units
    /// of dimension `d` would report, computed without allocating one —
    /// lets the sharded coordinator seed per-shard memory accounting
    /// (before the first worker report) for free.
    pub fn initial_state_bytes(n: usize, d: usize) -> usize {
        2 * d * std::mem::size_of::<f32>()
            + 2 * n * std::mem::size_of::<usize>()
    }

    /// The kernel tier this balancer dispatches through — lets the
    /// streaming reservoir rebuild a resized balancer on the *same*
    /// tier (determinism contract 7 must survive a re-plan).
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Number of ordering units.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the policy orders zero units.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Two-ended placement of one example.
    #[inline]
    fn place(&mut self, pos: usize, eps: f32) {
        let unit = self.current[pos];
        if eps > 0.0 {
            self.next[self.l] = unit;
            self.l += 1;
            self.plus_signs += 1;
            self.signs[pos] = 1;
        } else {
            self.r -= 1;
            self.next[self.r] = unit;
            self.signs[pos] = -1;
        }
    }

    /// Balance one complete pair (a at `pos_a`, b at `pos_a + 1`).
    fn pair_step(&mut self, a: &[f32], b: &[f32], pos_a: usize) {
        // ε = +1 iff <s, a − b> < 0, ties to −1 (Algorithm 5's rule on
        // the pair difference).
        let eps = if self.kernel.dot_diff(&self.s, a, b) < 0.0 {
            1.0f32
        } else {
            -1.0
        };
        self.kernel.axpy_diff(eps, a, b, &mut self.s);
        self.place(pos_a, eps);
        self.place(pos_a + 1, -eps);
    }

    /// Balance the trailing unpaired example against a zero partner.
    fn lone_step(&mut self) {
        debug_assert!(self.have_pending);
        let eps = if self.kernel.dot(&self.s, &self.pending) < 0.0 {
            1.0f32
        } else {
            -1.0
        };
        // s += eps * (g − 0).
        let pending = std::mem::take(&mut self.pending);
        self.kernel.axpy(eps, &pending, &mut self.s);
        self.pending = pending;
        self.place(self.pending_pos, eps);
        self.have_pending = false;
    }
}

impl OrderPolicy for PairBalance {
    fn name(&self) -> &'static str {
        "pair"
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.current
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        let rows = block.rows();
        if rows == 0 {
            return;
        }
        debug_assert_eq!(block.dim(), self.d);
        debug_assert_eq!(range.len(), rows);
        debug_assert!(range.end <= self.n);
        debug_assert!(
            !self.have_pending || range.start == self.pending_pos + 1,
            "blocks must arrive in contiguous position order"
        );
        let mut i = 0;
        // Complete a pair left hanging by the previous block.
        if self.have_pending {
            let pending = std::mem::take(&mut self.pending);
            self.pair_step(&pending, block.row(0), self.pending_pos);
            self.pending = pending;
            self.have_pending = false;
            i = 1;
        }
        // Whole pairs inside the block: zero-copy, both rows contiguous.
        while i + 2 <= rows {
            self.pair_step(
                block.row(i),
                block.row(i + 1),
                range.start + i,
            );
            i += 2;
        }
        // Stash a trailing odd row for the next block.
        if i < rows {
            self.pending.clear();
            self.pending.extend_from_slice(block.row(i));
            self.pending_pos = range.start + i;
            self.have_pending = true;
        }
        self.observed += rows;
        if self.observed % 16 < rows || self.observed == self.n {
            let inf = tensor::norm_inf(&self.s);
            if inf > self.epoch_balance_inf {
                self.epoch_balance_inf = inf;
            }
        }
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "PairBalance epoch_end before observing all {} units", self.n
        );
        if self.have_pending {
            // Odd n: the last example pairs with an implicit zero.
            self.lone_step();
        }
        assert_eq!(self.l, self.r, "two-ended construction must meet");
        std::mem::swap(&mut self.current, &mut self.next);
        tensor::zero(&mut self.s);
        self.l = 0;
        self.r = self.n;
        self.observed = 0;
        self.plus_signs = 0;
        self.epoch_balance_inf = 0.0;
    }

    fn state_bytes(&self) -> usize {
        // One running sum + one pending row + two permutations: O(d + n),
        // one d-vector *less* than GraB (no stale/fresh means).
        (self.s.len() + self.pending.capacity())
            * std::mem::size_of::<f32>()
            + 2 * self.n * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // Epoch-boundary state is just the order to follow next: the
        // running sum, fill pointers, and pending row are all reset by
        // `epoch_end`, so `current` alone resumes the stream exactly.
        let mut out = Vec::new();
        crate::util::ser::put_u64(&mut out, self.n as u64);
        crate::util::ser::put_u64(&mut out, self.d as u64);
        crate::util::ser::put_usize_slice(&mut out, &self.current);
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let n = r.u64()? as usize;
            let d = r.u64()? as usize;
            let current = r.usize_slice(self.n)?;
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((n, d, current))
        })();
        let (n, d, current) =
            parse.map_err(|e| format!("pair state: {e}"))?;
        if n != self.n || d != self.d {
            return Err(format!(
                "pair state shape mismatch: snapshot {n}x{d}, \
                 policy {}x{}",
                self.n, self.d
            ));
        }
        if !self.restore_order(&current) {
            return Err(format!(
                "pair state order is not a permutation of 0..{}",
                self.n
            ));
        }
        Ok(())
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        if !crate::ordering::is_permutation_of(order, self.n) {
            return false;
        }
        self.current.clear();
        self.current.extend_from_slice(order);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn feed_epoch(p: &mut PairBalance, vs: &[Vec<f32>], block: usize) {
        let mut flat = Vec::new();
        crate::ordering::stream_static_epoch(p, 0, vs, &mut flat, block);
    }

    #[test]
    fn first_epoch_is_identity() {
        let mut p = PairBalance::new(6, 2);
        assert_eq!(p.epoch_order(0), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn produces_permutations_even_and_odd_n() {
        prop::forall("pair balance permutations", 24, |rng| {
            let n = 1 + rng.gen_range(63) as usize;
            let d = 1 + rng.gen_range(8) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let vs = gen::vec_set(rng, n, d);
            let mut p = PairBalance::new(n, d);
            for _ in 0..3 {
                feed_epoch(&mut p, &vs, b);
                assert_permutation(p.epoch_order(0))?;
            }
            Ok(())
        });
    }

    #[test]
    fn pair_signs_are_antisymmetric() {
        // With two identical opposite pairs the construction is exact:
        // pair (a, -a): d = 2a, <0,d>=0 -> eps=-1: unit0 back, unit1
        // front; s = -2a. pair (a, -a): <s,d> = -4|a|^2 < 0 -> eps=+1:
        // unit2 front, unit3 back; s = 0.
        let a = [1.0f32, 2.0];
        let na = [-1.0f32, -2.0];
        let mut p = PairBalance::new(4, 2);
        let flat: Vec<f32> =
            [a, na, a, na].concat();
        p.observe_block(0..4, &GradBlock::new(&flat, 2));
        p.epoch_end();
        assert_eq!(p.epoch_order(1), &[1, 2, 3, 0]);
        assert_eq!(p.s, vec![0.0, 0.0]);
        // Per-position signs of the completed epoch: pair 1 balanced to
        // -1/+1, pair 2 to +1/-1 (the carry-out's view of the epoch).
        assert_eq!(p.last_epoch_signs(), &[-1, 1, 1, -1]);
    }

    #[test]
    fn block_boundaries_do_not_change_the_order() {
        // Pairs straddling block boundaries (odd block sizes) must give
        // exactly the same construction as one whole-epoch block.
        let mut rng = Rng::new(3);
        let n = 40;
        let d = 6;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut whole = PairBalance::new(n, d);
        let mut split = PairBalance::new(n, d);
        for _ in 0..3 {
            feed_epoch(&mut whole, &vs, n);
            feed_epoch(&mut split, &vs, 7);
            assert_eq!(
                whole.epoch_order(0).to_vec(),
                split.epoch_order(0).to_vec()
            );
        }
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut p = PairBalance::new(3, 1);
        p.observe(0, &[1.0]);
        p.epoch_end();
    }

    #[test]
    fn repeated_epochs_reduce_herding_bound_on_static_gradients() {
        // CD-GraB's guarantee mirrors GraB's: on a fixed vector set the
        // pair-balanced reorder drives the herding objective down.
        let mut rng = Rng::new(0);
        let n = 512;
        let d = 16;
        let vs = gen::vec_set(&mut rng, n, d);
        let identity: Vec<usize> = (0..n).collect();
        let (start_inf, _) = herding_bound(&vs, &identity);
        let mut p = PairBalance::new(n, d);
        for _ in 0..10 {
            feed_epoch(&mut p, &vs, 32);
        }
        let (last_inf, _) = herding_bound(&vs, p.epoch_order(0));
        assert!(
            last_inf < start_inf / 3.0,
            "start {start_inf} -> after 10 PairBalance epochs {last_inf}"
        );
    }

    #[test]
    fn pair_balance_beats_random_on_static_gradients() {
        // The acceptance gate shared with GraB: beat random reshuffling's
        // herding bound on the static-gradient test.
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let perm = rng.permutation(n);
            rand_acc += herding_bound(&vs, &perm).0;
        }
        let rand_inf = rand_acc / 5.0;
        let mut p = PairBalance::new(n, d);
        for _ in 0..8 {
            feed_epoch(&mut p, &vs, 64);
        }
        let (pair_inf, _) = herding_bound(&vs, p.epoch_order(0));
        assert!(
            pair_inf < rand_inf,
            "pair balance {pair_inf} vs random {rand_inf}"
        );
    }

    #[test]
    fn state_is_o_of_d_plus_n_without_means() {
        let p = PairBalance::new(1000, 50);
        // 2 d-vectors (s + pending) + 2 permutations — less than GraB's
        // 3 algorithm d-vectors because there is no mean state.
        assert_eq!(p.state_bytes(), 2 * 50 * 4 + 2 * 1000 * 8);
        // The allocation-free estimate must match the real thing.
        assert_eq!(
            PairBalance::initial_state_bytes(1000, 50),
            p.state_bytes()
        );
    }
}
