//! Bounded block queues + scratch-block pools — the hand-off primitive
//! between the async shard coordinator and its worker threads.
//!
//! [`crate::ordering::ShardedOrder`] in async mode gives each shard
//! balancer its own worker thread. The coordinator cannot lend the
//! workers zero-copy [`GradBlock`] views (the executor buffer does not
//! outlive the `observe_block` call), so crossing the thread boundary
//! forces one copy per row — exactly the copy the ROADMAP's "per-shard
//! block batching" item wanted to trade for batched balancing, so the
//! queue performs the gather as part of the enqueue.
//!
//! The queue is a single-producer single-consumer channel of
//! [`ScratchBlock`]s made *bounded by construction*: `depth` owned
//! buffers circulate between a free-list ("pool") channel and the
//! message channel, and a sender that finds the pool empty must wait for
//! the worker to recycle a buffer. Capacity is therefore also the
//! allocation budget — after warm-up the steady state performs no
//! allocation at all, every block reuses a pooled buffer.
//!
//! ```text
//!   coordinator --acquire()-- pool <--recycle()-- worker
//!        |                                          ^
//!        +-- gather rows --> send(ScratchBlock) ----+
//! ```
//!
//! Worker death (panic) drops both worker-side endpoints, so a blocked
//! `acquire`/`send` observes disconnection instead of deadlocking; the
//! coordinator surfaces the panic payload at the epoch boundary (see
//! `ShardedOrder::epoch_end`).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use crate::tensor::GradBlock;

/// An owned, reusable row-major `[rows × d]` gradient buffer — the unit
/// that crosses the coordinator → worker thread boundary. Rows are
/// appended with [`ScratchBlock::push_row`] during the gather and read
/// back as a zero-copy [`GradBlock`] view on the worker side.
pub struct ScratchBlock {
    data: Vec<f32>,
    d: usize,
}

impl ScratchBlock {
    /// An empty scratch buffer for rows of dimension `d`.
    pub fn new(d: usize) -> ScratchBlock {
        assert!(d > 0, "ScratchBlock dimension must be positive");
        ScratchBlock { data: Vec::new(), d }
    }

    /// An empty scratch buffer with room for `rows` rows of dimension
    /// `d` pre-allocated. Weighted shard topologies use this to size
    /// each shard's circulating pool for its expected gather share up
    /// front — the largest-weight shard's buffers reach steady state
    /// without mid-epoch reallocation.
    pub fn with_row_capacity(d: usize, rows: usize) -> ScratchBlock {
        assert!(d > 0, "ScratchBlock dimension must be positive");
        ScratchBlock { data: Vec::with_capacity(rows * d), d }
    }

    /// Append one `d`-dimensional gradient row.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        self.data.extend_from_slice(row);
    }

    /// Number of rows gathered so far.
    pub fn rows(&self) -> usize {
        self.data.len() / self.d
    }

    /// Whether the buffer currently holds no rows.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Per-row dimension `d`.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Drop all rows, keeping the allocation for reuse.
    pub fn clear(&mut self) {
        self.data.clear();
    }

    /// Bytes of backing storage currently allocated (survives `clear`).
    pub fn capacity_bytes(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f32>()
    }

    /// The gathered rows as a zero-copy [`GradBlock`] view.
    pub fn as_grad_block(&self) -> GradBlock<'_> {
        GradBlock::new(&self.data, self.d)
    }
}

/// A message on a shard's block queue.
pub enum ShardMsg {
    /// A gathered block of the shard's next `rows` local gradients.
    Block(ScratchBlock),
    /// Epoch boundary: finalize the shard's next local order and report
    /// it back on the worker's report channel.
    EpochEnd,
    /// Checkpoint resume: overwrite the balancer's next local order with
    /// a restored permutation (only sent between epochs, before any
    /// block of the next epoch).
    Seed(Vec<usize>),
    /// Test-only: make the worker panic, to exercise panic propagation.
    #[cfg(test)]
    Poison,
}

/// Coordinator-side endpoint of one shard's bounded block queue.
pub struct BlockSender {
    msgs: Sender<ShardMsg>,
    pool: Receiver<ScratchBlock>,
    stalls: u64,
    depth: usize,
    /// Largest scratch-block allocation sent so far (tracks the pool's
    /// steady-state memory, since buffers grow to the gather size and
    /// keep their capacity through recycling).
    max_block_bytes: usize,
    /// Total gathered payload bytes handed to the worker (the channel
    /// counterpart of a socket transport's bytes-on-wire).
    bytes_sent: u64,
}

/// Worker-side endpoint of one shard's bounded block queue.
pub struct BlockReceiver {
    msgs: Receiver<ShardMsg>,
    pool: Sender<ScratchBlock>,
}

/// Build one shard's bounded block queue: a message channel plus a pool
/// pre-seeded with `depth` scratch buffers of row dimension `d`. The
/// pool *is* the bound — at most `depth` blocks can be in flight, and
/// an `acquire` past that blocks until the worker recycles one.
pub fn block_queue(d: usize, depth: usize) -> (BlockSender, BlockReceiver) {
    block_queue_sized(d, depth, 0)
}

/// [`block_queue`] with each pooled buffer pre-allocated for `row_hint`
/// rows. Uneven (weighted) shard topologies pass each shard's expected
/// per-block gather share here, so the pool behind the largest-weight
/// shard starts at its steady-state size instead of growing through
/// reallocation during the first epoch. `row_hint = 0` starts empty.
pub fn block_queue_sized(
    d: usize,
    depth: usize,
    row_hint: usize,
) -> (BlockSender, BlockReceiver) {
    assert!(depth > 0, "block queue depth must be positive");
    let (msg_tx, msg_rx) = channel();
    let (pool_tx, pool_rx) = channel();
    for _ in 0..depth {
        pool_tx
            .send(ScratchBlock::with_row_capacity(d, row_hint))
            .expect("seed scratch pool");
    }
    (
        BlockSender {
            msgs: msg_tx,
            pool: pool_rx,
            stalls: 0,
            depth,
            max_block_bytes: 0,
            bytes_sent: 0,
        },
        BlockReceiver { msgs: msg_rx, pool: pool_tx },
    )
}

impl BlockSender {
    /// Take a free scratch buffer, blocking while all `depth` buffers
    /// are in flight (this wait is the queue's backpressure, counted in
    /// [`BlockSender::stalls`]). Returns `None` if the worker is gone —
    /// the caller must surface the worker's fate at the epoch boundary
    /// rather than retrying.
    pub fn acquire(&mut self) -> Option<ScratchBlock> {
        match self.pool.try_recv() {
            Ok(mut b) => {
                b.clear();
                Some(b)
            }
            Err(TryRecvError::Empty) => {
                self.stalls += 1;
                match self.pool.recv() {
                    Ok(mut b) => {
                        b.clear();
                        Some(b)
                    }
                    Err(_) => None,
                }
            }
            Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Enqueue a gathered block. Returns `false` if the worker is gone.
    pub fn send(&mut self, block: ScratchBlock) -> bool {
        self.max_block_bytes =
            self.max_block_bytes.max(block.capacity_bytes());
        self.bytes_sent += (block.rows()
            * block.dim()
            * std::mem::size_of::<f32>()) as u64;
        self.msgs.send(ShardMsg::Block(block)).is_ok()
    }

    /// Signal the epoch boundary. Returns `false` if the worker is gone.
    pub fn end_epoch(&self) -> bool {
        self.msgs.send(ShardMsg::EpochEnd).is_ok()
    }

    /// Re-seed the worker balancer's next local order from a checkpoint
    /// (must only be sent between epochs). Returns `false` if the
    /// worker is gone.
    pub fn seed(&self, order: Vec<usize>) -> bool {
        self.msgs.send(ShardMsg::Seed(order)).is_ok()
    }

    /// Times `acquire` had to wait for the worker (queue-full events).
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Total gathered payload bytes handed to the worker so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Estimated bytes held by this queue's circulating scratch pool:
    /// `depth` buffers at the largest gather size sent so far.
    pub fn pool_bytes(&self) -> usize {
        self.depth * self.max_block_bytes
    }

    /// Test-only: enqueue a message that makes the worker panic.
    #[cfg(test)]
    pub(crate) fn poison(&self) {
        let _ = self.msgs.send(ShardMsg::Poison);
    }
}

impl BlockReceiver {
    /// Next message, blocking; `None` once the coordinator has dropped
    /// its endpoint (shutdown).
    pub fn recv(&self) -> Option<ShardMsg> {
        self.msgs.recv().ok()
    }

    /// Return a consumed scratch buffer to the pool. A send failure
    /// means the coordinator is gone, which only happens at shutdown —
    /// the buffer is simply dropped.
    pub fn recycle(&self, block: ScratchBlock) {
        let _ = self.pool.send(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_block_round_trip() {
        let mut b = ScratchBlock::new(3);
        assert!(b.is_empty());
        b.push_row(&[1.0, 2.0, 3.0]);
        b.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.as_grad_block().row(1), &[4.0, 5.0, 6.0]);
        b.clear();
        assert_eq!(b.rows(), 0);
        assert_eq!(b.dim(), 3);
    }

    #[test]
    fn queue_bounds_in_flight_blocks() {
        let (mut tx, rx) = block_queue(2, 2);
        // Two buffers available, third acquire must wait for a recycle.
        let a = tx.acquire().unwrap();
        let b = tx.acquire().unwrap();
        assert_eq!(tx.stalls(), 0);
        assert!(tx.send(a));
        let h = std::thread::spawn(move || {
            // Hold the queue full long enough that the third acquire
            // below observes the empty pool (a stall) before this
            // recycle runs. Not a strict happens-before — acquire's
            // try_recv/recv split is internal — but 200ms dwarfs any
            // plausible scheduling delay between the spawn and the
            // acquire on the main thread.
            std::thread::sleep(std::time::Duration::from_millis(200));
            match rx.recv() {
                Some(ShardMsg::Block(blk)) => rx.recycle(blk),
                _ => panic!("expected a block message"),
            }
            rx
        });
        let c = tx.acquire().unwrap(); // blocks until the recycle above
        assert!(tx.stalls() >= 1);
        drop((b, c));
        let _rx = h.join().unwrap();
    }

    #[test]
    fn sized_pool_preallocates_row_capacity() {
        let (mut tx, rx) = block_queue_sized(4, 2, 16);
        let b = tx.acquire().unwrap();
        assert!(b.capacity_bytes() >= 16 * 4 * std::mem::size_of::<f32>());
        assert!(b.is_empty());
        drop((b, rx));
        let plain = ScratchBlock::with_row_capacity(3, 0);
        assert_eq!(plain.capacity_bytes(), 0);
        assert_eq!(plain.dim(), 3);
    }

    #[test]
    fn dead_worker_disconnects_instead_of_deadlocking() {
        let (mut tx, rx) = block_queue(4, 1);
        let blk = tx.acquire().unwrap();
        drop(rx); // worker died holding nothing; pool sender dropped
        assert!(!tx.send(blk));
        assert!(tx.acquire().is_none());
        assert!(!tx.end_epoch());
    }
}
