//! ShardedOrder — CD-GraB's distributed coordination (Cooper et al.
//! 2023, Algorithm 2 `CD-GraB`), simulated in-process over W shards.
//!
//! The dataset's `0..n` units are split into W contiguous ranges
//! ("workers"). Each shard runs its own [`PairBalance`] over its local
//! units — pair balancing needs no global mean, so shards are fully
//! independent between epoch boundaries, exactly the property CD-GraB
//! exploits to parallelize GraB across workers. The coordinator does two
//! things, mirroring the paper's server loop:
//!
//! * **merge** — the epoch order interleaves the shard orders
//!   round-robin (lock-step rounds: round t visits each worker's t-th
//!   local example), so consecutive global positions map to different
//!   shards just as in synchronous data-parallel training;
//! * **route** — observed gradient blocks are de-interleaved back to the
//!   owning shard's balancer at that shard's next local position.
//!
//! With `W = 1` the coordinator is the identity and the output matches
//! unsharded [`PairBalance`] exactly (tested below). The in-process
//! version routes rows zero-copy one at a time; a multi-node deployment
//! would batch per-shard slices and exchange orders at the epoch
//! boundary — see ROADMAP "Open items".

use std::ops::Range;

use crate::ordering::{GradBlock, OrderPolicy, PairBalance};

pub struct ShardedOrder {
    /// Per-shard balancers over disjoint contiguous unit ranges.
    shards: Vec<PairBalance>,
    /// Global unit id of shard w's local unit 0.
    bases: Vec<usize>,
    n: usize,
    /// Merged epoch order (global unit ids), rebuilt lazily per epoch.
    merged: Vec<usize>,
    /// Epoch position -> owning shard.
    route: Vec<u32>,
    /// Per-shard local observe cursors for the current epoch.
    cursors: Vec<usize>,
    /// Merged order needs rebuilding (new epoch).
    dirty: bool,
    observed: usize,
}

impl ShardedOrder {
    /// Split `n` units of dimension `d` across `num_shards` contiguous
    /// ranges (sizes differ by at most one; shards may be empty when
    /// `num_shards > n`).
    pub fn new(n: usize, d: usize, num_shards: usize) -> ShardedOrder {
        assert!(num_shards >= 1, "need at least one shard");
        let base_size = n / num_shards;
        let remainder = n % num_shards;
        let mut shards = Vec::with_capacity(num_shards);
        let mut bases = Vec::with_capacity(num_shards);
        let mut start = 0;
        for w in 0..num_shards {
            let size = base_size + usize::from(w < remainder);
            shards.push(PairBalance::new(size, d));
            bases.push(start);
            start += size;
        }
        debug_assert_eq!(start, n);
        ShardedOrder {
            shards,
            bases,
            n,
            merged: vec![0; n],
            route: vec![0; n],
            cursors: vec![0; num_shards],
            dirty: true,
            observed: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Round-robin merge of the shard-local orders into the global epoch
    /// order, plus the position->shard routing table. Local unit ids are
    /// lifted to global ids with the shard base offset.
    fn rebuild(&mut self, epoch: usize) {
        let locals: Vec<&[usize]> = self
            .shards
            .iter_mut()
            .map(|s| s.epoch_order(epoch))
            .collect();
        let mut taken: Vec<usize> = vec![0; locals.len()];
        let mut pos = 0;
        while pos < self.n {
            for (w, local) in locals.iter().enumerate() {
                if taken[w] < local.len() {
                    self.merged[pos] = self.bases[w] + local[taken[w]];
                    self.route[pos] = w as u32;
                    taken[w] += 1;
                    pos += 1;
                }
            }
        }
        for c in self.cursors.iter_mut() {
            *c = 0;
        }
    }
}

impl OrderPolicy for ShardedOrder {
    fn name(&self) -> &'static str {
        "cd-grab"
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.dirty {
            self.rebuild(epoch);
            self.dirty = false;
        }
        &self.merged
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        debug_assert!(!self.dirty, "observe before epoch_order");
        if self.shards.len() == 1 {
            // Degenerate coordinator: local positions == global
            // positions, forward the whole block untouched so W=1 costs
            // exactly what unsharded PairBalance costs.
            let q = self.cursors[0];
            self.cursors[0] += block.rows();
            self.shards[0].observe_block(q..q + block.rows(), block);
        } else {
            // De-interleave rows to their owning shard at its next local
            // position (local positions arrive in order by construction
            // of the round-robin merge). Shards are concrete
            // PairBalance values, so these are static calls; the per-row
            // forwarding (vs gathering each shard's strided rows into a
            // scratch block) is the zero-copy tradeoff noted in
            // ROADMAP "Open items".
            for (i, row) in block.iter_rows().enumerate() {
                let w = self.route[range.start + i] as usize;
                let q = self.cursors[w];
                self.cursors[w] += 1;
                self.shards[w].observe_block(
                    q..q + 1,
                    &GradBlock::new(row, block.dim()),
                );
            }
        }
        self.observed += block.rows();
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "ShardedOrder epoch_end before observing all {} units", self.n
        );
        for s in self.shards.iter_mut() {
            s.epoch_end();
        }
        self.observed = 0;
        self.dirty = true;
    }

    fn state_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.state_bytes()).sum::<usize>()
            + self.merged.len() * std::mem::size_of::<usize>()
            + self.route.len() * std::mem::size_of::<u32>()
    }

    fn wants_grads(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn feed_epoch(
        p: &mut dyn OrderPolicy,
        vs: &[Vec<f32>],
        block: usize,
    ) {
        let mut flat = Vec::new();
        crate::ordering::stream_static_epoch(p, vs, &mut flat, block);
    }

    #[test]
    fn shard_ranges_partition_units() {
        let s = ShardedOrder::new(10, 2, 4);
        assert_eq!(s.num_shards(), 4);
        assert_eq!(s.bases, vec![0, 3, 6, 8]);
        let sizes: Vec<usize> =
            s.shards.iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn first_epoch_interleaves_shards_round_robin() {
        let mut s = ShardedOrder::new(10, 2, 4);
        // Shard locals are identity on epoch 0, so the merge is the
        // lock-step interleave of [0,1,2], [3,4,5], [6,7], [8,9].
        assert_eq!(
            s.epoch_order(0),
            &[0, 3, 6, 8, 1, 4, 7, 9, 2, 5]
        );
    }

    #[test]
    fn sharded_order_is_always_a_permutation() {
        // The ISSUE's property test: W shards, random n/d/block sizes,
        // every epoch's merged order is a valid permutation of 0..n.
        prop::forall("sharded permutations", 24, |rng| {
            let n = 1 + rng.gen_range(96) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let w = 1 + rng.gen_range(8) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let vs = gen::vec_set(rng, n, d);
            let mut p = ShardedOrder::new(n, d, w);
            for _ in 0..3 {
                assert_permutation(p.epoch_order(0))?;
                feed_epoch(&mut p, &vs, b);
            }
            assert_permutation(p.epoch_order(0))?;
            Ok(())
        });
    }

    #[test]
    fn single_shard_matches_unsharded_pair_balance_exactly() {
        // Acceptance gate: W=1 sharded output == unsharded PairBalance,
        // byte for byte, across epochs and block sizes.
        let mut rng = Rng::new(5);
        for (n, b) in [(33usize, 7usize), (64, 16), (10, 1)] {
            let d = 8;
            let vs = gen::vec_set(&mut rng, n, d);
            let mut sharded = ShardedOrder::new(n, d, 1);
            let mut plain = PairBalance::new(n, d);
            for _ in 0..3 {
                feed_epoch(&mut sharded, &vs, b);
                feed_epoch(&mut plain, &vs, b);
                assert_eq!(
                    sharded.epoch_order(0).to_vec(),
                    plain.epoch_order(0).to_vec(),
                    "n={n} b={b}"
                );
            }
        }
    }

    #[test]
    fn sharded_beats_random_on_static_gradients() {
        // W in {1, 4}: the coordinator's merged order must still beat
        // random reshuffling's herding bound (CD-GraB's headline).
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let perm = rng.permutation(n);
            rand_acc += herding_bound(&vs, &perm).0;
        }
        let rand_inf = rand_acc / 5.0;
        for w in [1usize, 4] {
            let mut p = ShardedOrder::new(n, d, w);
            for _ in 0..8 {
                feed_epoch(&mut p, &vs, 64);
            }
            let (inf, _) = herding_bound(&vs, p.epoch_order(0));
            assert!(
                inf < rand_inf,
                "W={w}: sharded {inf} vs random {rand_inf}"
            );
        }
    }

    #[test]
    fn more_shards_than_units_still_works() {
        let d = 3;
        let vs = gen::vec_set(&mut Rng::new(2), 3, d);
        let mut p = ShardedOrder::new(3, d, 8);
        for _ in 0..2 {
            assert_permutation(p.epoch_order(0)).unwrap();
            feed_epoch(&mut p, &vs, 2);
        }
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut p = ShardedOrder::new(4, 1, 2);
        let _ = p.epoch_order(0);
        p.observe(0, &[1.0]);
        p.epoch_end();
    }
}
