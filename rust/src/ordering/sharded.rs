//! ShardedOrder — CD-GraB's distributed coordination (Cooper et al.
//! 2023, Algorithm 2 `CD-GraB`), simulated in-process over W shards.
//!
//! The dataset's `0..n` units are split into W contiguous ranges
//! ("workers"). Each shard runs its own [`PairBalance`] over its local
//! units — pair balancing needs no global mean, so shards are fully
//! independent between epoch boundaries, exactly the property CD-GraB
//! exploits to parallelize GraB across workers. The coordinator does two
//! things, mirroring the paper's server loop:
//!
//! * **merge** — the epoch order interleaves the shard orders
//!   round-robin (lock-step rounds: round t visits each worker's t-th
//!   local example), so consecutive global positions map to different
//!   shards just as in synchronous data-parallel training;
//! * **route** — observed gradient blocks are de-interleaved back to the
//!   owning shard's balancer at that shard's next local position.
//!
//! Three dispatch backends share that coordinator, differing only in
//! *where* the shard balancers run:
//!
//! * [`ShardedOrder::new`] — **strided**: rows are forwarded to the
//!   owning balancer one at a time on the caller's thread, zero-copy;
//! * [`ShardedOrder::new_gathered`] — **gathered**: each shard's strided
//!   rows are first copied into a reusable scratch block, then balanced
//!   as one batched `observe_block` call, still on the caller's thread
//!   (one copy for batched balancing — the ablation point between the
//!   other two, measured in `benches/ordering_overhead.rs`);
//! * [`ShardedOrder::new_async`] — **async**: each shard balancer runs
//!   on its own worker thread behind a bounded block queue
//!   ([`crate::ordering::queue`]). `observe_block` becomes gather +
//!   enqueue; the actual pair balancing overlaps with the trainer's
//!   next microbatch. The only join is the epoch-boundary drain inside
//!   [`OrderPolicy::epoch_end`] — the CD-GraB server loop made actually
//!   concurrent.
//!
//! All three are **bit-deterministic** and produce identical epoch
//! orders for a fixed gradient stream: each shard balancer sees exactly
//! the same local rows in the same order regardless of how they were
//! carried, and [`PairBalance`] is block-size invariant (pairs straddle
//! block boundaries via its pending-row state). Property-tested below;
//! `docs/determinism.md` documents the full equivalence-contract chain.
//!
//! With `W = 1` the coordinator is the identity and the output matches
//! unsharded [`PairBalance`] exactly (tested below). A worker that
//! panics does not deadlock the coordinator: its queue endpoints
//! disconnect, and the panic payload is re-raised at the epoch boundary
//! (`epoch_end`), where the drain would otherwise have joined it.

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver};
use std::thread::JoinHandle;

use crate::ordering::queue::{
    block_queue, BlockReceiver, BlockSender, ScratchBlock, ShardMsg,
};
use crate::ordering::{GradBlock, OrderPolicy, PairBalance};

/// Round-robin merge of shard-local orders into the global epoch order
/// plus the position → shard routing table. Local unit ids are lifted to
/// global ids with the shard base offsets. Round t visits each
/// non-exhausted shard's t-th local unit, in shard index order.
fn merge_round_robin(
    locals: &[&[usize]],
    bases: &[usize],
    merged: &mut [usize],
    route: &mut [u32],
) {
    let mut taken: Vec<usize> = vec![0; locals.len()];
    let mut pos = 0;
    while pos < merged.len() {
        for (w, local) in locals.iter().enumerate() {
            if taken[w] < local.len() {
                merged[pos] = bases[w] + local[taken[w]];
                route[pos] = w as u32;
                taken[w] += 1;
                pos += 1;
            }
        }
    }
}

/// What a shard worker sends back at each epoch boundary.
struct EpochReport {
    /// The shard's next local epoch order.
    order: Vec<usize>,
    /// The shard balancer's current `state_bytes`.
    state_bytes: usize,
}

/// One async shard: the coordinator-side queue endpoint, the report
/// channel, and the worker's join handle (used for panic propagation
/// and shutdown).
struct ShardWorker {
    queue: Option<BlockSender>,
    reports: Receiver<EpochReport>,
    handle: Option<JoinHandle<()>>,
    /// Set once an enqueue failed; skips further sends to a dead worker
    /// so the epoch can still complete before the boundary re-raises.
    dead: bool,
}

impl ShardWorker {
    /// Join the worker and re-raise its panic payload; called when the
    /// epoch-boundary drain finds the report channel disconnected.
    fn propagate_failure(&mut self, shard: usize) -> ! {
        if let Some(handle) = self.handle.take() {
            match handle.join() {
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(()) => panic!(
                    "shard worker {shard} exited before the epoch ended"
                ),
            }
        }
        panic!("shard worker {shard} failed and was already joined");
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        // Closing the queue ends the worker's recv loop; a panic payload
        // at this point was either already surfaced by epoch_end or the
        // coordinator itself is unwinding, so the join result is dropped.
        self.queue = None;
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// The async backend: W workers plus the coordinator's cached view of
/// their latest epoch orders (identity until the first boundary).
struct AsyncShards {
    workers: Vec<ShardWorker>,
    local_orders: Vec<Vec<usize>>,
    shard_state_bytes: Vec<usize>,
    /// Per-call staging slots for lazily acquired scratch blocks
    /// (allocated once; all `None` between `observe_block` calls).
    staged: Vec<Option<ScratchBlock>>,
}

impl AsyncShards {
    fn spawn(sizes: &[usize], d: usize, depth: usize) -> AsyncShards {
        let mut workers = Vec::with_capacity(sizes.len());
        let mut local_orders = Vec::with_capacity(sizes.len());
        let mut shard_state_bytes = Vec::with_capacity(sizes.len());
        for &size in sizes {
            let balancer = PairBalance::new(size, d);
            shard_state_bytes.push(balancer.state_bytes());
            local_orders.push((0..size).collect());
            let (sender, receiver) = block_queue(d, depth);
            let (report_tx, report_rx) = channel();
            let handle = std::thread::spawn(move || {
                shard_worker_loop(receiver, balancer, report_tx);
            });
            workers.push(ShardWorker {
                queue: Some(sender),
                reports: report_rx,
                handle: Some(handle),
                dead: false,
            });
        }
        AsyncShards {
            staged: (0..workers.len()).map(|_| None).collect(),
            workers,
            local_orders,
            shard_state_bytes,
        }
    }

    /// Gather this block's rows per owning shard and enqueue one scratch
    /// block per shard touched. Blocking happens only when a shard's
    /// scratch pool is exhausted (backpressure); dead shards are skipped
    /// until the epoch boundary re-raises their panic.
    fn observe(&mut self, range: Range<usize>, block: &GradBlock, route: &[u32]) {
        for (i, row) in block.iter_rows().enumerate() {
            let w = route[range.start + i] as usize;
            if self.workers[w].dead {
                continue;
            }
            if self.staged[w].is_none() {
                let queue = self.workers[w]
                    .queue
                    .as_mut()
                    .expect("queue open while worker is live");
                match queue.acquire() {
                    Some(scratch) => self.staged[w] = Some(scratch),
                    None => {
                        self.workers[w].dead = true;
                        continue;
                    }
                }
            }
            if let Some(scratch) = self.staged[w].as_mut() {
                scratch.push_row(row);
            }
        }
        for (w, slot) in self.staged.iter_mut().enumerate() {
            if let Some(scratch) = slot.take() {
                let queue = self.workers[w]
                    .queue
                    .as_mut()
                    .expect("queue open while worker is live");
                if !queue.send(scratch) {
                    self.workers[w].dead = true;
                }
            }
        }
    }

    /// The epoch-boundary barrier: signal every worker, then collect
    /// every report. Signalling first keeps the drains overlapped — no
    /// worker waits on another's `epoch_end`. A disconnected report
    /// channel means the worker panicked; its payload is re-raised here.
    fn drain_epoch(&mut self) {
        for worker in &self.workers {
            if let Some(queue) = &worker.queue {
                // A send failure is surfaced by the recv below.
                let _ = queue.end_epoch();
            }
        }
        for (w, worker) in self.workers.iter_mut().enumerate() {
            match worker.reports.recv() {
                Ok(report) => {
                    self.local_orders[w] = report.order;
                    self.shard_state_bytes[w] = report.state_bytes;
                }
                Err(_) => worker.propagate_failure(w),
            }
        }
    }

    /// Total backpressure events across all shard queues.
    fn stalls(&self) -> u64 {
        self.workers
            .iter()
            .filter_map(|w| w.queue.as_ref())
            .map(|q| q.stalls())
            .sum()
    }

    /// Bytes held by the circulating scratch pools (per-queue depth ×
    /// high-water gather size — buffers keep their capacity as they
    /// recycle, so this tracks steady-state memory, not the seed size).
    fn pool_bytes(&self) -> usize {
        self.workers
            .iter()
            .filter_map(|w| w.queue.as_ref())
            .map(|q| q.pool_bytes())
            .sum()
    }
}

/// A shard worker's thread body: balance queued blocks at the shard's
/// running local position, finalize + report at each epoch boundary,
/// exit when the coordinator closes the queue.
fn shard_worker_loop(
    receiver: BlockReceiver,
    mut balancer: PairBalance,
    reports: std::sync::mpsc::Sender<EpochReport>,
) {
    let mut cursor = 0usize;
    while let Some(msg) = receiver.recv() {
        match msg {
            ShardMsg::Block(scratch) => {
                let rows = scratch.rows();
                if rows > 0 {
                    balancer.observe_block(
                        cursor..cursor + rows,
                        &scratch.as_grad_block(),
                    );
                    cursor += rows;
                }
                receiver.recycle(scratch);
            }
            ShardMsg::EpochEnd => {
                balancer.epoch_end();
                cursor = 0;
                let report = EpochReport {
                    order: balancer.epoch_order(0).to_vec(),
                    state_bytes: balancer.state_bytes(),
                };
                if reports.send(report).is_err() {
                    return; // coordinator gone
                }
            }
            #[cfg(test)]
            ShardMsg::Poison => panic!("poisoned shard worker"),
        }
    }
}

/// Where the W shard balancers run and how observed rows reach them.
enum Backend {
    /// Caller-thread dispatch, one zero-copy row at a time.
    Strided(Vec<PairBalance>),
    /// Caller-thread dispatch after gathering each shard's strided rows
    /// into a reusable scratch block (one copy, batched balancing).
    Gathered {
        shards: Vec<PairBalance>,
        scratch: Vec<ScratchBlock>,
    },
    /// Worker-thread dispatch behind bounded per-shard block queues.
    Async(AsyncShards),
}

/// CD-GraB's sharded coordinator: W [`PairBalance`] workers over
/// disjoint contiguous unit ranges, merged round-robin at each epoch
/// boundary. See the module docs for the three dispatch backends.
pub struct ShardedOrder {
    backend: Backend,
    /// Global unit id of shard w's local unit 0.
    bases: Vec<usize>,
    n: usize,
    /// Merged epoch order (global unit ids), rebuilt lazily per epoch.
    merged: Vec<usize>,
    /// Epoch position -> owning shard.
    route: Vec<u32>,
    /// Per-shard local observe cursors (inline backends only; async
    /// workers track their own local positions).
    cursors: Vec<usize>,
    /// Merged order needs rebuilding (new epoch).
    dirty: bool,
    observed: usize,
}

/// Shard sizes (differing by at most one) and base offsets for `n`
/// units over `num_shards` contiguous ranges.
fn split_units(n: usize, num_shards: usize) -> (Vec<usize>, Vec<usize>) {
    assert!(num_shards >= 1, "need at least one shard");
    let base_size = n / num_shards;
    let remainder = n % num_shards;
    let mut sizes = Vec::with_capacity(num_shards);
    let mut bases = Vec::with_capacity(num_shards);
    let mut start = 0;
    for w in 0..num_shards {
        let size = base_size + usize::from(w < remainder);
        sizes.push(size);
        bases.push(start);
        start += size;
    }
    debug_assert_eq!(start, n);
    (sizes, bases)
}

impl ShardedOrder {
    /// Synchronous strided coordinator: split `n` units of dimension `d`
    /// across `num_shards` contiguous ranges (sizes differ by at most
    /// one; shards may be empty when `num_shards > n`) and forward
    /// observed rows to the owning balancer one at a time, zero-copy, on
    /// the caller's thread.
    pub fn new(n: usize, d: usize, num_shards: usize) -> ShardedOrder {
        let (sizes, bases) = split_units(n, num_shards);
        let shards =
            sizes.iter().map(|&s| PairBalance::new(s, d)).collect();
        ShardedOrder::assemble(Backend::Strided(shards), bases, n)
    }

    /// Synchronous gathered coordinator: like [`ShardedOrder::new`], but
    /// each shard's strided rows are copied into a reusable scratch
    /// block and balanced as one batched call — the copy-for-batching
    /// trade measured in `benches/ordering_overhead.rs`.
    pub fn new_gathered(
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> ShardedOrder {
        let (sizes, bases) = split_units(n, num_shards);
        let shards: Vec<PairBalance> =
            sizes.iter().map(|&s| PairBalance::new(s, d)).collect();
        let scratch =
            (0..num_shards).map(|_| ScratchBlock::new(d)).collect();
        ShardedOrder::assemble(
            Backend::Gathered { shards, scratch },
            bases,
            n,
        )
    }

    /// Asynchronous coordinator: each shard balancer runs on its own
    /// worker thread behind a bounded block queue holding at most
    /// `queue_depth` in-flight blocks. `observe_block` becomes gather +
    /// non-blocking enqueue (it only waits when a shard's queue is
    /// full); the epoch-boundary merge in
    /// [`OrderPolicy::epoch_end`] is the only join. Produces exactly the
    /// same epoch orders as the synchronous backends for the same
    /// gradient stream.
    pub fn new_async(
        n: usize,
        d: usize,
        num_shards: usize,
        queue_depth: usize,
    ) -> ShardedOrder {
        assert!(d > 0, "async shards need a positive dimension");
        let (sizes, bases) = split_units(n, num_shards);
        let shards = AsyncShards::spawn(&sizes, d, queue_depth);
        ShardedOrder::assemble(Backend::Async(shards), bases, n)
    }

    fn assemble(
        backend: Backend,
        bases: Vec<usize>,
        n: usize,
    ) -> ShardedOrder {
        let num_shards = bases.len();
        ShardedOrder {
            backend,
            bases,
            n,
            merged: vec![0; n],
            route: vec![0; n],
            cursors: vec![0; num_shards],
            dirty: true,
            observed: 0,
        }
    }

    /// Number of shard balancers (CD-GraB's W).
    pub fn num_shards(&self) -> usize {
        self.cursors.len()
    }

    /// Whether this coordinator dispatches to worker threads.
    pub fn is_async(&self) -> bool {
        matches!(self.backend, Backend::Async(_))
    }

    /// Total backpressure events (acquire waits on a full shard queue)
    /// since construction. Always 0 for the synchronous backends.
    pub fn queue_stalls(&self) -> u64 {
        match &self.backend {
            Backend::Async(shards) => shards.stalls(),
            _ => 0,
        }
    }

    /// Rebuild the merged order + routing table from the shard-local
    /// orders (queried inline, or cached from the last async drain).
    fn rebuild(&mut self, epoch: usize) {
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                let locals: Vec<&[usize]> = shards
                    .iter_mut()
                    .map(|s| s.epoch_order(epoch))
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
            Backend::Async(shards) => {
                let locals: Vec<&[usize]> = shards
                    .local_orders
                    .iter()
                    .map(|o| o.as_slice())
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
        }
        for c in self.cursors.iter_mut() {
            *c = 0;
        }
    }

    /// Test-only: make shard `w`'s worker panic on its next dequeue
    /// (async backend only), to exercise boundary panic propagation.
    #[cfg(test)]
    fn poison_shard(&self, w: usize) {
        match &self.backend {
            Backend::Async(shards) => {
                if let Some(queue) = &shards.workers[w].queue {
                    queue.poison();
                }
            }
            _ => panic!("poison_shard needs the async backend"),
        }
    }
}

impl OrderPolicy for ShardedOrder {
    fn name(&self) -> &'static str {
        match self.backend {
            Backend::Async(_) => "cd-grab-async",
            _ => "cd-grab",
        }
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.dirty {
            self.rebuild(epoch);
            self.dirty = false;
        }
        &self.merged
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        debug_assert!(!self.dirty, "observe before epoch_order");
        match &mut self.backend {
            // Degenerate inline coordinator (W = 1): local positions ==
            // global positions, forward the whole block untouched so it
            // costs exactly what unsharded PairBalance costs. (The
            // async backend still gathers at W = 1 — the queue hand-off
            // forces the copy either way.)
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. }
                if shards.len() == 1 =>
            {
                let q = self.cursors[0];
                self.cursors[0] += block.rows();
                shards[0].observe_block(q..q + block.rows(), block);
            }
            Backend::Strided(shards) => {
                // De-interleave rows to their owning shard at its next
                // local position (local positions arrive in order by
                // construction of the round-robin merge).
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    let q = self.cursors[w];
                    self.cursors[w] += 1;
                    shards[w].observe_block(
                        q..q + 1,
                        &GradBlock::new(row, block.dim()),
                    );
                }
            }
            Backend::Gathered { shards, scratch } => {
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    scratch[w].push_row(row);
                }
                for (w, buf) in scratch.iter_mut().enumerate() {
                    let rows = buf.rows();
                    if rows == 0 {
                        continue;
                    }
                    let q = self.cursors[w];
                    self.cursors[w] += rows;
                    shards[w].observe_block(
                        q..q + rows,
                        &buf.as_grad_block(),
                    );
                    buf.clear();
                }
            }
            Backend::Async(shards) => {
                shards.observe(range, block, &self.route);
            }
        }
        self.observed += block.rows();
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "ShardedOrder epoch_end before observing all {} units", self.n
        );
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                for s in shards.iter_mut() {
                    s.epoch_end();
                }
            }
            Backend::Async(shards) => shards.drain_epoch(),
        }
        self.observed = 0;
        self.dirty = true;
    }

    fn state_bytes(&self) -> usize {
        let shard_bytes = match &self.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
            }
            Backend::Gathered { shards, scratch } => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
                    + scratch
                        .iter()
                        .map(|b| b.capacity_bytes())
                        .sum::<usize>()
            }
            Backend::Async(shards) => {
                shards.shard_state_bytes.iter().sum::<usize>()
                    + shards.pool_bytes()
            }
        };
        shard_bytes
            + self.merged.len() * std::mem::size_of::<usize>()
            + self.route.len() * std::mem::size_of::<u32>()
    }

    fn wants_grads(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn feed_epoch(
        p: &mut dyn OrderPolicy,
        vs: &[Vec<f32>],
        block: usize,
    ) {
        let mut flat = Vec::new();
        crate::ordering::stream_static_epoch(p, vs, &mut flat, block);
    }

    fn shard_sizes(s: &ShardedOrder) -> Vec<usize> {
        match &s.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|p| p.len()).collect()
            }
            _ => panic!("expected strided backend"),
        }
    }

    #[test]
    fn shard_ranges_partition_units() {
        let s = ShardedOrder::new(10, 2, 4);
        assert_eq!(s.num_shards(), 4);
        assert_eq!(s.bases, vec![0, 3, 6, 8]);
        assert_eq!(shard_sizes(&s), vec![3, 3, 2, 2]);
    }

    #[test]
    fn first_epoch_interleaves_shards_round_robin() {
        let mut s = ShardedOrder::new(10, 2, 4);
        // Shard locals are identity on epoch 0, so the merge is the
        // lock-step interleave of [0,1,2], [3,4,5], [6,7], [8,9].
        assert_eq!(
            s.epoch_order(0),
            &[0, 3, 6, 8, 1, 4, 7, 9, 2, 5]
        );
    }

    #[test]
    fn sharded_order_is_always_a_permutation() {
        // W shards, random n/d/block sizes, every epoch's merged order
        // is a valid permutation of 0..n — for every backend.
        prop::forall("sharded permutations", 16, |rng| {
            let n = 1 + rng.gen_range(96) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let w = 1 + rng.gen_range(8) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let vs = gen::vec_set(rng, n, d);
            let mut policies: Vec<ShardedOrder> = vec![
                ShardedOrder::new(n, d, w),
                ShardedOrder::new_gathered(n, d, w),
                ShardedOrder::new_async(n, d, w, 2),
            ];
            for p in policies.iter_mut() {
                for _ in 0..3 {
                    assert_permutation(p.epoch_order(0))?;
                    feed_epoch(p, &vs, b);
                }
                assert_permutation(p.epoch_order(0))?;
            }
            Ok(())
        });
    }

    #[test]
    fn async_and_gathered_orders_match_strided_exactly() {
        // The ISSUE's acceptance property: for a fixed seed and
        // W in {1, 2, 4}, the async coordinator's epoch orders equal
        // the synchronous path's exactly across >= 3 epochs (and the
        // gathered backend agrees too), for random n/d/block/depth.
        prop::forall("async == sync sharded orders", 12, |rng| {
            let n = 1 + rng.gen_range(80) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let depth = 1 + rng.gen_range(4) as usize;
            let vs = gen::vec_set(rng, n, d);
            for w in [1usize, 2, 4] {
                let mut strided = ShardedOrder::new(n, d, w);
                let mut gathered = ShardedOrder::new_gathered(n, d, w);
                let mut asynch = ShardedOrder::new_async(n, d, w, depth);
                for epoch in 0..3 {
                    feed_epoch(&mut strided, &vs, b);
                    feed_epoch(&mut gathered, &vs, b);
                    feed_epoch(&mut asynch, &vs, b);
                    let want = strided.epoch_order(0).to_vec();
                    if gathered.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "gathered != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b}"
                        ));
                    }
                    if asynch.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "async != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b} depth={depth}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_shard_matches_unsharded_pair_balance_exactly() {
        // Acceptance gate: W=1 sharded output == unsharded PairBalance,
        // byte for byte, across epochs and block sizes — the async
        // equivalence test above then chains the invariant through to
        // the worker-thread path.
        let mut rng = Rng::new(5);
        for (n, b) in [(33usize, 7usize), (64, 16), (10, 1)] {
            let d = 8;
            let vs = gen::vec_set(&mut rng, n, d);
            let mut sharded = ShardedOrder::new(n, d, 1);
            let mut plain = PairBalance::new(n, d);
            for _ in 0..3 {
                feed_epoch(&mut sharded, &vs, b);
                feed_epoch(&mut plain, &vs, b);
                assert_eq!(
                    sharded.epoch_order(0).to_vec(),
                    plain.epoch_order(0).to_vec(),
                    "n={n} b={b}"
                );
            }
        }
    }

    #[test]
    fn sharded_beats_random_on_static_gradients() {
        // W in {1, 4}: the coordinator's merged order must still beat
        // random reshuffling's herding bound (CD-GraB's headline).
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let perm = rng.permutation(n);
            rand_acc += herding_bound(&vs, &perm).0;
        }
        let rand_inf = rand_acc / 5.0;
        for w in [1usize, 4] {
            let mut p = ShardedOrder::new(n, d, w);
            for _ in 0..8 {
                feed_epoch(&mut p, &vs, 64);
            }
            let (inf, _) = herding_bound(&vs, p.epoch_order(0));
            assert!(
                inf < rand_inf,
                "W={w}: sharded {inf} vs random {rand_inf}"
            );
        }
    }

    #[test]
    fn more_shards_than_units_still_works() {
        let d = 3;
        let vs = gen::vec_set(&mut Rng::new(2), 3, d);
        for mut p in [
            ShardedOrder::new(3, d, 8),
            ShardedOrder::new_gathered(3, d, 8),
            ShardedOrder::new_async(3, d, 8, 2),
        ] {
            for _ in 0..2 {
                assert_permutation(p.epoch_order(0)).unwrap();
                feed_epoch(&mut p, &vs, 2);
            }
        }
    }

    #[test]
    fn worker_panic_surfaces_at_epoch_boundary() {
        // A poisoned worker panics on its next dequeue. The coordinator
        // must keep accepting observations (no deadlock on the dead
        // shard's queue) and re-raise the worker's payload at epoch_end
        // instead of hanging in the drain.
        let n = 16;
        let d = 2;
        let vs = gen::vec_set(&mut Rng::new(3), n, d);
        let mut p = ShardedOrder::new_async(n, d, 2, 2);
        let _ = p.epoch_order(0);
        p.poison_shard(1);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                feed_epoch(&mut p, &vs, 4); // ends with epoch_end
            }),
        )
        .expect_err("worker panic must propagate");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(
            msg.contains("poisoned shard worker"),
            "unexpected payload: {msg}"
        );
    }

    #[test]
    fn async_drop_mid_epoch_does_not_hang() {
        // Dropping the coordinator with blocks still queued must shut
        // the workers down cleanly (queue close ends their recv loops).
        let n = 32;
        let d = 4;
        let vs = gen::vec_set(&mut Rng::new(4), n, d);
        let mut p = ShardedOrder::new_async(n, d, 4, 2);
        let order = p.epoch_order(0).to_vec();
        let mut flat = vec![0.0f32; 8 * d];
        for (pos, &unit) in order.iter().take(8).enumerate() {
            flat[pos * d..(pos + 1) * d].copy_from_slice(&vs[unit]);
        }
        p.observe_block(0..8, &GradBlock::new(&flat, d));
        drop(p); // mid-epoch: workers still own queued blocks
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut p = ShardedOrder::new(4, 1, 2);
        let _ = p.epoch_order(0);
        p.observe(0, &[1.0]);
        p.epoch_end();
    }
}
