//! ShardedOrder — CD-GraB's distributed coordination (Cooper et al.
//! 2023, Algorithm 2 `CD-GraB`), simulated in-process over W shards.
//!
//! The dataset's `0..n` units are split into W contiguous ranges
//! ("workers"). Each shard runs its own [`PairBalance`] over its local
//! units — pair balancing needs no global mean, so shards are fully
//! independent between epoch boundaries, exactly the property CD-GraB
//! exploits to parallelize GraB across workers. The coordinator does two
//! things, mirroring the paper's server loop:
//!
//! * **merge** — the epoch order interleaves the shard orders
//!   round-robin (lock-step rounds: round t visits each worker's t-th
//!   local example), so consecutive global positions map to different
//!   shards just as in synchronous data-parallel training;
//! * **route** — observed gradient blocks are de-interleaved back to the
//!   owning shard's balancer at that shard's next local position.
//!
//! Four dispatch backends share that coordinator, differing only in
//! *where* the shard balancers run and what carries the bytes:
//!
//! * [`ShardedOrder::new`] — **strided**: rows are forwarded to the
//!   owning balancer one at a time on the caller's thread, zero-copy;
//! * [`ShardedOrder::new_gathered`] — **gathered**: each shard's strided
//!   rows are first copied into a reusable scratch block, then balanced
//!   as one batched `observe_block` call, still on the caller's thread
//!   (one copy for batched balancing — the ablation point between the
//!   other two, measured in `benches/ordering_overhead.rs`);
//! * [`ShardedOrder::new_async`] — **async / channel transport**: each
//!   shard balancer runs on its own worker thread behind a bounded
//!   block queue ([`crate::ordering::queue`]). `observe_block` becomes
//!   gather + enqueue; the actual pair balancing overlaps with the
//!   trainer's next microbatch. The only join is the epoch-boundary
//!   drain inside [`OrderPolicy::epoch_end`] — the CD-GraB server loop
//!   made actually concurrent;
//! * [`ShardedOrder::new_tcp_loopback`] / [`ShardedOrder::new_tcp_connect`]
//!   — **TCP transport**: the same conversation serialized into
//!   checksummed frames over sockets
//!   ([`crate::ordering::transport::tcp`]), with workers in-process
//!   over loopback or in a separate OS process (`exp cdgrab --listen`).
//!
//! The concurrent backends share one code path: the coordinator speaks
//! [`ShardTransport`] and never learns which carrier moved the bytes.
//!
//! All four are **bit-deterministic** and produce identical epoch
//! orders for a fixed gradient stream: each shard balancer sees exactly
//! the same local rows in the same order regardless of how they were
//! carried, and [`PairBalance`] is block-size invariant (pairs straddle
//! block boundaries via its pending-row state). Property-tested below
//! and in `tests/transport.rs`; `docs/determinism.md` documents the
//! full equivalence-contract chain.
//!
//! With `W = 1` the coordinator is the identity and the output matches
//! unsharded [`PairBalance`] exactly (tested below). A worker that
//! panics (or a socket peer that disconnects) does not deadlock the
//! coordinator: its link reports failure, and the payload/error is
//! re-raised at the epoch boundary (`epoch_end`), where the drain would
//! otherwise have joined it.

use std::ops::Range;

use crate::ordering::queue::ScratchBlock;
use crate::ordering::transport::{
    spawn_channel_shards, tcp, LinkStats, ShardTransport, TransportStats,
};
use crate::ordering::{GradBlock, OrderPolicy, PairBalance};

/// Round-robin merge of shard-local orders into the global epoch order
/// plus the position → shard routing table. Local unit ids are lifted to
/// global ids with the shard base offsets. Round t visits each
/// non-exhausted shard's t-th local unit, in shard index order.
fn merge_round_robin(
    locals: &[&[usize]],
    bases: &[usize],
    merged: &mut [usize],
    route: &mut [u32],
) {
    let mut taken: Vec<usize> = vec![0; locals.len()];
    let mut pos = 0;
    while pos < merged.len() {
        for (w, local) in locals.iter().enumerate() {
            if taken[w] < local.len() {
                merged[pos] = bases[w] + local[taken[w]];
                route[pos] = w as u32;
                taken[w] += 1;
                pos += 1;
            }
        }
    }
}

/// The transported backend: W shard links ([`ShardTransport`] — worker
/// threads behind channels, or TCP peers) plus the coordinator's cached
/// view of their latest epoch orders (identity until the first
/// boundary).
struct AsyncShards {
    links: Vec<Box<dyn ShardTransport>>,
    /// Short transport label for `OrderPolicy::name` and metrics.
    transport: &'static str,
    /// Per-link failure flag, set on the first failed send/acquire; the
    /// shard is skipped for the rest of the epoch and the failure is
    /// re-raised at the boundary drain.
    dead: Vec<bool>,
    local_orders: Vec<Vec<usize>>,
    shard_state_bytes: Vec<usize>,
    /// Per-call staging slots for lazily acquired scratch blocks
    /// (allocated once; all `None` between `observe_block` calls).
    staged: Vec<Option<ScratchBlock>>,
}

impl AsyncShards {
    /// Wrap pre-opened shard links into the coordinator backend.
    /// `sizes[w]` must match the local unit count link `w` was opened
    /// with.
    fn new(
        links: Vec<Box<dyn ShardTransport>>,
        sizes: &[usize],
        d: usize,
        transport: &'static str,
    ) -> AsyncShards {
        assert_eq!(links.len(), sizes.len());
        let shard_state_bytes = sizes
            .iter()
            .map(|&s| PairBalance::new(s, d).state_bytes())
            .collect();
        AsyncShards {
            staged: (0..links.len()).map(|_| None).collect(),
            dead: vec![false; links.len()],
            local_orders: sizes.iter().map(|&s| (0..s).collect()).collect(),
            links,
            transport,
            shard_state_bytes,
        }
    }

    /// Gather this block's rows per owning shard and ship one scratch
    /// block per shard touched. Blocking happens only at the link's
    /// backpressure point (full queue / full socket buffer); dead shards
    /// are skipped until the epoch boundary re-raises their failure.
    fn observe(&mut self, range: Range<usize>, block: &GradBlock, route: &[u32]) {
        for (i, row) in block.iter_rows().enumerate() {
            let w = route[range.start + i] as usize;
            if self.dead[w] {
                continue;
            }
            if self.staged[w].is_none() {
                match self.links[w].acquire() {
                    Some(scratch) => self.staged[w] = Some(scratch),
                    None => {
                        self.dead[w] = true;
                        continue;
                    }
                }
            }
            if let Some(scratch) = self.staged[w].as_mut() {
                scratch.push_row(row);
            }
        }
        for (w, slot) in self.staged.iter_mut().enumerate() {
            if let Some(scratch) = slot.take() {
                if !self.links[w].send_block(scratch) {
                    self.dead[w] = true;
                }
            }
        }
    }

    /// The epoch-boundary barrier: signal every link, then collect every
    /// report. Signalling first keeps the drains overlapped — no worker
    /// waits on another's `epoch_end`. A failed link surfaces here: the
    /// channel transport re-raises the worker's panic payload, a socket
    /// transport's typed error is raised as a coordinator panic — either
    /// way the failure lands at the boundary, exactly like a worker
    /// panic, and the coordinator's cached orders are left untouched.
    fn drain_epoch(&mut self) {
        for link in self.links.iter_mut() {
            // A send failure is surfaced by the recv below.
            let _ = link.end_epoch();
        }
        for (w, link) in self.links.iter_mut().enumerate() {
            match link.recv_report() {
                Ok(report) => {
                    self.local_orders[w] = report.order;
                    self.shard_state_bytes[w] = report.state_bytes;
                }
                Err(e) => panic!(
                    "shard {w} ({} transport) failed mid-epoch: {e}",
                    self.transport
                ),
            }
        }
    }

    /// Per-shard link counters (stalls, bytes moved each way).
    fn stats(&self) -> TransportStats {
        TransportStats {
            transport: self.transport,
            per_shard: self.links.iter().map(|l| l.stats()).collect(),
        }
    }
}

/// Where the W shard balancers run and how observed rows reach them.
enum Backend {
    /// Caller-thread dispatch, one zero-copy row at a time.
    Strided(Vec<PairBalance>),
    /// Caller-thread dispatch after gathering each shard's strided rows
    /// into a reusable scratch block (one copy, batched balancing).
    Gathered {
        shards: Vec<PairBalance>,
        scratch: Vec<ScratchBlock>,
    },
    /// Transported dispatch: shard balancers behind [`ShardTransport`]
    /// links (worker threads over channels, or TCP peers).
    Async(AsyncShards),
}

/// CD-GraB's sharded coordinator: W [`PairBalance`] workers over
/// disjoint contiguous unit ranges, merged round-robin at each epoch
/// boundary. See the module docs for the dispatch backends.
pub struct ShardedOrder {
    backend: Backend,
    /// Global unit id of shard w's local unit 0.
    bases: Vec<usize>,
    n: usize,
    /// Merged epoch order (global unit ids), rebuilt lazily per epoch.
    merged: Vec<usize>,
    /// Epoch position -> owning shard.
    route: Vec<u32>,
    /// Per-shard local observe cursors (inline backends only; async
    /// workers track their own local positions).
    cursors: Vec<usize>,
    /// Merged order needs rebuilding (new epoch).
    dirty: bool,
    observed: usize,
}

/// Shard sizes (differing by at most one) and base offsets for `n`
/// units over `num_shards` contiguous ranges.
fn split_units(n: usize, num_shards: usize) -> (Vec<usize>, Vec<usize>) {
    assert!(num_shards >= 1, "need at least one shard");
    let base_size = n / num_shards;
    let remainder = n % num_shards;
    let mut sizes = Vec::with_capacity(num_shards);
    let mut bases = Vec::with_capacity(num_shards);
    let mut start = 0;
    for w in 0..num_shards {
        let size = base_size + usize::from(w < remainder);
        sizes.push(size);
        bases.push(start);
        start += size;
    }
    debug_assert_eq!(start, n);
    (sizes, bases)
}

impl ShardedOrder {
    /// Synchronous strided coordinator: split `n` units of dimension `d`
    /// across `num_shards` contiguous ranges (sizes differ by at most
    /// one; shards may be empty when `num_shards > n`) and forward
    /// observed rows to the owning balancer one at a time, zero-copy, on
    /// the caller's thread.
    pub fn new(n: usize, d: usize, num_shards: usize) -> ShardedOrder {
        let (sizes, bases) = split_units(n, num_shards);
        let shards =
            sizes.iter().map(|&s| PairBalance::new(s, d)).collect();
        ShardedOrder::assemble(Backend::Strided(shards), bases, n)
    }

    /// Synchronous gathered coordinator: like [`ShardedOrder::new`], but
    /// each shard's strided rows are copied into a reusable scratch
    /// block and balanced as one batched call — the copy-for-batching
    /// trade measured in `benches/ordering_overhead.rs`.
    pub fn new_gathered(
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> ShardedOrder {
        let (sizes, bases) = split_units(n, num_shards);
        let shards: Vec<PairBalance> =
            sizes.iter().map(|&s| PairBalance::new(s, d)).collect();
        let scratch =
            (0..num_shards).map(|_| ScratchBlock::new(d)).collect();
        ShardedOrder::assemble(
            Backend::Gathered { shards, scratch },
            bases,
            n,
        )
    }

    /// Asynchronous coordinator over the in-process channel transport:
    /// each shard balancer runs on its own worker thread behind a
    /// bounded block queue holding at most `queue_depth` in-flight
    /// blocks. `observe_block` becomes gather + non-blocking enqueue (it
    /// only waits when a shard's queue is full); the epoch-boundary
    /// merge in [`OrderPolicy::epoch_end`] is the only join. Produces
    /// exactly the same epoch orders as the synchronous backends for the
    /// same gradient stream.
    pub fn new_async(
        n: usize,
        d: usize,
        num_shards: usize,
        queue_depth: usize,
    ) -> ShardedOrder {
        assert!(d > 0, "async shards need a positive dimension");
        let (sizes, bases) = split_units(n, num_shards);
        let links = spawn_channel_shards(&sizes, d, queue_depth);
        let shards = AsyncShards::new(links, &sizes, d, "channel");
        ShardedOrder::assemble(Backend::Async(shards), bases, n)
    }

    /// TCP coordinator with in-process loopback workers: spawn a
    /// listener plus one worker thread per shard inside this process,
    /// then run the full socket protocol (frames, checksums, handshake)
    /// over 127.0.0.1. Bit-equal to every other backend; used by tests,
    /// benches, and `--transport tcp` without `--connect`.
    pub fn new_tcp_loopback(
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> crate::Result<ShardedOrder> {
        anyhow::ensure!(d > 0, "tcp shards need a positive dimension");
        let (sizes, bases) = split_units(n, num_shards);
        let addr = tcp::spawn_loopback(num_shards)?;
        let links = tcp::connect_shards(addr, &sizes, d)?;
        let shards = AsyncShards::new(links, &sizes, d, "tcp");
        Ok(ShardedOrder::assemble(Backend::Async(shards), bases, n))
    }

    /// TCP coordinator against a remote worker server (`exp cdgrab
    /// --listen` in another process): dial `addr` once per shard and
    /// drive the same socket protocol as the loopback constructor.
    pub fn new_tcp_connect(
        addr: &str,
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> crate::Result<ShardedOrder> {
        anyhow::ensure!(d > 0, "tcp shards need a positive dimension");
        let (sizes, bases) = split_units(n, num_shards);
        let links = tcp::connect_shards(addr, &sizes, d)?;
        let shards = AsyncShards::new(links, &sizes, d, "tcp");
        Ok(ShardedOrder::assemble(Backend::Async(shards), bases, n))
    }

    fn assemble(
        backend: Backend,
        bases: Vec<usize>,
        n: usize,
    ) -> ShardedOrder {
        let num_shards = bases.len();
        ShardedOrder {
            backend,
            bases,
            n,
            merged: vec![0; n],
            route: vec![0; n],
            cursors: vec![0; num_shards],
            dirty: true,
            observed: 0,
        }
    }

    /// Number of shard balancers (CD-GraB's W).
    pub fn num_shards(&self) -> usize {
        self.cursors.len()
    }

    /// Whether this coordinator dispatches through a [`ShardTransport`]
    /// (worker threads or sockets) rather than inline.
    pub fn is_async(&self) -> bool {
        matches!(self.backend, Backend::Async(_))
    }

    /// Total backpressure events (acquire waits on a full shard queue)
    /// since construction. Always 0 for the synchronous backends and
    /// for TCP links (the kernel socket buffer is their backpressure).
    pub fn queue_stalls(&self) -> u64 {
        self.transport_stats().total().stalls
    }

    /// Aggregated per-shard link counters — stalls and bytes moved each
    /// way — comparable across the sync, channel, and tcp dispatch
    /// paths (the synchronous backends report one all-zero entry per
    /// shard).
    pub fn transport_stats(&self) -> TransportStats {
        match &self.backend {
            Backend::Async(shards) => shards.stats(),
            _ => TransportStats {
                transport: "inline",
                per_shard: vec![LinkStats::default(); self.num_shards()],
            },
        }
    }

    /// Rebuild the merged order + routing table from the shard-local
    /// orders (queried inline, or cached from the last async drain).
    fn rebuild(&mut self, epoch: usize) {
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                let locals: Vec<&[usize]> = shards
                    .iter_mut()
                    .map(|s| s.epoch_order(epoch))
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
            Backend::Async(shards) => {
                let locals: Vec<&[usize]> = shards
                    .local_orders
                    .iter()
                    .map(|o| o.as_slice())
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
        }
        for c in self.cursors.iter_mut() {
            *c = 0;
        }
    }

    /// Test-only: make shard `w`'s worker panic on its next dequeue
    /// (async backend only), to exercise boundary panic propagation.
    #[cfg(test)]
    fn poison_shard(&mut self, w: usize) {
        match &mut self.backend {
            Backend::Async(shards) => shards.links[w].poison(),
            _ => panic!("poison_shard needs the async backend"),
        }
    }
}

impl OrderPolicy for ShardedOrder {
    fn name(&self) -> &'static str {
        match &self.backend {
            Backend::Async(shards) => match shards.transport {
                "tcp" => "cd-grab-tcp",
                _ => "cd-grab-async",
            },
            _ => "cd-grab",
        }
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.dirty {
            self.rebuild(epoch);
            self.dirty = false;
        }
        &self.merged
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        debug_assert!(!self.dirty, "observe before epoch_order");
        match &mut self.backend {
            // Degenerate inline coordinator (W = 1): local positions ==
            // global positions, forward the whole block untouched so it
            // costs exactly what unsharded PairBalance costs. (The
            // async backend still gathers at W = 1 — the queue hand-off
            // forces the copy either way.)
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. }
                if shards.len() == 1 =>
            {
                let q = self.cursors[0];
                self.cursors[0] += block.rows();
                shards[0].observe_block(q..q + block.rows(), block);
            }
            Backend::Strided(shards) => {
                // De-interleave rows to their owning shard at its next
                // local position (local positions arrive in order by
                // construction of the round-robin merge).
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    let q = self.cursors[w];
                    self.cursors[w] += 1;
                    shards[w].observe_block(
                        q..q + 1,
                        &GradBlock::new(row, block.dim()),
                    );
                }
            }
            Backend::Gathered { shards, scratch } => {
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    scratch[w].push_row(row);
                }
                for (w, buf) in scratch.iter_mut().enumerate() {
                    let rows = buf.rows();
                    if rows == 0 {
                        continue;
                    }
                    let q = self.cursors[w];
                    self.cursors[w] += rows;
                    shards[w].observe_block(
                        q..q + rows,
                        &buf.as_grad_block(),
                    );
                    buf.clear();
                }
            }
            Backend::Async(shards) => {
                shards.observe(range, block, &self.route);
            }
        }
        self.observed += block.rows();
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "ShardedOrder epoch_end before observing all {} units", self.n
        );
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                for s in shards.iter_mut() {
                    s.epoch_end();
                }
            }
            Backend::Async(shards) => shards.drain_epoch(),
        }
        self.observed = 0;
        self.dirty = true;
    }

    fn state_bytes(&self) -> usize {
        let shard_bytes = match &self.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
            }
            Backend::Gathered { shards, scratch } => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
                    + scratch
                        .iter()
                        .map(|b| b.capacity_bytes())
                        .sum::<usize>()
            }
            Backend::Async(shards) => {
                // Worker-side balancer state (from the latest reports)
                // plus the coordinator-side link buffers (scratch
                // pools, frame buffers) — keeps Table 1 memory numbers
                // comparable across dispatch paths.
                shards.shard_state_bytes.iter().sum::<usize>()
                    + shards
                        .links
                        .iter()
                        .map(|l| l.buffer_bytes())
                        .sum::<usize>()
            }
        };
        shard_bytes
            + self.merged.len() * std::mem::size_of::<usize>()
            + self.route.len() * std::mem::size_of::<u32>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn transport_stats(&self) -> Option<TransportStats> {
        Some(ShardedOrder::transport_stats(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn feed_epoch(
        p: &mut dyn OrderPolicy,
        vs: &[Vec<f32>],
        block: usize,
    ) {
        let mut flat = Vec::new();
        crate::ordering::stream_static_epoch(p, vs, &mut flat, block);
    }

    fn shard_sizes(s: &ShardedOrder) -> Vec<usize> {
        match &s.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|p| p.len()).collect()
            }
            _ => panic!("expected strided backend"),
        }
    }

    #[test]
    fn shard_ranges_partition_units() {
        let s = ShardedOrder::new(10, 2, 4);
        assert_eq!(s.num_shards(), 4);
        assert_eq!(s.bases, vec![0, 3, 6, 8]);
        assert_eq!(shard_sizes(&s), vec![3, 3, 2, 2]);
    }

    #[test]
    fn first_epoch_interleaves_shards_round_robin() {
        let mut s = ShardedOrder::new(10, 2, 4);
        // Shard locals are identity on epoch 0, so the merge is the
        // lock-step interleave of [0,1,2], [3,4,5], [6,7], [8,9].
        assert_eq!(
            s.epoch_order(0),
            &[0, 3, 6, 8, 1, 4, 7, 9, 2, 5]
        );
    }

    #[test]
    fn sharded_order_is_always_a_permutation() {
        // W shards, random n/d/block sizes, every epoch's merged order
        // is a valid permutation of 0..n — for every backend.
        prop::forall("sharded permutations", 16, |rng| {
            let n = 1 + rng.gen_range(96) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let w = 1 + rng.gen_range(8) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let vs = gen::vec_set(rng, n, d);
            let mut policies: Vec<ShardedOrder> = vec![
                ShardedOrder::new(n, d, w),
                ShardedOrder::new_gathered(n, d, w),
                ShardedOrder::new_async(n, d, w, 2),
            ];
            for p in policies.iter_mut() {
                for _ in 0..3 {
                    assert_permutation(p.epoch_order(0))?;
                    feed_epoch(p, &vs, b);
                }
                assert_permutation(p.epoch_order(0))?;
            }
            Ok(())
        });
    }

    #[test]
    fn async_and_gathered_orders_match_strided_exactly() {
        // The ISSUE's acceptance property: for a fixed seed and
        // W in {1, 2, 4}, the async coordinator's epoch orders equal
        // the synchronous path's exactly across >= 3 epochs (and the
        // gathered backend agrees too), for random n/d/block/depth.
        prop::forall("async == sync sharded orders", 12, |rng| {
            let n = 1 + rng.gen_range(80) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let depth = 1 + rng.gen_range(4) as usize;
            let vs = gen::vec_set(rng, n, d);
            for w in [1usize, 2, 4] {
                let mut strided = ShardedOrder::new(n, d, w);
                let mut gathered = ShardedOrder::new_gathered(n, d, w);
                let mut asynch = ShardedOrder::new_async(n, d, w, depth);
                for epoch in 0..3 {
                    feed_epoch(&mut strided, &vs, b);
                    feed_epoch(&mut gathered, &vs, b);
                    feed_epoch(&mut asynch, &vs, b);
                    let want = strided.epoch_order(0).to_vec();
                    if gathered.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "gathered != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b}"
                        ));
                    }
                    if asynch.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "async != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b} depth={depth}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_shard_matches_unsharded_pair_balance_exactly() {
        // Acceptance gate: W=1 sharded output == unsharded PairBalance,
        // byte for byte, across epochs and block sizes — the async
        // equivalence test above then chains the invariant through to
        // the worker-thread path.
        let mut rng = Rng::new(5);
        for (n, b) in [(33usize, 7usize), (64, 16), (10, 1)] {
            let d = 8;
            let vs = gen::vec_set(&mut rng, n, d);
            let mut sharded = ShardedOrder::new(n, d, 1);
            let mut plain = PairBalance::new(n, d);
            for _ in 0..3 {
                feed_epoch(&mut sharded, &vs, b);
                feed_epoch(&mut plain, &vs, b);
                assert_eq!(
                    sharded.epoch_order(0).to_vec(),
                    plain.epoch_order(0).to_vec(),
                    "n={n} b={b}"
                );
            }
        }
    }

    #[test]
    fn sharded_beats_random_on_static_gradients() {
        // W in {1, 4}: the coordinator's merged order must still beat
        // random reshuffling's herding bound (CD-GraB's headline).
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let perm = rng.permutation(n);
            rand_acc += herding_bound(&vs, &perm).0;
        }
        let rand_inf = rand_acc / 5.0;
        for w in [1usize, 4] {
            let mut p = ShardedOrder::new(n, d, w);
            for _ in 0..8 {
                feed_epoch(&mut p, &vs, 64);
            }
            let (inf, _) = herding_bound(&vs, p.epoch_order(0));
            assert!(
                inf < rand_inf,
                "W={w}: sharded {inf} vs random {rand_inf}"
            );
        }
    }

    #[test]
    fn more_shards_than_units_still_works() {
        let d = 3;
        let vs = gen::vec_set(&mut Rng::new(2), 3, d);
        for mut p in [
            ShardedOrder::new(3, d, 8),
            ShardedOrder::new_gathered(3, d, 8),
            ShardedOrder::new_async(3, d, 8, 2),
        ] {
            for _ in 0..2 {
                assert_permutation(p.epoch_order(0)).unwrap();
                feed_epoch(&mut p, &vs, 2);
            }
        }
    }

    #[test]
    fn worker_panic_surfaces_at_epoch_boundary() {
        // A poisoned worker panics on its next dequeue. The coordinator
        // must keep accepting observations (no deadlock on the dead
        // shard's queue) and re-raise the worker's payload at epoch_end
        // instead of hanging in the drain.
        let n = 16;
        let d = 2;
        let vs = gen::vec_set(&mut Rng::new(3), n, d);
        let mut p = ShardedOrder::new_async(n, d, 2, 2);
        let _ = p.epoch_order(0);
        p.poison_shard(1);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                feed_epoch(&mut p, &vs, 4); // ends with epoch_end
            }),
        )
        .expect_err("worker panic must propagate");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(
            msg.contains("poisoned shard worker"),
            "unexpected payload: {msg}"
        );
    }

    #[test]
    fn async_drop_mid_epoch_does_not_hang() {
        // Dropping the coordinator with blocks still queued must shut
        // the workers down cleanly (queue close ends their recv loops).
        let n = 32;
        let d = 4;
        let vs = gen::vec_set(&mut Rng::new(4), n, d);
        let mut p = ShardedOrder::new_async(n, d, 4, 2);
        let order = p.epoch_order(0).to_vec();
        let mut flat = vec![0.0f32; 8 * d];
        for (pos, &unit) in order.iter().take(8).enumerate() {
            flat[pos * d..(pos + 1) * d].copy_from_slice(&vs[unit]);
        }
        p.observe_block(0..8, &GradBlock::new(&flat, d));
        drop(p); // mid-epoch: workers still own queued blocks
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut p = ShardedOrder::new(4, 1, 2);
        let _ = p.epoch_order(0);
        p.observe(0, &[1.0]);
        p.epoch_end();
    }
}
