//! ShardedOrder — CD-GraB's distributed coordination (Cooper et al.
//! 2023, Algorithm 2 `CD-GraB`), simulated in-process over W shards.
//!
//! The dataset's `0..n` units are split into W contiguous ranges
//! ("workers") by a [`Topology`] plan — classically W equal weights
//! (sizes differ by at most one), generally any integer weight vector
//! apportioned by [`crate::ordering::topology::split_units_weighted`].
//! Each shard runs its own [`PairBalance`] over its local units — pair
//! balancing needs no global mean, so shards are fully independent
//! between epoch boundaries, exactly the property CD-GraB exploits to
//! parallelize GraB across workers. The coordinator does two things,
//! mirroring the paper's server loop:
//!
//! * **merge** — the epoch order interleaves the shard orders
//!   round-robin (lock-step rounds: round t visits each worker's t-th
//!   local example), so consecutive global positions map to different
//!   shards just as in synchronous data-parallel training;
//! * **route** — observed gradient blocks are de-interleaved back to the
//!   owning shard's balancer at that shard's next local position.
//!
//! Four dispatch backends share that coordinator, differing only in
//! *where* the shard balancers run and what carries the bytes:
//!
//! * [`ShardedOrder::new`] — **strided**: rows are forwarded to the
//!   owning balancer one at a time on the caller's thread, zero-copy;
//! * [`ShardedOrder::new_gathered`] — **gathered**: each shard's strided
//!   rows are first copied into a reusable scratch block, then balanced
//!   as one batched `observe_block` call, still on the caller's thread
//!   (one copy for batched balancing — the ablation point between the
//!   other two, measured in `benches/ordering_overhead.rs`);
//! * [`ShardedOrder::new_async`] — **async / channel transport**: each
//!   shard balancer runs on its own worker thread behind a bounded
//!   block queue ([`crate::ordering::queue`]). `observe_block` becomes
//!   gather + enqueue; the actual pair balancing overlaps with the
//!   trainer's next microbatch. The only join is the epoch-boundary
//!   drain inside [`OrderPolicy::epoch_end`] — the CD-GraB server loop
//!   made actually concurrent;
//! * [`ShardedOrder::new_tcp_loopback`] / [`ShardedOrder::new_tcp_connect`]
//!   — **TCP transport**: the same conversation serialized into
//!   checksummed frames over sockets
//!   ([`crate::ordering::transport::tcp`]), with workers in-process
//!   over loopback or in a separate OS process (`exp cdgrab --listen`).
//!
//! The concurrent backends share one code path: the coordinator speaks
//! [`ShardTransport`] and never learns which carrier moved the bytes.
//!
//! # Elastic topologies
//!
//! The transported backends can additionally be **elastic**
//! ([`ShardedOrder::new_elastic`] and friends, `--elastic`): at each
//! epoch boundary the coordinator re-derives shard weights from the
//! epoch's measured per-link costs ([`ElasticPlanner`] — EWMA over
//! per-row blocked time, quantized integers, hysteresis) or follows a
//! pinned per-epoch schedule ([`WeightSource::Schedule`], the replay
//! path). When the plan's sizes change — or a link failed mid-epoch —
//! the coordinator *re-plans*: it re-splits `0..n` under the new
//! weights, bumps the topology generation, and opens fresh links
//! through its [`Relink`] hook (a fresh TCP `Hello` per link is the
//! shard-migration re-handshake). Shard balancer state restarts at a
//! re-plan; the GraB guarantee needs only that every unit is balanced
//! once per epoch, which every plan preserves by construction. The
//! per-epoch [`Topology`] log is recorded and surfaced
//! ([`OrderPolicy::topology_log`], `TrainResult`, `exp cdgrab` CSV) so
//! any elastic run replays bit-for-bit from its recorded weights —
//! determinism contract 6 in `docs/determinism.md`: an elastic run
//! whose weights stay frozen is bit-identical to the static topology,
//! and any weight schedule still emits valid permutations with every
//! unit balanced exactly once per epoch.
//!
//! All backends are **bit-deterministic** for a fixed gradient stream
//! and topology schedule: each shard balancer sees exactly the same
//! local rows in the same order regardless of how they were carried,
//! and [`PairBalance`] is block-size invariant (pairs straddle block
//! boundaries via its pending-row state). Property-tested below and in
//! `tests/transport.rs`; `docs/determinism.md` documents the full
//! equivalence-contract chain.
//!
//! With `W = 1` the coordinator is the identity and the output matches
//! unsharded [`PairBalance`] exactly (tested below). A worker that
//! panics (or a socket peer that disconnects) does not deadlock the
//! coordinator: its link reports failure, and the payload/error is
//! re-raised at the epoch boundary (`epoch_end`), where the drain would
//! otherwise have joined it — unless the coordinator is elastic, in
//! which case a failed *transport link* is survived by re-planning the
//! next epoch over the remaining shards (an in-process worker panic
//! still re-raises: thread panics are bugs, not churn).

use std::ops::Range;

use crate::ordering::queue::ScratchBlock;
use crate::ordering::topology::{
    ElasticPlanner, Topology, WeightSource,
};
use crate::ordering::transport::{
    spawn_channel_shards, spawn_channel_shards_with_kernel, tcp,
    LinkStats, Relink, ShardTransport, TransportStats,
};
use crate::ordering::{GradBlock, OrderPolicy, PairBalance};
use crate::tensor::Kernel;
use crate::util::timer::Stopwatch;

/// Round-robin merge of shard-local orders into the global epoch order
/// plus the position → shard routing table. Local unit ids are lifted to
/// global ids with the shard base offsets. Round t visits each
/// non-exhausted shard's t-th local unit, in shard index order.
fn merge_round_robin(
    locals: &[&[usize]],
    bases: &[usize],
    merged: &mut [usize],
    route: &mut [u32],
) {
    let mut taken: Vec<usize> = vec![0; locals.len()];
    let mut pos = 0;
    while pos < merged.len() {
        for (w, local) in locals.iter().enumerate() {
            if taken[w] < local.len() {
                merged[pos] = bases[w] + local[taken[w]];
                route[pos] = w as u32;
                taken[w] += 1;
                pos += 1;
            }
        }
    }
}

/// The transported backend: W shard links ([`ShardTransport`] — worker
/// threads behind channels, or TCP peers) plus the coordinator's cached
/// view of their latest epoch orders (identity until the first
/// boundary).
struct AsyncShards {
    links: Vec<Box<dyn ShardTransport>>,
    /// Short transport label for `OrderPolicy::name` and metrics.
    transport: &'static str,
    /// Per-link failure flag, set on the first failed send/acquire; the
    /// shard is skipped for the rest of the epoch and the failure is
    /// re-raised at the boundary drain.
    dead: Vec<bool>,
    local_orders: Vec<Vec<usize>>,
    shard_state_bytes: Vec<usize>,
    /// Per-call staging slots for lazily acquired scratch blocks
    /// (allocated once; all `None` between `observe_block` calls).
    staged: Vec<Option<ScratchBlock>>,
    /// Whether to clock per-link blocked time (elastic coordinators
    /// only — the static paths skip the `Instant::now` reads on the
    /// hot gather path).
    measure: bool,
    /// Seconds spent blocked on each link this epoch (scratch
    /// acquisition + block sends: queue stalls and full socket buffers
    /// both land here) — the elastic planner's cost signal. All zero
    /// unless `measure` is set.
    epoch_cost: Vec<f64>,
    /// Rows shipped per link this epoch (normalizes the cost signal).
    epoch_rows: Vec<usize>,
}

impl AsyncShards {
    /// Wrap pre-opened shard links into the coordinator backend.
    /// `sizes[w]` must match the local unit count link `w` was opened
    /// with; `measure` enables the per-link cost clocks an elastic
    /// coordinator plans from.
    fn new(
        links: Vec<Box<dyn ShardTransport>>,
        sizes: &[usize],
        d: usize,
        transport: &'static str,
        measure: bool,
    ) -> AsyncShards {
        assert_eq!(links.len(), sizes.len());
        // Seeded from the allocation-free estimate; the first worker
        // report overwrites these with the live values.
        let shard_state_bytes = sizes
            .iter()
            .map(|&s| PairBalance::initial_state_bytes(s, d))
            .collect();
        AsyncShards {
            staged: (0..links.len()).map(|_| None).collect(),
            dead: vec![false; links.len()],
            local_orders: sizes.iter().map(|&s| (0..s).collect()).collect(),
            measure,
            epoch_cost: vec![0.0; links.len()],
            epoch_rows: vec![0; links.len()],
            links,
            transport,
            shard_state_bytes,
        }
    }

    /// Gather this block's rows per owning shard and ship one scratch
    /// block per shard touched. Blocking happens only at the link's
    /// backpressure point (full queue / full socket buffer); dead shards
    /// are skipped until the epoch boundary re-raises their failure.
    fn observe(&mut self, range: Range<usize>, block: &GradBlock, route: &[u32]) {
        for (i, row) in block.iter_rows().enumerate() {
            let w = route[range.start + i] as usize;
            if self.dead[w] {
                continue;
            }
            if self.staged[w].is_none() {
                let got = if self.measure {
                    let sw = Stopwatch::start();
                    let got = self.links[w].acquire();
                    self.epoch_cost[w] += sw.secs();
                    got
                } else {
                    self.links[w].acquire()
                };
                match got {
                    Some(scratch) => self.staged[w] = Some(scratch),
                    None => {
                        self.dead[w] = true;
                        continue;
                    }
                }
            }
            if let Some(scratch) = self.staged[w].as_mut() {
                scratch.push_row(row);
            }
        }
        for (w, slot) in self.staged.iter_mut().enumerate() {
            if let Some(scratch) = slot.take() {
                let rows = scratch.rows();
                let ok = if self.measure {
                    let sw = Stopwatch::start();
                    let ok = self.links[w].send_block(scratch);
                    self.epoch_cost[w] += sw.secs();
                    ok
                } else {
                    self.links[w].send_block(scratch)
                };
                if ok {
                    self.epoch_rows[w] += rows;
                } else {
                    self.dead[w] = true;
                }
            }
        }
    }

    /// The epoch-boundary barrier: signal every link, then collect every
    /// report. Signalling first keeps the drains overlapped — no worker
    /// waits on another's `epoch_end`. A failed link surfaces here: the
    /// channel transport re-raises the worker's panic payload, a socket
    /// transport's typed error is raised as a coordinator panic — either
    /// way the failure lands at the boundary, exactly like a worker
    /// panic, and the coordinator's cached orders are left untouched.
    ///
    /// With `tolerate_failure` (the elastic coordinator), a link whose
    /// report fails with a *typed* transport error is recorded instead
    /// of panicking: the returned vector holds `Some(error)` per lost
    /// shard so the caller can re-plan over the survivors. (An
    /// in-process channel worker panic still re-raises either way.)
    fn drain_epoch(
        &mut self,
        tolerate_failure: bool,
    ) -> Vec<Option<String>> {
        for link in self.links.iter_mut() {
            // A send failure is surfaced by the recv below.
            let _ = link.end_epoch();
        }
        let mut outcomes = Vec::with_capacity(self.links.len());
        for (w, link) in self.links.iter_mut().enumerate() {
            match link.recv_report() {
                Ok(report) => {
                    self.local_orders[w] = report.order;
                    self.shard_state_bytes[w] = report.state_bytes;
                    outcomes.push(None);
                }
                Err(e) if tolerate_failure => {
                    outcomes.push(Some(e.to_string()));
                }
                Err(e) => panic!(
                    "shard {w} ({} transport) failed mid-epoch: {e}",
                    self.transport
                ),
            }
        }
        outcomes
    }

    /// Take (and reset) this epoch's per-shard cost/row counters — the
    /// elastic planner's input.
    fn take_epoch_costs(&mut self) -> (Vec<f64>, Vec<usize>) {
        let costs = std::mem::replace(
            &mut self.epoch_cost,
            vec![0.0; self.links.len()],
        );
        let rows = std::mem::replace(
            &mut self.epoch_rows,
            vec![0; self.links.len()],
        );
        (costs, rows)
    }

    /// Per-shard link counters (stalls, bytes moved each way) for the
    /// current links; the coordinator folds in retired-link counters.
    fn stats(&self) -> TransportStats {
        TransportStats {
            transport: self.transport,
            per_shard: self.links.iter().map(|l| l.stats()).collect(),
            retired: LinkStats::default(),
        }
    }
}

/// Where the W shard balancers run and how observed rows reach them.
enum Backend {
    /// Caller-thread dispatch, one zero-copy row at a time.
    Strided(Vec<PairBalance>),
    /// Caller-thread dispatch after gathering each shard's strided rows
    /// into a reusable scratch block (one copy, batched balancing).
    Gathered {
        shards: Vec<PairBalance>,
        scratch: Vec<ScratchBlock>,
    },
    /// Transported dispatch: shard balancers behind [`ShardTransport`]
    /// links (worker threads over channels, or TCP peers).
    Async(AsyncShards),
}

/// The elastic half of a transported coordinator: where next-epoch
/// weights come from and how fresh links are opened after a re-plan.
struct ElasticState {
    source: WeightSource,
    relink: Relink,
    /// Epoch boundaries crossed so far (indexes `Schedule` entries).
    boundaries: usize,
}

/// CD-GraB's sharded coordinator: W [`PairBalance`] workers over
/// disjoint contiguous unit ranges, merged round-robin at each epoch
/// boundary. See the module docs for the dispatch backends and the
/// elastic topology layer.
pub struct ShardedOrder {
    backend: Backend,
    /// The current shard layout (weights, sizes, base offsets,
    /// re-plan generation).
    topology: Topology,
    /// Entry `e` is the plan that produced epoch `e`'s merged order
    /// (recorded for replay; contract 6). After E completed epochs the
    /// log holds E+1 entries: the trailing one is the plan behind the
    /// *next* epoch's order (the trainer's `final_order`).
    log: Vec<Topology>,
    /// Elastic re-planning state; `None` = static topology.
    elastic: Option<ElasticState>,
    /// Aggregate link counters of every set of links retired by an
    /// elastic re-plan, so `transport_stats` stays cumulative over the
    /// whole run (always zero for static topologies).
    retired_stats: LinkStats,
    n: usize,
    /// Gradient dimension (needed to rebuild shard state at a re-plan).
    d: usize,
    /// Merged epoch order (global unit ids), rebuilt lazily per epoch.
    merged: Vec<usize>,
    /// Epoch position -> owning shard.
    route: Vec<u32>,
    /// Per-shard local observe cursors (inline backends only; async
    /// workers track their own local positions).
    cursors: Vec<usize>,
    /// Merged order needs rebuilding (new epoch).
    dirty: bool,
    observed: usize,
}

impl ShardedOrder {
    /// Synchronous strided coordinator: split `n` units of dimension `d`
    /// across `num_shards` equal-weight contiguous ranges (sizes differ
    /// by at most one; shards may be empty when `num_shards > n`) and
    /// forward observed rows to the owning balancer one at a time,
    /// zero-copy, on the caller's thread.
    pub fn new(n: usize, d: usize, num_shards: usize) -> ShardedOrder {
        ShardedOrder::new_weighted(n, d, &vec![1; num_shards])
    }

    /// [`ShardedOrder::new`] over a weighted topology: shard sizes
    /// proportional to integer `weights` (largest-remainder
    /// apportionment, zero-weight shards clamped to one unit while
    /// units last).
    pub fn new_weighted(
        n: usize,
        d: usize,
        weights: &[u64],
    ) -> ShardedOrder {
        let topology = Topology::plan(n, 0, weights);
        let shards = topology
            .sizes
            .iter()
            .map(|&s| PairBalance::new(s, d))
            .collect();
        ShardedOrder::assemble(
            Backend::Strided(shards),
            topology,
            n,
            d,
            None,
        )
    }

    /// [`ShardedOrder::new`] with an explicit kernel tier for every
    /// shard balancer (determinism contract 7; the default
    /// constructors snapshot [`crate::tensor::default_kernel`]
    /// instead).
    pub fn new_with_kernel(
        n: usize,
        d: usize,
        num_shards: usize,
        kernel: Kernel,
    ) -> ShardedOrder {
        let topology = Topology::plan(n, 0, &vec![1; num_shards]);
        let shards = topology
            .sizes
            .iter()
            .map(|&s| PairBalance::with_kernel(s, d, kernel))
            .collect();
        ShardedOrder::assemble(
            Backend::Strided(shards),
            topology,
            n,
            d,
            None,
        )
    }

    /// Synchronous gathered coordinator: like [`ShardedOrder::new`], but
    /// each shard's strided rows are copied into a reusable scratch
    /// block and balanced as one batched call — the copy-for-batching
    /// trade measured in `benches/ordering_overhead.rs`.
    pub fn new_gathered(
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> ShardedOrder {
        ShardedOrder::new_gathered_weighted(n, d, &vec![1; num_shards])
    }

    /// [`ShardedOrder::new_gathered`] over a weighted topology.
    pub fn new_gathered_weighted(
        n: usize,
        d: usize,
        weights: &[u64],
    ) -> ShardedOrder {
        let topology = Topology::plan(n, 0, weights);
        let shards: Vec<PairBalance> = topology
            .sizes
            .iter()
            .map(|&s| PairBalance::new(s, d))
            .collect();
        let scratch = (0..topology.num_shards())
            .map(|_| ScratchBlock::new(d))
            .collect();
        ShardedOrder::assemble(
            Backend::Gathered { shards, scratch },
            topology,
            n,
            d,
            None,
        )
    }

    /// [`ShardedOrder::new_gathered`] with an explicit kernel tier
    /// (determinism contract 7).
    pub fn new_gathered_with_kernel(
        n: usize,
        d: usize,
        num_shards: usize,
        kernel: Kernel,
    ) -> ShardedOrder {
        let topology = Topology::plan(n, 0, &vec![1; num_shards]);
        let shards: Vec<PairBalance> = topology
            .sizes
            .iter()
            .map(|&s| PairBalance::with_kernel(s, d, kernel))
            .collect();
        let scratch = (0..topology.num_shards())
            .map(|_| ScratchBlock::new(d))
            .collect();
        ShardedOrder::assemble(
            Backend::Gathered { shards, scratch },
            topology,
            n,
            d,
            None,
        )
    }

    /// Asynchronous coordinator over the in-process channel transport:
    /// each shard balancer runs on its own worker thread behind a
    /// bounded block queue holding at most `queue_depth` in-flight
    /// blocks. `observe_block` becomes gather + non-blocking enqueue (it
    /// only waits when a shard's queue is full); the epoch-boundary
    /// merge in [`OrderPolicy::epoch_end`] is the only join. Produces
    /// exactly the same epoch orders as the synchronous backends for the
    /// same gradient stream.
    pub fn new_async(
        n: usize,
        d: usize,
        num_shards: usize,
        queue_depth: usize,
    ) -> ShardedOrder {
        ShardedOrder::new_async_weighted(
            n,
            d,
            &vec![1; num_shards],
            queue_depth,
        )
    }

    /// [`ShardedOrder::new_async`] over a weighted topology (static:
    /// the weights never change).
    pub fn new_async_weighted(
        n: usize,
        d: usize,
        weights: &[u64],
        queue_depth: usize,
    ) -> ShardedOrder {
        assert!(d > 0, "async shards need a positive dimension");
        let topology = Topology::plan(n, 0, weights);
        let links =
            spawn_channel_shards(&topology.sizes, d, queue_depth);
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            "channel",
            false,
        );
        ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            None,
        )
    }

    /// [`ShardedOrder::new_async`] with an explicit kernel tier: each
    /// worker thread's balancer snapshots `kernel` instead of the
    /// process default (determinism contract 7).
    pub fn new_async_with_kernel(
        n: usize,
        d: usize,
        num_shards: usize,
        queue_depth: usize,
        kernel: Kernel,
    ) -> ShardedOrder {
        assert!(d > 0, "async shards need a positive dimension");
        let topology = Topology::plan(n, 0, &vec![1; num_shards]);
        let links = spawn_channel_shards_with_kernel(
            &topology.sizes,
            d,
            queue_depth,
            kernel,
        );
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            "channel",
            false,
        );
        ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            None,
        )
    }

    /// Elastic coordinator over the channel transport: starts from
    /// `weights`, measures per-link cost each epoch, and re-plans the
    /// topology (fresh worker threads) when the measured skew is
    /// sustained or a link fails. See the module docs and
    /// `docs/determinism.md` contract 6.
    pub fn new_elastic(
        n: usize,
        d: usize,
        weights: &[u64],
        queue_depth: usize,
    ) -> ShardedOrder {
        let planner = ElasticPlanner::new(weights.len());
        ShardedOrder::new_channel_elastic(
            n,
            d,
            weights,
            queue_depth,
            WeightSource::Measured(planner),
        )
    }

    /// Elastic coordinator over the channel transport following a
    /// pinned per-epoch weight schedule (`schedule[e]` = weights for
    /// epoch `e`; the last entry repeats). This is the replay mode: a
    /// recorded elastic run — including mid-run shard-count changes —
    /// re-executes bit-for-bit from its topology log.
    pub fn new_scheduled(
        n: usize,
        d: usize,
        schedule: &[Vec<u64>],
        queue_depth: usize,
    ) -> ShardedOrder {
        assert!(!schedule.is_empty(), "empty topology schedule");
        ShardedOrder::new_channel_elastic(
            n,
            d,
            &schedule[0],
            queue_depth,
            WeightSource::Schedule(schedule.to_vec()),
        )
    }

    fn new_channel_elastic(
        n: usize,
        d: usize,
        weights: &[u64],
        queue_depth: usize,
        source: WeightSource,
    ) -> ShardedOrder {
        assert!(d > 0, "async shards need a positive dimension");
        let topology = Topology::plan(n, 0, weights);
        let links =
            spawn_channel_shards(&topology.sizes, d, queue_depth);
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            "channel",
            true,
        );
        let relink: Relink = Box::new(move |sizes, _generation| {
            Ok(spawn_channel_shards(sizes, d, queue_depth))
        });
        ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            Some(ElasticState { source, relink, boundaries: 0 }),
        )
    }

    /// TCP coordinator with in-process loopback workers: spawn a
    /// listener plus one worker thread per shard inside this process,
    /// then run the full socket protocol (frames, checksums, handshake)
    /// over 127.0.0.1. Bit-equal to every other backend; used by tests,
    /// benches, and `--transport tcp` without `--connect`.
    pub fn new_tcp_loopback(
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> crate::Result<ShardedOrder> {
        ShardedOrder::new_tcp_loopback_weighted(
            n,
            d,
            &vec![1; num_shards],
        )
    }

    /// [`ShardedOrder::new_tcp_loopback`] over a weighted topology.
    pub fn new_tcp_loopback_weighted(
        n: usize,
        d: usize,
        weights: &[u64],
    ) -> crate::Result<ShardedOrder> {
        ShardedOrder::tcp_loopback_inner(n, d, weights, None)
    }

    /// Elastic TCP coordinator with in-process loopback workers: a
    /// re-plan spawns a fresh loopback worker pool and re-handshakes
    /// (fresh `Hello`s at the bumped generation).
    pub fn new_tcp_loopback_elastic(
        n: usize,
        d: usize,
        weights: &[u64],
    ) -> crate::Result<ShardedOrder> {
        let planner = ElasticPlanner::new(weights.len());
        ShardedOrder::tcp_loopback_inner(
            n,
            d,
            weights,
            Some(WeightSource::Measured(planner)),
        )
    }

    /// Elastic TCP loopback coordinator on a pinned per-epoch weight
    /// schedule (see [`ShardedOrder::new_scheduled`]).
    pub fn new_tcp_loopback_scheduled(
        n: usize,
        d: usize,
        schedule: &[Vec<u64>],
    ) -> crate::Result<ShardedOrder> {
        anyhow::ensure!(!schedule.is_empty(), "empty topology schedule");
        ShardedOrder::tcp_loopback_inner(
            n,
            d,
            &schedule[0],
            Some(WeightSource::Schedule(schedule.to_vec())),
        )
    }

    fn tcp_loopback_inner(
        n: usize,
        d: usize,
        weights: &[u64],
        source: Option<WeightSource>,
    ) -> crate::Result<ShardedOrder> {
        anyhow::ensure!(d > 0, "tcp shards need a positive dimension");
        let topology = Topology::plan(n, 0, weights);
        let addr = tcp::spawn_loopback(topology.num_shards())?;
        // Loopback workers answer in microseconds; the default timeout
        // only guards against a wedged worker thread.
        let links = tcp::connect_shards(
            addr,
            &topology.sizes,
            d,
            0,
            tcp::default_read_timeout(),
        )?;
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            "tcp",
            source.is_some(),
        );
        let elastic = source.map(|source| {
            // Each re-plan gets a fresh loopback worker pool — the
            // in-process analogue of re-handshaking a worker server.
            let relink: Relink = Box::new(move |sizes, generation| {
                let addr = tcp::spawn_loopback(sizes.len())
                    .map_err(crate::ordering::transport::TransportError::Io)?;
                tcp::connect_shards(
                    addr,
                    sizes,
                    d,
                    generation,
                    tcp::default_read_timeout(),
                )
            });
            ElasticState { source, relink, boundaries: 0 }
        });
        Ok(ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            elastic,
        ))
    }

    /// TCP coordinator against a remote worker server (`exp cdgrab
    /// --listen` in another process): dial `addr` once per shard and
    /// drive the same socket protocol as the loopback constructor.
    pub fn new_tcp_connect(
        addr: &str,
        n: usize,
        d: usize,
        num_shards: usize,
    ) -> crate::Result<ShardedOrder> {
        ShardedOrder::new_tcp_connect_weighted(
            &[addr.to_string()],
            n,
            d,
            &vec![1; num_shards],
            tcp::default_read_timeout(),
        )
    }

    /// TCP coordinator against a pool of remote worker servers: shard
    /// `w` dials `addrs[w % addrs.len()]` (falling through the list on
    /// failure), over a weighted topology. `read_timeout` bounds every
    /// per-frame wait on a worker socket; an expiry surfaces as
    /// [`crate::ordering::transport::TransportError::Timeout`] at the
    /// epoch boundary.
    pub fn new_tcp_connect_weighted(
        addrs: &[String],
        n: usize,
        d: usize,
        weights: &[u64],
        read_timeout: std::time::Duration,
    ) -> crate::Result<ShardedOrder> {
        ShardedOrder::tcp_connect_inner(
            addrs,
            n,
            d,
            weights,
            None,
            read_timeout,
        )
    }

    /// Elastic TCP coordinator against a pool of remote worker servers:
    /// a shard whose server dies mid-run surfaces at the epoch
    /// boundary, and the next epoch is re-planned over the surviving
    /// shards — the fresh `Hello`s land on whichever servers still
    /// accept connections.
    pub fn new_tcp_connect_elastic(
        addrs: &[String],
        n: usize,
        d: usize,
        weights: &[u64],
        read_timeout: std::time::Duration,
    ) -> crate::Result<ShardedOrder> {
        let planner = ElasticPlanner::new(weights.len());
        ShardedOrder::tcp_connect_inner(
            addrs,
            n,
            d,
            weights,
            Some(WeightSource::Measured(planner)),
            read_timeout,
        )
    }

    fn tcp_connect_inner(
        addrs: &[String],
        n: usize,
        d: usize,
        weights: &[u64],
        source: Option<WeightSource>,
        read_timeout: std::time::Duration,
    ) -> crate::Result<ShardedOrder> {
        anyhow::ensure!(d > 0, "tcp shards need a positive dimension");
        anyhow::ensure!(!addrs.is_empty(), "need a worker address");
        let topology = Topology::plan(n, 0, weights);
        let links = tcp::connect_shards_multi(
            addrs,
            &topology.sizes,
            d,
            0,
            read_timeout,
        )?;
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            "tcp",
            source.is_some(),
        );
        let elastic = source.map(|source| {
            let addrs = addrs.to_vec();
            let relink: Relink = Box::new(move |sizes, generation| {
                tcp::connect_shards_multi(
                    &addrs,
                    sizes,
                    d,
                    generation,
                    read_timeout,
                )
            });
            ElasticState { source, relink, boundaries: 0 }
        });
        Ok(ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            elastic,
        ))
    }

    /// Assemble a coordinator from pre-opened [`ShardTransport`] links
    /// — the composition point the public constructors build on, and
    /// the hook for tests that wrap links (fault injection). `links`
    /// must have one entry per `topology` shard, opened with the
    /// matching local sizes; `elastic` enables boundary re-planning.
    pub fn from_links(
        n: usize,
        d: usize,
        topology: Topology,
        links: Vec<Box<dyn ShardTransport>>,
        transport: &'static str,
        elastic: Option<(WeightSource, Relink)>,
    ) -> ShardedOrder {
        assert_eq!(links.len(), topology.num_shards());
        assert_eq!(topology.sizes.iter().sum::<usize>(), n);
        let shards = AsyncShards::new(
            links,
            &topology.sizes,
            d,
            transport,
            elastic.is_some(),
        );
        ShardedOrder::assemble(
            Backend::Async(shards),
            topology,
            n,
            d,
            elastic.map(|(source, relink)| ElasticState {
                source,
                relink,
                boundaries: 0,
            }),
        )
    }

    fn assemble(
        backend: Backend,
        topology: Topology,
        n: usize,
        d: usize,
        elastic: Option<ElasticState>,
    ) -> ShardedOrder {
        if elastic.is_some() {
            assert!(
                matches!(backend, Backend::Async(_)),
                "elastic topologies need a transported backend"
            );
        }
        let num_shards = topology.num_shards();
        ShardedOrder {
            backend,
            log: vec![topology.clone()],
            topology,
            elastic,
            retired_stats: LinkStats::default(),
            n,
            d,
            merged: vec![0; n],
            route: vec![0; n],
            cursors: vec![0; num_shards],
            dirty: true,
            observed: 0,
        }
    }

    /// Number of shard balancers (CD-GraB's W) in the current plan.
    pub fn num_shards(&self) -> usize {
        self.topology.num_shards()
    }

    /// The current [`Topology`] plan.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Per-epoch topology plans: entry `e` produced epoch `e`'s merged
    /// order, and after E completed epochs a trailing E+1-th entry
    /// records the plan behind the *next* (not yet run) epoch's order.
    /// Static runs repeat one plan; elastic runs record every re-plan
    /// (replay input; see `docs/determinism.md` contract 6).
    pub fn topology_log(&self) -> &[Topology] {
        &self.log
    }

    /// Whether this coordinator re-plans its topology at epoch
    /// boundaries.
    pub fn is_elastic(&self) -> bool {
        self.elastic.is_some()
    }

    /// Whether this coordinator dispatches through a [`ShardTransport`]
    /// (worker threads or sockets) rather than inline.
    pub fn is_async(&self) -> bool {
        matches!(self.backend, Backend::Async(_))
    }

    /// Total backpressure events (acquire waits on a full shard queue)
    /// since construction. Always 0 for the synchronous backends and
    /// for TCP links (the kernel socket buffer is their backpressure).
    pub fn queue_stalls(&self) -> u64 {
        self.transport_stats().total().stalls
    }

    /// Aggregated per-shard link counters — stalls and bytes moved each
    /// way — comparable across the sync, channel, and tcp dispatch
    /// paths (the synchronous backends report one all-zero entry per
    /// shard).
    pub fn transport_stats(&self) -> TransportStats {
        match &self.backend {
            Backend::Async(shards) => {
                let mut stats = shards.stats();
                stats.retired = self.retired_stats;
                stats
            }
            _ => TransportStats {
                transport: "inline",
                per_shard: vec![LinkStats::default(); self.num_shards()],
                retired: LinkStats::default(),
            },
        }
    }

    /// Rebuild the merged order + routing table from the shard-local
    /// orders (queried inline, or cached from the last async drain).
    fn rebuild(&mut self, epoch: usize) {
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                let locals: Vec<&[usize]> = shards
                    .iter_mut()
                    .map(|s| s.epoch_order(epoch))
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.topology.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
            Backend::Async(shards) => {
                let locals: Vec<&[usize]> = shards
                    .local_orders
                    .iter()
                    .map(|o| o.as_slice())
                    .collect();
                merge_round_robin(
                    &locals,
                    &self.topology.bases,
                    &mut self.merged,
                    &mut self.route,
                );
            }
        }
        for c in self.cursors.iter_mut() {
            *c = 0;
        }
    }

    /// The elastic epoch-boundary step, after the drain: fold the
    /// epoch's link observations into the next plan and re-plan (fresh
    /// split + fresh links at a bumped generation) when the plan's
    /// sizes changed or a link was lost. Panics only when no shard
    /// survives or the re-link itself fails.
    fn replan_after_drain(&mut self, failures: &[Option<String>]) {
        let Backend::Async(shards) = &mut self.backend else {
            unreachable!("elastic coordinators are transported");
        };
        let el = self
            .elastic
            .as_mut()
            .expect("replan_after_drain needs elastic state");
        el.boundaries += 1;
        let lost = failures.iter().any(|f| f.is_some());
        for (w, f) in failures.iter().enumerate() {
            if let Some(why) = f {
                eprintln!(
                    "[elastic] shard {w}/{} lost at epoch boundary \
                     ({why}); re-planning the next epoch",
                    failures.len()
                );
            }
        }
        let alive: Vec<bool> =
            failures.iter().map(|f| f.is_none()).collect();
        assert!(
            alive.iter().any(|&a| a),
            "all {} shard links failed mid-epoch ({} transport)",
            failures.len(),
            shards.transport
        );
        let (costs, rows) = shards.take_epoch_costs();
        let next_weights: Vec<u64> = match &mut el.source {
            WeightSource::Measured(planner) => planner.plan(
                &costs,
                &rows,
                &alive,
                &self.topology.weights,
            ),
            WeightSource::Schedule(schedule) => {
                let idx = el.boundaries.min(schedule.len() - 1);
                schedule[idx].clone()
            }
        };
        let next = Topology::plan(
            self.n,
            self.topology.generation,
            &next_weights,
        );
        if lost || next.sizes != self.topology.sizes {
            let generation = self.topology.generation + 1;
            let links = match (el.relink)(&next.sizes, generation) {
                Ok(links) => links,
                Err(e) => panic!(
                    "elastic re-plan failed to open {} shard links \
                     (generation {generation}): {e}",
                    next.sizes.len()
                ),
            };
            let transport = shards.transport;
            // Retire the old links' counters so transport stats stay
            // cumulative across the re-plan.
            self.retired_stats =
                self.retired_stats.merged(shards.stats().total());
            *shards = AsyncShards::new(
                links,
                &next.sizes,
                self.d,
                transport,
                true,
            );
            self.cursors = vec![0; next.sizes.len()];
            self.topology = Topology { generation, ..next };
            eprintln!(
                "[elastic] re-planned to {} shards (weights {}, \
                 generation {})",
                self.topology.num_shards(),
                self.topology.weights_label(),
                self.topology.generation
            );
        } else {
            // Weights moved inside the same sizes (or not at all): no
            // re-handshake, no state reset — record the weights only.
            self.topology.weights = next_weights;
        }
    }

    /// Seed every shard balancer's next local order (checkpoint
    /// resume, between epochs): inline balancers adopt the order
    /// directly, transported ones are seeded through their link (a
    /// `Seed` queue message or TCP frame). Returns `false` if any
    /// shard refuses (wrong length, dead link, or a transport without
    /// seeding support).
    fn seed_locals(&mut self, locals: &[Vec<usize>]) -> bool {
        if locals.len() != self.topology.num_shards() {
            return false;
        }
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                for (s, l) in shards.iter_mut().zip(locals) {
                    if !s.restore_order(l) {
                        return false;
                    }
                }
            }
            Backend::Async(shards) => {
                for (w, l) in locals.iter().enumerate() {
                    if !crate::ordering::is_permutation_of(
                        l,
                        self.topology.sizes[w],
                    ) {
                        return false;
                    }
                    if !shards.links[w].seed_order(l) {
                        return false;
                    }
                    shards.local_orders[w] = l.clone();
                }
            }
        }
        self.dirty = true;
        true
    }

    /// Test-only: make shard `w`'s worker panic on its next dequeue
    /// (async backend only), to exercise boundary panic propagation.
    #[cfg(test)]
    fn poison_shard(&mut self, w: usize) {
        match &mut self.backend {
            Backend::Async(shards) => shards.links[w].poison(),
            _ => panic!("poison_shard needs the async backend"),
        }
    }
}

/// Append a length-prefixed `u64` vector (topology weights).
fn put_u64_vec(out: &mut Vec<u8>, v: &[u64]) {
    crate::util::ser::put_u64(out, v.len() as u64);
    for &x in v {
        crate::util::ser::put_u64(out, x);
    }
}

fn read_u64_vec(
    r: &mut crate::util::ser::ByteReader,
    max: usize,
) -> Result<Vec<u64>, crate::util::ser::WireError> {
    let n = r.len(max)?;
    (0..n).map(|_| r.u64()).collect()
}

impl OrderPolicy for ShardedOrder {
    fn name(&self) -> &'static str {
        if self.elastic.is_some() {
            return "cd-grab-elastic";
        }
        match &self.backend {
            Backend::Async(shards) => match shards.transport {
                "tcp" => "cd-grab-tcp",
                _ => "cd-grab-async",
            },
            _ => "cd-grab",
        }
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.dirty {
            self.rebuild(epoch);
            self.dirty = false;
        }
        &self.merged
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        debug_assert!(!self.dirty, "observe before epoch_order");
        match &mut self.backend {
            // Degenerate inline coordinator (W = 1): local positions ==
            // global positions, forward the whole block untouched so it
            // costs exactly what unsharded PairBalance costs. (The
            // async backend still gathers at W = 1 — the queue hand-off
            // forces the copy either way.)
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. }
                if shards.len() == 1 =>
            {
                let q = self.cursors[0];
                self.cursors[0] += block.rows();
                shards[0].observe_block(q..q + block.rows(), block);
            }
            Backend::Strided(shards) => {
                // De-interleave rows to their owning shard at its next
                // local position (local positions arrive in order by
                // construction of the round-robin merge).
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    let q = self.cursors[w];
                    self.cursors[w] += 1;
                    shards[w].observe_block(
                        q..q + 1,
                        &GradBlock::new(row, block.dim()),
                    );
                }
            }
            Backend::Gathered { shards, scratch } => {
                for (i, row) in block.iter_rows().enumerate() {
                    let w = self.route[range.start + i] as usize;
                    scratch[w].push_row(row);
                }
                for (w, buf) in scratch.iter_mut().enumerate() {
                    let rows = buf.rows();
                    if rows == 0 {
                        continue;
                    }
                    let q = self.cursors[w];
                    self.cursors[w] += rows;
                    shards[w].observe_block(
                        q..q + rows,
                        &buf.as_grad_block(),
                    );
                    buf.clear();
                }
            }
            Backend::Async(shards) => {
                shards.observe(range, block, &self.route);
            }
        }
        self.observed += block.rows();
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.observed, self.n,
            "ShardedOrder epoch_end before observing all {} units", self.n
        );
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                for s in shards.iter_mut() {
                    s.epoch_end();
                }
            }
            Backend::Async(shards) => {
                let failures =
                    shards.drain_epoch(self.elastic.is_some());
                if self.elastic.is_some() {
                    self.replan_after_drain(&failures);
                }
            }
        }
        self.observed = 0;
        self.dirty = true;
        // Record the plan that will produce the NEXT epoch's order.
        self.log.push(self.topology.clone());
    }

    fn state_bytes(&self) -> usize {
        let shard_bytes = match &self.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
            }
            Backend::Gathered { shards, scratch } => {
                shards.iter().map(|s| s.state_bytes()).sum::<usize>()
                    + scratch
                        .iter()
                        .map(|b| b.capacity_bytes())
                        .sum::<usize>()
            }
            Backend::Async(shards) => {
                // Worker-side balancer state (from the latest reports)
                // plus the coordinator-side link buffers (scratch
                // pools, frame buffers) — keeps Table 1 memory numbers
                // comparable across dispatch paths.
                shards.shard_state_bytes.iter().sum::<usize>()
                    + shards
                        .links
                        .iter()
                        .map(|l| l.buffer_bytes())
                        .sum::<usize>()
            }
        };
        shard_bytes
            + self.merged.len() * std::mem::size_of::<usize>()
            + self.route.len() * std::mem::size_of::<u32>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn transport_stats(&self) -> Option<TransportStats> {
        Some(ShardedOrder::transport_stats(self))
    }

    fn topology_log(&self) -> Option<&[Topology]> {
        Some(ShardedOrder::topology_log(self))
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // Epoch-boundary coordinator state: the current plan, the full
        // per-epoch topology log (replay input, contract 6), the
        // elastic schedule position, and each shard's next local order.
        // Sizes/bases are recomputed from (n, weights) on restore —
        // `Topology::plan` is pure — so only weights are serialized.
        // The measured elastic planner's EWMA rides along as an
        // optional trailer (absent from pre-trailer snapshots): the
        // costs it folded are wall-clock and not replayable, but
        // *losing* them made a resumed elastic run re-plan from a
        // cold planner — one epoch of forgotten skew history per
        // restart, drifting from the uninterrupted run's plans.
        let mut out = Vec::new();
        crate::util::ser::put_u64(&mut out, self.n as u64);
        crate::util::ser::put_u64(&mut out, self.d as u64);
        crate::util::ser::put_u64(&mut out, self.topology.generation);
        put_u64_vec(&mut out, &self.topology.weights);
        crate::util::ser::put_u64(&mut out, self.log.len() as u64);
        for t in &self.log {
            crate::util::ser::put_u64(&mut out, t.generation);
            put_u64_vec(&mut out, &t.weights);
        }
        let boundaries = self
            .elastic
            .as_ref()
            .map(|el| el.boundaries as u64)
            .unwrap_or(0);
        crate::util::ser::put_u64(&mut out, boundaries);
        let num_shards = self.topology.num_shards();
        crate::util::ser::put_u64(&mut out, num_shards as u64);
        match &mut self.backend {
            Backend::Strided(shards)
            | Backend::Gathered { shards, .. } => {
                for s in shards.iter_mut() {
                    crate::util::ser::put_usize_slice(
                        &mut out,
                        s.epoch_order(0),
                    );
                }
            }
            Backend::Async(shards) => {
                for o in &shards.local_orders {
                    crate::util::ser::put_usize_slice(&mut out, o);
                }
            }
        }
        // Optional trailer: the measured planner's EWMA, one f64 per
        // live shard. Scheduled/static coordinators write nothing here
        // and older snapshots end above — the reader keys on
        // `remaining()`.
        if let Some(el) = &self.elastic {
            if let WeightSource::Measured(p) = &el.source {
                let ewma = p.ewma();
                crate::util::ser::put_u64(&mut out, ewma.len() as u64);
                for &e in ewma {
                    crate::util::ser::put_f64(&mut out, e);
                }
            }
        }
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        const MAX_SHARDS: usize = 1 << 16;
        const MAX_EPOCHS: usize = 1 << 20;
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let n = r.u64()? as usize;
            let d = r.u64()? as usize;
            let generation = r.u64()?;
            let weights = read_u64_vec(&mut r, MAX_SHARDS)?;
            let log_len = r.len(MAX_EPOCHS)?;
            let mut log = Vec::with_capacity(log_len);
            for _ in 0..log_len {
                let g = r.u64()?;
                let w = read_u64_vec(&mut r, MAX_SHARDS)?;
                log.push((g, w));
            }
            let boundaries = r.u64()? as usize;
            let num_shards = r.len(MAX_SHARDS)?;
            let mut locals = Vec::with_capacity(num_shards);
            for _ in 0..num_shards {
                locals.push(r.usize_slice(self.n)?);
            }
            // EWMA trailer (measured-elastic snapshots only; absent
            // from static/scheduled ones and from pre-trailer blobs).
            let ewma = if r.remaining() > 0 {
                let len = r.len(MAX_SHARDS)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(r.f64()?);
                }
                Some(v)
            } else {
                None
            };
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((
                n, d, generation, weights, log, boundaries, locals,
                ewma,
            ))
        })();
        let (n, d, generation, weights, log, boundaries, locals, ewma) =
            parse.map_err(|e| format!("sharded state: {e}"))?;
        if n != self.n || d != self.d {
            return Err(format!(
                "sharded state shape mismatch: snapshot n={n} d={d}, \
                 policy n={} d={}",
                self.n, self.d
            ));
        }
        if weights.is_empty() || weights.iter().all(|&w| w == 0) {
            return Err("sharded state has no usable weights".into());
        }
        let expected = Topology::plan(n, generation, &weights);
        if locals.len() != expected.num_shards() {
            return Err(format!(
                "sharded state has {} local orders for {} shards",
                locals.len(),
                expected.num_shards()
            ));
        }
        for (w, l) in locals.iter().enumerate() {
            if !crate::ordering::is_permutation_of(
                l,
                expected.sizes[w],
            ) {
                return Err(format!(
                    "shard {w} local order is not a permutation of \
                     0..{}",
                    expected.sizes[w]
                ));
            }
        }
        // Reconcile the live links with the snapshot's plan. A static
        // coordinator must already match (same config ⇒ same plan); an
        // elastic one re-links at the recorded sizes and generation —
        // the same re-handshake a mid-run re-plan performs.
        if expected.sizes != self.topology.sizes
            || expected.generation != self.topology.generation
        {
            let Some(el) = self.elastic.as_mut() else {
                return Err(format!(
                    "sharded state plan (sizes {:?}, generation {}) \
                     does not match the static topology (sizes {:?})",
                    expected.sizes,
                    expected.generation,
                    self.topology.sizes
                ));
            };
            let Backend::Async(shards) = &mut self.backend else {
                unreachable!("elastic coordinators are transported");
            };
            let links = (el.relink)(
                &expected.sizes,
                expected.generation,
            )
            .map_err(|e| {
                format!(
                    "sharded state re-link at generation {} failed: {e}",
                    expected.generation
                )
            })?;
            let transport = shards.transport;
            // Retire the old links' counters, exactly as a mid-run
            // re-plan does, so transport stats stay cumulative.
            self.retired_stats =
                self.retired_stats.merged(shards.stats().total());
            *shards = AsyncShards::new(
                links,
                &expected.sizes,
                self.d,
                transport,
                true,
            );
            self.cursors = vec![0; expected.sizes.len()];
        }
        if let Some(el) = self.elastic.as_mut() {
            el.boundaries = boundaries;
            if let WeightSource::Measured(p) = &mut el.source {
                // Rehydrate the planner from the snapshot's EWMA
                // trailer so a resumed elastic run re-plans from the
                // same smoothed cost history as the uninterrupted one.
                // A snapshot without a trailer (pre-trailer format, or
                // one written by a scheduled coordinator) falls back to
                // a cold planner at the restored shard count.
                *p = match ewma {
                    Some(e) if e.len() == expected.num_shards()
                        && e.iter().all(|x| x.is_finite() && *x >= 0.0) =>
                    {
                        ElasticPlanner::from_ewma(e)
                    }
                    Some(e) => {
                        return Err(format!(
                            "sharded state EWMA trailer has {} entries \
                             for {} shards (or non-finite costs)",
                            e.len(),
                            expected.num_shards()
                        ));
                    }
                    None => ElasticPlanner::new(expected.num_shards()),
                };
            }
        }
        self.topology = expected;
        self.log = log
            .into_iter()
            .map(|(g, w)| Topology::plan(self.n, g, &w))
            .collect();
        if !self.seed_locals(&locals) {
            return Err(
                "shard links refused the restored local orders \
                 (dead link or transport without seed support)"
                    .into(),
            );
        }
        self.observed = 0;
        Ok(())
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        // De-merge a global order back into per-shard locals by
        // replaying the round-robin pattern the merge used — a pure
        // function of the current plan's sizes. Any global id that
        // lands outside its round's shard range means the order was
        // not produced by this topology.
        if !crate::ordering::is_permutation_of(order, self.n) {
            return false;
        }
        let sizes = self.topology.sizes.clone();
        let bases = self.topology.bases.clone();
        let mut locals: Vec<Vec<usize>> =
            sizes.iter().map(|&s| Vec::with_capacity(s)).collect();
        let mut taken = vec![0usize; sizes.len()];
        let mut pos = 0;
        while pos < self.n {
            for w in 0..sizes.len() {
                if taken[w] < sizes[w] {
                    let g = order[pos];
                    let local = match g.checked_sub(bases[w]) {
                        Some(l) if l < sizes[w] => l,
                        _ => return false,
                    };
                    locals[w].push(local);
                    taken[w] += 1;
                    pos += 1;
                }
            }
        }
        self.seed_locals(&locals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::herding::herding_bound;
    use crate::util::prop::{self, assert_permutation, gen};
    use crate::util::rng::Rng;

    fn feed_epoch(
        p: &mut dyn OrderPolicy,
        vs: &[Vec<f32>],
        block: usize,
    ) {
        let mut flat = Vec::new();
        // Epoch 0 everywhere: every policy in this suite is
        // epoch-agnostic (sharded/pair orders depend only on the
        // observed gradient stream).
        crate::ordering::stream_static_epoch(p, 0, vs, &mut flat, block);
    }

    fn shard_sizes(s: &ShardedOrder) -> Vec<usize> {
        match &s.backend {
            Backend::Strided(shards) => {
                shards.iter().map(|p| p.len()).collect()
            }
            _ => panic!("expected strided backend"),
        }
    }

    #[test]
    fn shard_ranges_partition_units() {
        let s = ShardedOrder::new(10, 2, 4);
        assert_eq!(s.num_shards(), 4);
        assert_eq!(s.topology.bases, vec![0, 3, 6, 8]);
        assert_eq!(shard_sizes(&s), vec![3, 3, 2, 2]);
        assert_eq!(s.topology.sizes, vec![3, 3, 2, 2]);
        assert_eq!(s.topology.generation, 0);
    }

    #[test]
    fn weighted_ranges_follow_the_weights() {
        let s = ShardedOrder::new_weighted(60, 2, &[1, 1, 4]);
        assert_eq!(s.num_shards(), 3);
        assert_eq!(shard_sizes(&s), vec![10, 10, 40]);
        assert_eq!(s.topology.bases, vec![0, 10, 20]);
        assert_eq!(s.topology.weights_label(), "1:1:4");
    }

    #[test]
    fn first_epoch_interleaves_shards_round_robin() {
        let mut s = ShardedOrder::new(10, 2, 4);
        // Shard locals are identity on epoch 0, so the merge is the
        // lock-step interleave of [0,1,2], [3,4,5], [6,7], [8,9].
        assert_eq!(
            s.epoch_order(0),
            &[0, 3, 6, 8, 1, 4, 7, 9, 2, 5]
        );
    }

    #[test]
    fn sharded_order_is_always_a_permutation() {
        // W shards, random n/d/block sizes, every epoch's merged order
        // is a valid permutation of 0..n — for every backend, and for
        // weighted topologies too.
        prop::forall("sharded permutations", 16, |rng| {
            let n = 1 + rng.gen_range(96) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let w = 1 + rng.gen_range(8) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let weights: Vec<u64> =
                (0..w).map(|_| rng.gen_range(5)).collect();
            let weights = if weights.iter().all(|&x| x == 0) {
                vec![1; w]
            } else {
                weights
            };
            let vs = gen::vec_set(rng, n, d);
            let mut policies: Vec<ShardedOrder> = vec![
                ShardedOrder::new(n, d, w),
                ShardedOrder::new_gathered(n, d, w),
                ShardedOrder::new_async(n, d, w, 2),
                ShardedOrder::new_weighted(n, d, &weights),
                ShardedOrder::new_async_weighted(n, d, &weights, 2),
            ];
            for p in policies.iter_mut() {
                for _ in 0..3 {
                    assert_permutation(p.epoch_order(0))?;
                    feed_epoch(p, &vs, b);
                }
                assert_permutation(p.epoch_order(0))?;
            }
            Ok(())
        });
    }

    #[test]
    fn async_and_gathered_orders_match_strided_exactly() {
        // The ISSUE's acceptance property: for a fixed seed and
        // W in {1, 2, 4}, the async coordinator's epoch orders equal
        // the synchronous path's exactly across >= 3 epochs (and the
        // gathered backend agrees too), for random n/d/block/depth.
        prop::forall("async == sync sharded orders", 12, |rng| {
            let n = 1 + rng.gen_range(80) as usize;
            let d = 1 + rng.gen_range(6) as usize;
            let b = 1 + rng.gen_range(9) as usize;
            let depth = 1 + rng.gen_range(4) as usize;
            let vs = gen::vec_set(rng, n, d);
            for w in [1usize, 2, 4] {
                let mut strided = ShardedOrder::new(n, d, w);
                let mut gathered = ShardedOrder::new_gathered(n, d, w);
                let mut asynch = ShardedOrder::new_async(n, d, w, depth);
                for epoch in 0..3 {
                    feed_epoch(&mut strided, &vs, b);
                    feed_epoch(&mut gathered, &vs, b);
                    feed_epoch(&mut asynch, &vs, b);
                    let want = strided.epoch_order(0).to_vec();
                    if gathered.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "gathered != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b}"
                        ));
                    }
                    if asynch.epoch_order(0) != want.as_slice() {
                        return Err(format!(
                            "async != strided at w={w} epoch={epoch} \
                             n={n} d={d} b={b} depth={depth}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_backends_agree_with_each_other() {
        // Contract 6's static half at unit-test scale: the same skewed
        // weight vector produces identical orders across strided,
        // gathered, and async dispatch.
        prop::forall("weighted sharded backends agree", 8, |rng| {
            let n = 1 + rng.gen_range(70) as usize;
            let d = 1 + rng.gen_range(5) as usize;
            let b = 1 + rng.gen_range(8) as usize;
            let w = 1 + rng.gen_range(4) as usize;
            let weights: Vec<u64> =
                (0..w).map(|_| 1 + rng.gen_range(4)).collect();
            let vs = gen::vec_set(rng, n, d);
            let mut strided = ShardedOrder::new_weighted(n, d, &weights);
            let mut gathered =
                ShardedOrder::new_gathered_weighted(n, d, &weights);
            let mut asynch =
                ShardedOrder::new_async_weighted(n, d, &weights, 2);
            for epoch in 0..3 {
                feed_epoch(&mut strided, &vs, b);
                feed_epoch(&mut gathered, &vs, b);
                feed_epoch(&mut asynch, &vs, b);
                let want = strided.epoch_order(0).to_vec();
                assert_permutation(&want)?;
                if gathered.epoch_order(0) != want.as_slice()
                    || asynch.epoch_order(0) != want.as_slice()
                {
                    return Err(format!(
                        "weighted backends diverged at epoch={epoch} \
                         n={n} d={d} b={b} weights={weights:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn elastic_frozen_schedule_matches_static_weighted_exactly() {
        // Determinism contract 6 (frozen half) at unit-test scale: an
        // elastic coordinator whose schedule never changes is
        // bit-identical to the static weighted topology, across epochs
        // and W in {1, 2, 4}. The full cross-transport version lives in
        // tests/transport.rs.
        prop::forall("elastic frozen == static", 8, |rng| {
            let n = 1 + rng.gen_range(60) as usize;
            let d = 1 + rng.gen_range(5) as usize;
            let b = 1 + rng.gen_range(8) as usize;
            let vs = gen::vec_set(rng, n, d);
            for w in [1usize, 2, 4] {
                let weights: Vec<u64> =
                    (0..w).map(|_| 1 + rng.gen_range(3)).collect();
                let mut fixed =
                    ShardedOrder::new_async_weighted(n, d, &weights, 2);
                let schedule = vec![weights.clone()];
                let mut elastic =
                    ShardedOrder::new_scheduled(n, d, &schedule, 2);
                for epoch in 0..3 {
                    feed_epoch(&mut fixed, &vs, b);
                    feed_epoch(&mut elastic, &vs, b);
                    if elastic.epoch_order(0) != fixed.epoch_order(0) {
                        return Err(format!(
                            "frozen elastic != static at w={w} \
                             epoch={epoch} n={n} d={d} b={b} \
                             weights={weights:?}"
                        ));
                    }
                }
                // Frozen: no re-plan ever happened.
                assert_eq!(elastic.topology().generation, 0);
                assert!(elastic.is_elastic());
                assert_eq!(elastic.name(), "cd-grab-elastic");
            }
            Ok(())
        });
    }

    #[test]
    fn scheduled_shrink_replans_and_stays_valid() {
        // A mid-run W=4 -> 3 shrink via a pinned schedule: the next
        // epoch re-plans (generation bump, fresh identities) and every
        // epoch's order remains a valid permutation with all n units.
        let n = 37;
        let d = 3;
        let vs = gen::vec_set(&mut Rng::new(8), n, d);
        let schedule = vec![
            vec![1u64, 1, 1, 1],
            vec![1u64, 1, 1, 1],
            vec![1u64, 1, 1],
        ];
        let mut p = ShardedOrder::new_scheduled(n, d, &schedule, 2);
        for epoch in 0..4 {
            assert_permutation(p.epoch_order(0)).unwrap();
            feed_epoch(&mut p, &vs, 5);
            let log = ShardedOrder::topology_log(&p);
            assert_eq!(log.len(), epoch + 2);
        }
        assert_eq!(p.num_shards(), 3);
        assert_eq!(p.topology().generation, 1, "exactly one re-plan");
        let log = ShardedOrder::topology_log(&p);
        assert_eq!(log[0].num_shards(), 4);
        assert_eq!(log[1].num_shards(), 4);
        assert_eq!(log[2].num_shards(), 3);
        // Replay: the same schedule over the same stream reproduces
        // every epoch's order bit-for-bit.
        let mut replay = ShardedOrder::new_scheduled(n, d, &schedule, 2);
        let mut q = ShardedOrder::new_scheduled(n, d, &schedule, 2);
        for _ in 0..4 {
            feed_epoch(&mut replay, &vs, 5);
            feed_epoch(&mut q, &vs, 5);
            assert_eq!(replay.epoch_order(0), q.epoch_order(0));
        }
    }

    #[test]
    fn measured_elastic_smokes_and_logs_topologies() {
        // The measured planner on a healthy symmetric run: orders stay
        // valid permutations, and with the hysteresis band the plan
        // should not churn (weights may move, sizes should not — but
        // this is wall-clock dependent, so only validity is asserted).
        let n = 48;
        let d = 4;
        let vs = gen::vec_set(&mut Rng::new(12), n, d);
        let mut p = ShardedOrder::new_elastic(n, d, &[1, 1, 1], 2);
        for _ in 0..3 {
            assert_permutation(p.epoch_order(0)).unwrap();
            feed_epoch(&mut p, &vs, 6);
        }
        assert_permutation(p.epoch_order(0)).unwrap();
        assert_eq!(ShardedOrder::topology_log(&p).len(), 4);
        let stats = ShardedOrder::transport_stats(&p);
        assert_eq!(stats.transport, "channel");
    }

    #[test]
    fn single_shard_matches_unsharded_pair_balance_exactly() {
        // Acceptance gate: W=1 sharded output == unsharded PairBalance,
        // byte for byte, across epochs and block sizes — the async
        // equivalence test above then chains the invariant through to
        // the worker-thread path.
        let mut rng = Rng::new(5);
        for (n, b) in [(33usize, 7usize), (64, 16), (10, 1)] {
            let d = 8;
            let vs = gen::vec_set(&mut rng, n, d);
            let mut sharded = ShardedOrder::new(n, d, 1);
            let mut plain = PairBalance::new(n, d);
            for _ in 0..3 {
                feed_epoch(&mut sharded, &vs, b);
                feed_epoch(&mut plain, &vs, b);
                assert_eq!(
                    sharded.epoch_order(0).to_vec(),
                    plain.epoch_order(0).to_vec(),
                    "n={n} b={b}"
                );
            }
        }
    }

    #[test]
    fn sharded_beats_random_on_static_gradients() {
        // W in {1, 4}: the coordinator's merged order must still beat
        // random reshuffling's herding bound (CD-GraB's headline).
        let mut rng = Rng::new(1);
        let n = 1024;
        let d = 32;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut rand_acc = 0.0f32;
        for _ in 0..5 {
            let perm = rng.permutation(n);
            rand_acc += herding_bound(&vs, &perm).0;
        }
        let rand_inf = rand_acc / 5.0;
        for w in [1usize, 4] {
            let mut p = ShardedOrder::new(n, d, w);
            for _ in 0..8 {
                feed_epoch(&mut p, &vs, 64);
            }
            let (inf, _) = herding_bound(&vs, p.epoch_order(0));
            assert!(
                inf < rand_inf,
                "W={w}: sharded {inf} vs random {rand_inf}"
            );
        }
    }

    #[test]
    fn more_shards_than_units_still_works() {
        let d = 3;
        let vs = gen::vec_set(&mut Rng::new(2), 3, d);
        for mut p in [
            ShardedOrder::new(3, d, 8),
            ShardedOrder::new_gathered(3, d, 8),
            ShardedOrder::new_async(3, d, 8, 2),
            ShardedOrder::new_weighted(3, d, &[2, 0, 1, 5, 0, 1, 1, 1]),
        ] {
            for _ in 0..2 {
                assert_permutation(p.epoch_order(0)).unwrap();
                feed_epoch(&mut p, &vs, 2);
            }
        }
    }

    #[test]
    fn elastic_snapshot_carries_the_planner_ewma() {
        // Contract 8, measured-elastic extension: save_state must carry
        // the planner's smoothed cost history and restore_state must
        // rehydrate it — a resumed elastic coordinator re-plans from
        // the same EWMA as the uninterrupted one. (Before the fix the
        // restore installed a cold planner, silently dropping the
        // history.)
        let n = 32;
        let d = 2;
        let vs = gen::vec_set(&mut Rng::new(9), n, d);
        let mut p = ShardedOrder::new_elastic(n, d, &[1, 1], 4);
        feed_epoch(&mut p, &vs, 8);
        let ewma = vec![2.5e-3, 1.0e-3];
        match &mut p.elastic.as_mut().unwrap().source {
            WeightSource::Measured(pl) => {
                *pl = ElasticPlanner::from_ewma(ewma.clone());
            }
            _ => panic!("new_elastic must carry a measured planner"),
        }
        let state = p.save_state().unwrap();

        let mut q = ShardedOrder::new_elastic(n, d, &[1, 1], 4);
        q.restore_state(&state).unwrap();
        match &q.elastic.as_ref().unwrap().source {
            WeightSource::Measured(pl) => {
                assert_eq!(pl.ewma(), &ewma[..], "EWMA lost on resume")
            }
            _ => panic!("restored coordinator lost its planner"),
        }
        assert_eq!(q.epoch_order(0), p.epoch_order(0));

        // Pre-trailer snapshots (24 bytes shorter) must still restore —
        // with a cold planner at the restored shard count.
        let legacy = &state[..state.len() - 8 - ewma.len() * 8];
        let mut r = ShardedOrder::new_elastic(n, d, &[1, 1], 4);
        r.restore_state(legacy).unwrap();
        match &r.elastic.as_ref().unwrap().source {
            WeightSource::Measured(pl) => {
                assert_eq!(pl.ewma(), &[0.0, 0.0][..])
            }
            _ => panic!("legacy restore lost the planner"),
        }

        // A trailer whose length disagrees with the plan is rejected.
        let mut bad = legacy.to_vec();
        crate::util::ser::put_u64(&mut bad, 3);
        for _ in 0..3 {
            crate::util::ser::put_f64(&mut bad, 1.0e-3);
        }
        assert!(ShardedOrder::new_elastic(n, d, &[1, 1], 4)
            .restore_state(&bad)
            .is_err());
    }

    #[test]
    fn worker_panic_surfaces_at_epoch_boundary() {
        // A poisoned worker panics on its next dequeue. The coordinator
        // must keep accepting observations (no deadlock on the dead
        // shard's queue) and re-raise the worker's payload at epoch_end
        // instead of hanging in the drain.
        let n = 16;
        let d = 2;
        let vs = gen::vec_set(&mut Rng::new(3), n, d);
        let mut p = ShardedOrder::new_async(n, d, 2, 2);
        let _ = p.epoch_order(0);
        p.poison_shard(1);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                feed_epoch(&mut p, &vs, 4); // ends with epoch_end
            }),
        )
        .expect_err("worker panic must propagate");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(
            msg.contains("poisoned shard worker"),
            "unexpected payload: {msg}"
        );
    }

    #[test]
    fn async_drop_mid_epoch_does_not_hang() {
        // Dropping the coordinator with blocks still queued must shut
        // the workers down cleanly (queue close ends their recv loops).
        let n = 32;
        let d = 4;
        let vs = gen::vec_set(&mut Rng::new(4), n, d);
        let mut p = ShardedOrder::new_async(n, d, 4, 2);
        let order = p.epoch_order(0).to_vec();
        let mut flat = vec![0.0f32; 8 * d];
        for (pos, &unit) in order.iter().take(8).enumerate() {
            flat[pos * d..(pos + 1) * d].copy_from_slice(&vs[unit]);
        }
        p.observe_block(0..8, &GradBlock::new(&flat, d));
        drop(p); // mid-epoch: workers still own queued blocks
    }

    #[test]
    #[should_panic(expected = "before observing")]
    fn epoch_end_requires_full_epoch() {
        let mut p = ShardedOrder::new(4, 1, 2);
        let _ = p.epoch_order(0);
        p.observe(0, &[1.0]);
        p.epoch_end();
    }
}
