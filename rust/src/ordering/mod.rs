//! Example-ordering policies — the paper's Section 6 lineup.
//!
//! All policies implement [`OrderPolicy`]: the trainer asks for the epoch's
//! permutation, streams each visited unit's per-example gradient through
//! [`OrderPolicy::observe`], and calls [`OrderPolicy::epoch_end`] at the
//! boundary. Policies that learn from gradients (Greedy Ordering, GraB)
//! build the *next* epoch's permutation from these observations; the rest
//! ignore them. [`OrderPolicy::state_bytes`] reports ordering-state memory
//! for the Table 1 comparison.

mod grab;
pub mod granularity;
mod greedy;

pub use grab::GraBOrder;
pub use greedy::GreedyOrder;

use crate::config::{BalancerKind, OrderingKind, TrainConfig};
use crate::util::rng::Rng;
use anyhow::Result;

/// A data-ordering policy over `n` ordering units.
pub trait OrderPolicy: Send {
    fn name(&self) -> &'static str;

    /// Permutation to follow during epoch `epoch` (0-based). Must be a
    /// valid permutation of `0..n`; the trainer visits units in this order.
    fn epoch_order(&mut self, epoch: usize) -> Vec<usize>;

    /// Observe the gradient of the unit visited at position `pos` of the
    /// current epoch (the unit is `epoch_order(epoch)[pos]`).
    fn observe(&mut self, _pos: usize, _grad: &[f32]) {}

    /// Epoch boundary; policies finalize the next epoch's order here.
    fn epoch_end(&mut self) {}

    /// Bytes of ordering state held between epochs (Table 1's storage
    /// column). Excludes the dataset and model, which all policies share.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Whether this policy consumes per-example gradients (lets the
    /// trainer skip gradient streaming for RR/SO/FlipFlop).
    fn wants_grads(&self) -> bool {
        false
    }
}

/// Random Reshuffling — a fresh uniform permutation each epoch.
pub struct RandomReshuffle {
    n: usize,
    rng: Rng,
}

impl RandomReshuffle {
    pub fn new(n: usize, seed: u64) -> Self {
        RandomReshuffle { n, rng: Rng::new(seed ^ 0x5252) }
    }
}

impl OrderPolicy for RandomReshuffle {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn epoch_order(&mut self, _epoch: usize) -> Vec<usize> {
        self.rng.permutation(self.n)
    }
}

/// Shuffle Once — one random permutation reused every epoch.
pub struct ShuffleOnce {
    order: Vec<usize>,
}

impl ShuffleOnce {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x50);
        ShuffleOnce { order: rng.permutation(n) }
    }
}

impl OrderPolicy for ShuffleOnce {
    fn name(&self) -> &'static str {
        "so"
    }

    fn epoch_order(&mut self, _epoch: usize) -> Vec<usize> {
        self.order.clone()
    }

    fn state_bytes(&self) -> usize {
        self.order.len() * std::mem::size_of::<usize>()
    }
}

/// FlipFlop (Rajput et al. 2021) — reshuffle on even epochs, replay the
/// previous epoch *reversed* on odd epochs.
pub struct FlipFlop {
    n: usize,
    rng: Rng,
    last: Vec<usize>,
}

impl FlipFlop {
    pub fn new(n: usize, seed: u64) -> Self {
        FlipFlop { n, rng: Rng::new(seed ^ 0xF11F), last: Vec::new() }
    }
}

impl OrderPolicy for FlipFlop {
    fn name(&self) -> &'static str {
        "flipflop"
    }

    fn epoch_order(&mut self, epoch: usize) -> Vec<usize> {
        if epoch % 2 == 0 || self.last.is_empty() {
            self.last = self.rng.permutation(self.n);
            self.last.clone()
        } else {
            let mut rev = self.last.clone();
            rev.reverse();
            rev
        }
    }

    fn state_bytes(&self) -> usize {
        self.last.len() * std::mem::size_of::<usize>()
    }
}

/// Sequential — identity order every epoch (sanity baseline).
pub struct Sequential {
    n: usize,
}

impl Sequential {
    pub fn new(n: usize) -> Self {
        Sequential { n }
    }
}

impl OrderPolicy for Sequential {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn epoch_order(&mut self, _epoch: usize) -> Vec<usize> {
        (0..self.n).collect()
    }
}

/// A fixed, externally supplied permutation (Fig. 3 "Retrain from GraB").
pub struct FixedOrder {
    order: Vec<usize>,
    name: &'static str,
}

impl FixedOrder {
    pub fn new(order: Vec<usize>, name: &'static str) -> Self {
        FixedOrder { order, name }
    }
}

impl OrderPolicy for FixedOrder {
    fn name(&self) -> &'static str {
        self.name
    }

    fn epoch_order(&mut self, _epoch: usize) -> Vec<usize> {
        self.order.clone()
    }

    fn state_bytes(&self) -> usize {
        self.order.len() * std::mem::size_of::<usize>()
    }
}

/// One-step GraB (Fig. 3): run GraB during epoch 0 only, then freeze the
/// order it produced for all later epochs.
pub struct OneStepGraB {
    inner: GraBOrder,
    frozen: Option<Vec<usize>>,
}

impl OneStepGraB {
    pub fn new(inner: GraBOrder) -> Self {
        OneStepGraB { inner, frozen: None }
    }
}

impl OrderPolicy for OneStepGraB {
    fn name(&self) -> &'static str {
        "grab-1step"
    }

    fn epoch_order(&mut self, epoch: usize) -> Vec<usize> {
        match &self.frozen {
            Some(o) => o.clone(),
            None => self.inner.epoch_order(epoch),
        }
    }

    fn observe(&mut self, pos: usize, grad: &[f32]) {
        if self.frozen.is_none() {
            self.inner.observe(pos, grad);
        }
    }

    fn epoch_end(&mut self) {
        if self.frozen.is_none() {
            self.inner.epoch_end();
            self.frozen = Some(self.inner.epoch_order(1));
        }
    }

    fn state_bytes(&self) -> usize {
        self.frozen
            .as_ref()
            .map(|o| o.len() * std::mem::size_of::<usize>())
            .unwrap_or_else(|| self.inner.state_bytes())
    }

    fn wants_grads(&self) -> bool {
        self.frozen.is_none()
    }
}

/// Build the policy requested by a [`TrainConfig`] over `n` units of
/// dimension `d`. `retrain_order` supplies the fixed permutation for
/// [`OrderingKind::RetrainFromGraB`].
pub fn build_policy(
    cfg: &TrainConfig,
    n: usize,
    d: usize,
    retrain_order: Option<Vec<usize>>,
) -> Result<Box<dyn OrderPolicy>> {
    // Coarse granularity (paper §granularity): build the inner policy over
    // n/gs groups and expand. Fixed-order policies are exempt (they are
    // already permutations over examples).
    if cfg.group_size > 1
        && !matches!(cfg.ordering, OrderingKind::RetrainFromGraB)
    {
        let groups = n.div_ceil(cfg.group_size);
        let mut inner_cfg = cfg.clone();
        inner_cfg.group_size = 1;
        let inner = build_policy(&inner_cfg, groups, d, None)?;
        return Ok(Box::new(granularity::GroupedOrder::new(
            n, d, cfg.group_size, inner,
        )));
    }
    let seed = cfg.seed;
    Ok(match cfg.ordering {
        OrderingKind::RandomReshuffle => {
            Box::new(RandomReshuffle::new(n, seed))
        }
        OrderingKind::ShuffleOnce => Box::new(ShuffleOnce::new(n, seed)),
        OrderingKind::FlipFlop => Box::new(FlipFlop::new(n, seed)),
        OrderingKind::Sequential => Box::new(Sequential::new(n)),
        OrderingKind::GreedyOrdering => Box::new(GreedyOrder::new(n, d)),
        OrderingKind::GraB => {
            Box::new(grab_from_cfg(cfg, n, d))
        }
        OrderingKind::OneStepGraB => {
            Box::new(OneStepGraB::new(grab_from_cfg(cfg, n, d)))
        }
        OrderingKind::RetrainFromGraB => {
            let order = retrain_order.ok_or_else(|| {
                anyhow::anyhow!(
                    "retrain-from-grab needs a source order \
                     (run GraB first)"
                )
            })?;
            anyhow::ensure!(order.len() == n, "retrain order length");
            Box::new(FixedOrder::new(order, "grab-retrain"))
        }
    })
}

fn grab_from_cfg(cfg: &TrainConfig, n: usize, d: usize) -> GraBOrder {
    let balancer: Box<dyn crate::balance::Balancer + Send> =
        match cfg.balancer {
            BalancerKind::Deterministic | BalancerKind::Kernel => {
                Box::new(crate::balance::DeterministicBalancer)
            }
            BalancerKind::Walk => {
                let c = if cfg.walk_c > 0.0 {
                    cfg.walk_c
                } else {
                    crate::balance::WalkBalancer::theorem_c(n, d, 0.01)
                };
                Box::new(crate::balance::WalkBalancer::new(c, cfg.seed))
            }
        };
    GraBOrder::new(n, d, balancer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_permutation;

    #[test]
    fn rr_fresh_permutation_each_epoch() {
        let mut rr = RandomReshuffle::new(100, 0);
        let a = rr.epoch_order(0);
        let b = rr.epoch_order(1);
        assert_permutation(&a).unwrap();
        assert_permutation(&b).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn so_same_every_epoch() {
        let mut so = ShuffleOnce::new(50, 1);
        assert_eq!(so.epoch_order(0), so.epoch_order(7));
        assert_permutation(&so.epoch_order(0)).unwrap();
    }

    #[test]
    fn flipflop_reverses_odd_epochs() {
        let mut ff = FlipFlop::new(20, 2);
        let e0 = ff.epoch_order(0);
        let e1 = ff.epoch_order(1);
        let mut rev = e0.clone();
        rev.reverse();
        assert_eq!(e1, rev);
        let e2 = ff.epoch_order(2);
        assert_ne!(e2, e0, "even epoch reshuffles");
        assert_permutation(&e2).unwrap();
    }

    #[test]
    fn sequential_identity() {
        let mut s = Sequential::new(5);
        assert_eq!(s.epoch_order(3), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fixed_order_replays() {
        let mut f = FixedOrder::new(vec![2, 0, 1], "grab-retrain");
        assert_eq!(f.epoch_order(0), vec![2, 0, 1]);
        assert_eq!(f.epoch_order(9), vec![2, 0, 1]);
    }

    #[test]
    fn build_policy_all_kinds() {
        let mut cfg = TrainConfig::default();
        for kind in [
            OrderingKind::RandomReshuffle,
            OrderingKind::ShuffleOnce,
            OrderingKind::FlipFlop,
            OrderingKind::GreedyOrdering,
            OrderingKind::GraB,
            OrderingKind::OneStepGraB,
            OrderingKind::Sequential,
        ] {
            cfg.ordering = kind;
            let p = build_policy(&cfg, 16, 4, None).unwrap();
            assert!(!p.name().is_empty());
        }
        cfg.ordering = OrderingKind::RetrainFromGraB;
        assert!(build_policy(&cfg, 16, 4, None).is_err());
        let p = build_policy(&cfg, 3, 4, Some(vec![2, 1, 0])).unwrap();
        assert_eq!(p.name(), "grab-retrain");
    }

    #[test]
    fn onestep_freezes_after_first_epoch() {
        let cfg = TrainConfig::default();
        let inner = super::grab_from_cfg(&cfg, 8, 2);
        let mut p = OneStepGraB::new(inner);
        let _e0 = p.epoch_order(0);
        assert!(p.wants_grads());
        for pos in 0..8 {
            p.observe(pos, &[pos as f32, -(pos as f32)]);
        }
        p.epoch_end();
        assert!(!p.wants_grads());
        let e1 = p.epoch_order(1);
        let e2 = p.epoch_order(2);
        assert_eq!(e1, e2);
        assert_permutation(&e1).unwrap();
    }
}
