//! Example-ordering policies — the paper's Section 6 lineup plus the
//! CD-GraB extensions (pair balancing, sharded coordination).
//!
//! All policies implement [`OrderPolicy`]: the trainer asks for the epoch's
//! permutation (a *borrowed* slice — policies keep their permutations
//! between calls, no per-call clone), streams visited unit gradients
//! through [`OrderPolicy::observe_block`] in contiguous
//! [`GradBlock`]s (zero-copy views over the executor's `[B × d]` upload
//! buffer), and calls [`OrderPolicy::epoch_end`] at the boundary. Policies
//! that learn from gradients (Greedy Ordering, GraB, PairBalance) build
//! the *next* epoch's permutation from these observations; the rest ignore
//! them. [`OrderPolicy::state_bytes`] reports ordering-state memory for
//! the Table 1 comparison.
//!
//! The block API is the scaling seam: one virtual dispatch per microbatch
//! instead of per example, batched sign kernels inside the policies, and a
//! natural decomposition point for the sharded CD-GraB coordinator
//! ([`ShardedOrder`]).

mod grab;
pub mod granularity;
mod greedy;
pub mod pair;
pub mod queue;
pub mod sharded;
pub mod stream;
pub mod topology;
pub mod transport;

pub use grab::GraBOrder;
pub use greedy::GreedyOrder;
pub use pair::PairBalance;
pub use sharded::ShardedOrder;
pub use stream::StreamOrder;
pub use topology::Topology;

pub use crate::tensor::GradBlock;

use std::ops::Range;

use crate::config::{
    BalancerKind, OrderingKind, TrainConfig, TransportKind,
};
use crate::util::rng::Rng;
use anyhow::Result;

/// A data-ordering policy over `n` ordering units.
///
/// The trainer's contract per epoch: call [`OrderPolicy::epoch_order`]
/// once, visit units in that order while streaming their gradients
/// through [`OrderPolicy::observe_block`] in contiguous position blocks,
/// then call [`OrderPolicy::epoch_end`] at the boundary.
///
/// # Example
///
/// Driving one epoch of [`PairBalance`] (CD-GraB's kernel) by hand:
///
/// ```
/// use grab::ordering::{GradBlock, OrderPolicy, PairBalance};
///
/// let (n, d) = (4, 2);
/// let mut policy = PairBalance::new(n, d);
///
/// // 1. The epoch's permutation (first epoch is the identity).
/// let order = policy.epoch_order(0).to_vec();
/// assert_eq!(order, vec![0, 1, 2, 3]);
///
/// // 2. Stream per-example gradients in visit order, as one or more
/// //    contiguous [rows x d] blocks over the epoch's positions.
/// let grads: Vec<f32> = vec![
///     1.0, 0.0,   // gradient of the unit at position 0
///     -1.0, 0.0,  // position 1
///     0.5, 0.5,   // position 2
///     -0.5, -0.5, // position 3
/// ];
/// policy.observe_block(0..4, &GradBlock::new(&grads, d));
///
/// // 3. Close the epoch; the policy finalizes the next epoch's order.
/// policy.epoch_end();
/// let mut next = policy.epoch_order(1).to_vec();
/// next.sort_unstable();
/// assert_eq!(next, vec![0, 1, 2, 3]); // still a permutation of 0..n
/// ```
pub trait OrderPolicy: Send {
    /// Short stable policy name (used in run ids, CSV rows, and logs).
    fn name(&self) -> &'static str;

    /// Permutation to follow during epoch `epoch` (0-based). Must be a
    /// valid permutation of `0..n`; the trainer visits units in this
    /// order. The slice is borrowed from the policy's own state — callers
    /// that need ownership copy explicitly, and policies must return the
    /// same permutation for repeated calls within one epoch.
    fn epoch_order(&mut self, epoch: usize) -> &[usize];

    /// Observe the gradients of the units visited at positions `range`
    /// of the current epoch (unit `i` of the block is
    /// `epoch_order(epoch)[range.start + i]`). `block` is a zero-copy
    /// view over the executor's contiguous `[B × d]` gradient buffer;
    /// `range.len()` must equal `block.rows()`. Blocks arrive in epoch
    /// order and cover positions `0..n` exactly once per epoch.
    fn observe_block(&mut self, _range: Range<usize>, _block: &GradBlock) {}

    /// Compatibility shim: observe a single unit gradient as a 1-row
    /// block. Exactly equivalent to the pre-block per-example API (and
    /// measured against the block path in benches/ordering_overhead.rs);
    /// the trainer itself always streams whole blocks.
    fn observe(&mut self, pos: usize, grad: &[f32]) {
        self.observe_block(pos..pos + 1, &GradBlock::new(grad, grad.len()));
    }

    /// Epoch boundary; policies finalize the next epoch's order here.
    fn epoch_end(&mut self) {}

    /// Bytes of ordering state held by the policy (Table 1's storage
    /// column). Excludes the dataset and model, which all policies share.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Whether this policy consumes per-example gradients (lets the
    /// trainer skip gradient streaming for RR/SO/FlipFlop).
    fn wants_grads(&self) -> bool {
        false
    }

    /// Aggregated shard-link counters (backpressure stalls, bytes moved
    /// to/from shard workers) for policies that coordinate over a
    /// [`transport::ShardTransport`]; `None` for unsharded policies.
    /// Lets the trainer report comparable numbers for sync / channel /
    /// tcp CD-GraB runs without downcasting.
    fn transport_stats(&self) -> Option<transport::TransportStats> {
        None
    }

    /// Per-epoch shard [`Topology`] plans for policies that lay units
    /// out over shards: entry `e` is the plan that produced epoch `e`'s
    /// order. Static topologies repeat one plan; elastic CD-GraB
    /// records every boundary re-plan, which is what makes an elastic
    /// run replayable (`docs/determinism.md` contract 6). `None` for
    /// unsharded policies.
    fn topology_log(&self) -> Option<&[Topology]> {
        None
    }

    /// Serialize the policy's *epoch-boundary* state for a checkpoint
    /// (determinism contract 8, `docs/determinism.md`): everything a
    /// freshly constructed policy of the same config needs to continue
    /// the run bit-identically from the next epoch. Must only be called
    /// between epochs (after [`OrderPolicy::epoch_end`], before the
    /// next [`OrderPolicy::epoch_order`]). `None` for policies whose
    /// boundary state is fully derivable from config (Sequential,
    /// ShuffleOnce, FixedOrder).
    fn save_state(&mut self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state captured by [`OrderPolicy::save_state`] into a
    /// freshly constructed policy of the same config. The error string
    /// is wrapped into a typed checkpoint error by the trainer.
    fn restore_state(&mut self, _bytes: &[u8]) -> Result<(), String> {
        Err(format!(
            "policy '{}' does not carry restorable checkpoint state",
            self.name()
        ))
    }

    /// Overwrite the permutation the next [`OrderPolicy::epoch_order`]
    /// call returns with `order` (the legacy single-file
    /// checkpoint-resume path, which records only the order). Returns
    /// `false` for policies that cannot adopt an external permutation.
    fn restore_order(&mut self, _order: &[usize]) -> bool {
        false
    }
}

/// Whether `order` is a permutation of `0..n` — the validation gate on
/// every checkpoint-restored permutation (a corrupt order must never
/// reach an epoch loop).
pub(crate) fn is_permutation_of(order: &[usize], n: usize) -> bool {
    if order.len() != n {
        return false;
    }
    let mut seen = vec![false; n];
    for &u in order {
        if u >= n || seen[u] {
            return false;
        }
        seen[u] = true;
    }
    true
}

/// Random Reshuffling — a fresh uniform permutation each epoch.
pub struct RandomReshuffle {
    order: Vec<usize>,
    rng: Rng,
    cached_epoch: Option<usize>,
}

impl RandomReshuffle {
    /// A reshuffler over `n` units, seeded from the run seed.
    pub fn new(n: usize, seed: u64) -> Self {
        RandomReshuffle {
            order: (0..n).collect(),
            rng: Rng::new(seed ^ 0x5252),
            cached_epoch: None,
        }
    }
}

impl OrderPolicy for RandomReshuffle {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.cached_epoch != Some(epoch) {
            self.rng.shuffle(&mut self.order);
            self.cached_epoch = Some(epoch);
        }
        &self.order
    }

    // state_bytes stays 0 (Table 1's "RR needs no extra storage"): the
    // permutation buffer is the borrowed-slice API's transient output,
    // not algorithm state carried between epochs.

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // The shuffle mutates `order` in place, so resuming the stream
        // bit-identically needs both the RNG position and the current
        // permutation the next shuffle will start from.
        let mut out = Vec::new();
        for w in self.rng.state() {
            crate::util::ser::put_u64(&mut out, w);
        }
        crate::util::ser::put_usize_slice(&mut out, &self.order);
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let n = self.order.len();
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
            let order = r.usize_slice(n)?;
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((s, order))
        })();
        let (s, order) =
            parse.map_err(|e| format!("rr state: {e}"))?;
        if !is_permutation_of(&order, n) {
            return Err(format!(
                "rr state order is not a permutation of 0..{n}"
            ));
        }
        self.rng = Rng::from_state(s);
        self.order = order;
        self.cached_epoch = None;
        Ok(())
    }
}

/// Shuffle Once — one random permutation reused every epoch.
pub struct ShuffleOnce {
    order: Vec<usize>,
}

impl ShuffleOnce {
    /// One seeded permutation of `n` units, reused every epoch.
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x50);
        ShuffleOnce { order: rng.permutation(n) }
    }
}

impl OrderPolicy for ShuffleOnce {
    fn name(&self) -> &'static str {
        "so"
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.order
    }

    fn state_bytes(&self) -> usize {
        self.order.len() * std::mem::size_of::<usize>()
    }
}

/// FlipFlop (Rajput et al. 2021) — reshuffle on even epochs, replay the
/// previous epoch *reversed* on odd epochs.
pub struct FlipFlop {
    n: usize,
    rng: Rng,
    /// The even-epoch shuffle being flip-flopped.
    shuffled: Vec<usize>,
    /// The order handed out for the cached epoch.
    out: Vec<usize>,
    cached_epoch: Option<usize>,
}

impl FlipFlop {
    /// A flip-flopper over `n` units, seeded from the run seed.
    pub fn new(n: usize, seed: u64) -> Self {
        FlipFlop {
            n,
            rng: Rng::new(seed ^ 0xF11F),
            shuffled: Vec::new(),
            out: Vec::new(),
            cached_epoch: None,
        }
    }
}

impl OrderPolicy for FlipFlop {
    fn name(&self) -> &'static str {
        "flipflop"
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        if self.cached_epoch != Some(epoch) {
            if epoch % 2 == 0 || self.shuffled.is_empty() {
                if self.shuffled.is_empty() {
                    self.shuffled = (0..self.n).collect();
                }
                self.rng.shuffle(&mut self.shuffled);
                self.out.clear();
                self.out.extend_from_slice(&self.shuffled);
            } else {
                self.out.clear();
                self.out.extend(self.shuffled.iter().rev().copied());
            }
            self.cached_epoch = Some(epoch);
        }
        &self.out
    }

    fn state_bytes(&self) -> usize {
        // Only the retained even-epoch shuffle is algorithm state (it
        // must be replayed reversed); `out` is a presentation cache.
        self.shuffled.len() * std::mem::size_of::<usize>()
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // RNG position plus the retained even-epoch shuffle (an odd
        // resume epoch replays it reversed; an even one reshuffles it).
        let mut out = Vec::new();
        for w in self.rng.state() {
            crate::util::ser::put_u64(&mut out, w);
        }
        crate::util::ser::put_usize_slice(&mut out, &self.shuffled);
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let parse = (|| {
            let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
            let shuffled = r.usize_slice(self.n)?;
            r.finish()?;
            Ok::<_, crate::util::ser::WireError>((s, shuffled))
        })();
        let (s, shuffled) =
            parse.map_err(|e| format!("flipflop state: {e}"))?;
        if !shuffled.is_empty() && !is_permutation_of(&shuffled, self.n)
        {
            return Err(format!(
                "flipflop shuffle is not a permutation of 0..{}",
                self.n
            ));
        }
        self.rng = Rng::from_state(s);
        self.shuffled = shuffled;
        self.out.clear();
        self.cached_epoch = None;
        Ok(())
    }
}

/// Sequential — identity order every epoch (sanity baseline).
pub struct Sequential {
    order: Vec<usize>,
}

impl Sequential {
    /// Identity order over `n` units.
    pub fn new(n: usize) -> Self {
        Sequential { order: (0..n).collect() }
    }
}

impl OrderPolicy for Sequential {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.order
    }
}

/// A fixed, externally supplied permutation (Fig. 3 "Retrain from GraB").
pub struct FixedOrder {
    order: Vec<usize>,
    name: &'static str,
}

impl FixedOrder {
    /// Replay `order` every epoch, reporting `name` in logs.
    pub fn new(order: Vec<usize>, name: &'static str) -> Self {
        FixedOrder { order, name }
    }
}

impl OrderPolicy for FixedOrder {
    fn name(&self) -> &'static str {
        self.name
    }

    fn epoch_order(&mut self, _epoch: usize) -> &[usize] {
        &self.order
    }

    fn state_bytes(&self) -> usize {
        self.order.len() * std::mem::size_of::<usize>()
    }
}

/// One-step GraB (Fig. 3): run GraB during epoch 0 only, then freeze the
/// order it produced for all later epochs.
pub struct OneStepGraB {
    inner: GraBOrder,
    frozen: Option<Vec<usize>>,
}

impl OneStepGraB {
    /// Wrap a GraB policy: balance during epoch 0, then freeze.
    pub fn new(inner: GraBOrder) -> Self {
        OneStepGraB { inner, frozen: None }
    }
}

impl OrderPolicy for OneStepGraB {
    fn name(&self) -> &'static str {
        "grab-1step"
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        match &self.frozen {
            Some(o) => o,
            None => self.inner.epoch_order(epoch),
        }
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        if self.frozen.is_none() {
            self.inner.observe_block(range, block);
        }
    }

    fn epoch_end(&mut self) {
        if self.frozen.is_none() {
            self.inner.epoch_end();
            self.frozen = Some(self.inner.epoch_order(1).to_vec());
        }
    }

    fn state_bytes(&self) -> usize {
        self.frozen
            .as_ref()
            .map(|o| o.len() * std::mem::size_of::<usize>())
            .unwrap_or_else(|| self.inner.state_bytes())
    }

    fn wants_grads(&self) -> bool {
        self.frozen.is_none()
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        let mut out = Vec::new();
        match &self.frozen {
            Some(order) => {
                crate::util::ser::put_u32(&mut out, 1);
                crate::util::ser::put_usize_slice(&mut out, order);
            }
            None => {
                crate::util::ser::put_u32(&mut out, 0);
                out.extend_from_slice(&self.inner.save_state()?);
            }
        }
        Some(out)
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = crate::util::ser::ByteReader::new(bytes);
        let tag =
            r.u32().map_err(|e| format!("grab-1step state: {e}"))?;
        match tag {
            1 => {
                let order = (|| {
                    let o = r.usize_slice(usize::MAX)?;
                    r.finish()?;
                    Ok::<_, crate::util::ser::WireError>(o)
                })()
                .map_err(|e| format!("grab-1step state: {e}"))?;
                let n = self.inner.epoch_order(0).len();
                if !is_permutation_of(&order, n) {
                    return Err(format!(
                        "grab-1step frozen order is not a permutation \
                         of 0..{n}"
                    ));
                }
                self.frozen = Some(order);
                Ok(())
            }
            0 => self.inner.restore_state(r.rest()),
            t => Err(format!("grab-1step state: unknown tag {t}")),
        }
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        match &mut self.frozen {
            Some(frozen) => {
                if !is_permutation_of(order, frozen.len()) {
                    return false;
                }
                frozen.clear();
                frozen.extend_from_slice(order);
                true
            }
            None => self.inner.restore_order(order),
        }
    }
}

/// Stream epoch `epoch` of a static vector set through a policy: gather
/// the rows of `vs` into `flat` in the policy's visit order (the loader
/// stage's job in real training, kept outside the timed section), stream
/// `block`-row [`GradBlock`]s through
/// [`OrderPolicy::observe_block`], and end the epoch. Returns the
/// observe + epoch_end wall-clock seconds. Shared by the static-gradient
/// experiments, tests, and benches.
///
/// The epoch index is forwarded to [`OrderPolicy::epoch_order`] — an
/// epoch-keyed policy (RandomReshuffle, FlipFlop) reshuffles per epoch
/// exactly as it would under the trainer; multi-epoch callers must pass
/// their real epoch counter, not 0.
///
/// # Panics
///
/// Hard input contracts (release builds included — a violation in a
/// release caller used to silently truncate or corrupt the gathered
/// buffer): `block` must be positive, every row of `vs` must have the
/// same dimension, and the policy's order must cover exactly `vs.len()`
/// units.
pub fn stream_static_epoch(
    policy: &mut dyn OrderPolicy,
    epoch: usize,
    vs: &[Vec<f32>],
    flat: &mut Vec<f32>,
    block: usize,
) -> f64 {
    assert!(block > 0, "block must be positive");
    let n = vs.len();
    let d = vs.first().map_or(0, |v| v.len());
    for (u, v) in vs.iter().enumerate() {
        assert_eq!(
            v.len(),
            d,
            "ragged vector set: vs[{u}] has {} values, expected d={d}",
            v.len()
        );
    }
    flat.clear();
    flat.resize(n * d, 0.0);
    {
        let pname = policy.name();
        let order = policy.epoch_order(epoch);
        assert_eq!(
            order.len(),
            n,
            "policy '{pname}' returned a {}-unit order for {n} vectors",
            order.len()
        );
        for (pos, &unit) in order.iter().enumerate() {
            flat[pos * d..(pos + 1) * d].copy_from_slice(&vs[unit]);
        }
    }
    let sw = crate::util::timer::Stopwatch::start();
    let mut pos = 0;
    while pos < n {
        let end = (pos + block).min(n);
        policy.observe_block(
            pos..end,
            &GradBlock::new(&flat[pos * d..end * d], d),
        );
        pos = end;
    }
    policy.epoch_end();
    sw.secs()
}

/// Build the policy requested by a [`TrainConfig`] over `n` units of
/// dimension `d`. `retrain_order` supplies the fixed permutation for
/// [`OrderingKind::RetrainFromGraB`].
pub fn build_policy(
    cfg: &TrainConfig,
    n: usize,
    d: usize,
    retrain_order: Option<Vec<usize>>,
) -> Result<Box<dyn OrderPolicy>> {
    // Coarse granularity (paper §granularity): build the inner policy over
    // n/gs groups and expand. Fixed-order policies are exempt (they are
    // already permutations over examples).
    if cfg.group_size > 1
        && !matches!(cfg.ordering, OrderingKind::RetrainFromGraB)
    {
        let groups = n.div_ceil(cfg.group_size);
        let mut inner_cfg = cfg.clone();
        inner_cfg.group_size = 1;
        let inner = build_policy(&inner_cfg, groups, d, None)?;
        return Ok(Box::new(granularity::GroupedOrder::new(
            n, d, cfg.group_size, inner,
        )));
    }
    let seed = cfg.seed;
    Ok(match cfg.ordering {
        OrderingKind::RandomReshuffle => {
            Box::new(RandomReshuffle::new(n, seed))
        }
        OrderingKind::ShuffleOnce => Box::new(ShuffleOnce::new(n, seed)),
        OrderingKind::FlipFlop => Box::new(FlipFlop::new(n, seed)),
        OrderingKind::Sequential => Box::new(Sequential::new(n)),
        OrderingKind::GreedyOrdering => Box::new(GreedyOrder::new(n, d)),
        OrderingKind::GraB => {
            Box::new(grab_from_cfg(cfg, n, d))
        }
        OrderingKind::OneStepGraB => {
            Box::new(OneStepGraB::new(grab_from_cfg(cfg, n, d)))
        }
        OrderingKind::PairBalance => Box::new(PairBalance::new(n, d)),
        OrderingKind::Stream => {
            // The trainer's epoch loop visits all `n` units per epoch,
            // so its reservoir is the whole dataset: one window per
            // epoch, statically scheduled — exactly PairBalance
            // (contract 9's static half), plus the window bookkeeping.
            // Sliding reservoirs (admits/retires mid-run) are driven by
            // the streaming surfaces: `grab exp stream` and the daemon's
            // stream jobs. `--window` beyond the dataset leaves slack
            // capacity (validate() rejects windows below `n`).
            let units: Vec<u64> = (0..n as u64).collect();
            let capacity = cfg.stream_window.max(n).max(1);
            Box::new(StreamOrder::with_units(capacity, d, &units))
        }
        OrderingKind::ShardedPairBalance => {
            // The starting topology: pinned `--weights`, or equal.
            let weights: Vec<u64> = cfg
                .shard_weights
                .clone()
                .unwrap_or_else(|| vec![1; cfg.num_shards]);
            match cfg.shard_transport {
                TransportKind::Tcp => match &cfg.connect {
                    Some(addrs) => {
                        let addrs = transport::parse_connect_addrs(addrs);
                        let read_timeout = std::time::Duration::from_secs(
                            cfg.read_timeout_secs,
                        );
                        if cfg.elastic {
                            Box::new(
                                ShardedOrder::new_tcp_connect_elastic(
                                    &addrs,
                                    n,
                                    d,
                                    &weights,
                                    read_timeout,
                                )?,
                            )
                        } else {
                            Box::new(
                                ShardedOrder::new_tcp_connect_weighted(
                                    &addrs,
                                    n,
                                    d,
                                    &weights,
                                    read_timeout,
                                )?,
                            )
                        }
                    }
                    None if cfg.elastic => {
                        Box::new(ShardedOrder::new_tcp_loopback_elastic(
                            n, d, &weights,
                        )?)
                    }
                    None => {
                        Box::new(ShardedOrder::new_tcp_loopback_weighted(
                            n, d, &weights,
                        )?)
                    }
                },
                TransportKind::Channel if cfg.elastic => {
                    Box::new(ShardedOrder::new_elastic(
                        n,
                        d,
                        &weights,
                        cfg.shard_queue_depth,
                    ))
                }
                TransportKind::Channel if cfg.async_shards => {
                    Box::new(ShardedOrder::new_async_weighted(
                        n,
                        d,
                        &weights,
                        cfg.shard_queue_depth,
                    ))
                }
                TransportKind::Channel => {
                    Box::new(ShardedOrder::new_weighted(n, d, &weights))
                }
            }
        }
        OrderingKind::RetrainFromGraB => {
            let order = retrain_order.ok_or_else(|| {
                anyhow::anyhow!(
                    "retrain-from-grab needs a source order \
                     (run GraB first)"
                )
            })?;
            anyhow::ensure!(order.len() == n, "retrain order length");
            Box::new(FixedOrder::new(order, "grab-retrain"))
        }
    })
}

fn grab_from_cfg(cfg: &TrainConfig, n: usize, d: usize) -> GraBOrder {
    let balancer: Box<dyn crate::balance::Balancer + Send> =
        match cfg.balancer {
            BalancerKind::Deterministic | BalancerKind::Kernel => {
                Box::new(crate::balance::DeterministicBalancer)
            }
            BalancerKind::Walk => {
                let c = if cfg.walk_c > 0.0 {
                    cfg.walk_c
                } else {
                    crate::balance::WalkBalancer::theorem_c(n, d, 0.01)
                };
                Box::new(crate::balance::WalkBalancer::new(c, cfg.seed))
            }
        };
    GraBOrder::new(n, d, balancer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::assert_permutation;

    #[test]
    fn rr_fresh_permutation_each_epoch() {
        let mut rr = RandomReshuffle::new(100, 0);
        let a = rr.epoch_order(0).to_vec();
        let b = rr.epoch_order(1).to_vec();
        assert_permutation(&a).unwrap();
        assert_permutation(&b).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn rr_stable_within_an_epoch() {
        // Borrowed-slice contract: repeated calls for the same epoch must
        // not reshuffle under the caller.
        let mut rr = RandomReshuffle::new(64, 3);
        let a = rr.epoch_order(4).to_vec();
        let b = rr.epoch_order(4).to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn so_same_every_epoch() {
        let mut so = ShuffleOnce::new(50, 1);
        let a = so.epoch_order(0).to_vec();
        assert_eq!(a, so.epoch_order(7));
        assert_permutation(&a).unwrap();
    }

    #[test]
    fn flipflop_reverses_odd_epochs() {
        let mut ff = FlipFlop::new(20, 2);
        let e0 = ff.epoch_order(0).to_vec();
        let e1 = ff.epoch_order(1).to_vec();
        let mut rev = e0.clone();
        rev.reverse();
        assert_eq!(e1, rev);
        let e2 = ff.epoch_order(2).to_vec();
        assert_ne!(e2, e0, "even epoch reshuffles");
        assert_permutation(&e2).unwrap();
    }

    #[test]
    fn sequential_identity() {
        let mut s = Sequential::new(5);
        assert_eq!(s.epoch_order(3), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn fixed_order_replays() {
        let mut f = FixedOrder::new(vec![2, 0, 1], "grab-retrain");
        assert_eq!(f.epoch_order(0), &[2, 0, 1]);
        assert_eq!(f.epoch_order(9), &[2, 0, 1]);
    }

    #[test]
    fn build_policy_all_kinds() {
        let mut cfg = TrainConfig::default();
        for kind in [
            OrderingKind::RandomReshuffle,
            OrderingKind::ShuffleOnce,
            OrderingKind::FlipFlop,
            OrderingKind::GreedyOrdering,
            OrderingKind::GraB,
            OrderingKind::OneStepGraB,
            OrderingKind::PairBalance,
            OrderingKind::Stream,
            OrderingKind::ShardedPairBalance,
            OrderingKind::Sequential,
        ] {
            cfg.ordering = kind;
            let p = build_policy(&cfg, 16, 4, None).unwrap();
            assert!(!p.name().is_empty());
        }
        cfg.ordering = OrderingKind::RetrainFromGraB;
        assert!(build_policy(&cfg, 16, 4, None).is_err());
        let p = build_policy(&cfg, 3, 4, Some(vec![2, 1, 0])).unwrap();
        assert_eq!(p.name(), "grab-retrain");
    }

    #[test]
    fn build_policy_selects_async_backend() {
        let mut cfg = TrainConfig::default();
        cfg.ordering = OrderingKind::ShardedPairBalance;
        cfg.num_shards = 2;
        let p = build_policy(&cfg, 16, 4, None).unwrap();
        assert_eq!(p.name(), "cd-grab");
        cfg.async_shards = true;
        cfg.shard_queue_depth = 2;
        let p = build_policy(&cfg, 16, 4, None).unwrap();
        assert_eq!(p.name(), "cd-grab-async");
    }

    #[test]
    fn build_policy_selects_weighted_and_elastic_backends() {
        // Pinned weights flow into the topology; --elastic picks the
        // re-planning coordinator (over channel workers here).
        let mut cfg = TrainConfig::default();
        cfg.ordering = OrderingKind::ShardedPairBalance;
        cfg.num_shards = 3;
        cfg.shard_weights = Some(vec![1, 1, 2]);
        let mut p = build_policy(&cfg, 16, 4, None).unwrap();
        assert_eq!(p.name(), "cd-grab");
        let log = p.topology_log().expect("sharded policies log plans");
        assert_eq!(log[0].weights, vec![1, 1, 2]);
        assert_eq!(log[0].sizes, vec![4, 4, 8]);
        crate::util::prop::assert_permutation(p.epoch_order(0)).unwrap();

        cfg.async_shards = true;
        cfg.elastic = true;
        cfg.shard_queue_depth = 2;
        let p = build_policy(&cfg, 16, 4, None).unwrap();
        assert_eq!(p.name(), "cd-grab-elastic");
    }

    #[test]
    fn build_policy_selects_tcp_transport() {
        // --transport tcp with no --connect: loopback socket workers.
        let mut cfg = TrainConfig::default();
        cfg.ordering = OrderingKind::ShardedPairBalance;
        cfg.num_shards = 2;
        cfg.shard_transport = TransportKind::Tcp;
        let mut p = build_policy(&cfg, 16, 4, None).unwrap();
        assert_eq!(p.name(), "cd-grab-tcp");
        // The policy is live: first epoch order is a permutation and
        // link stats are reported.
        crate::util::prop::assert_permutation(p.epoch_order(0)).unwrap();
        let stats = p.transport_stats().expect("transported policy");
        assert_eq!(stats.transport, "tcp");
        assert_eq!(stats.per_shard.len(), 2);
    }

    #[test]
    fn stream_static_epoch_threads_the_epoch_index() {
        // Regression: the helper used to hardcode `epoch_order(0)`, so
        // epoch-keyed policies (RandomReshuffle caches per epoch index)
        // silently replayed epoch 0's permutation for every streamed
        // epoch. The streamed orders must now match a directly driven
        // policy epoch-for-epoch.
        let n = 32;
        let d = 2;
        let vs: Vec<Vec<f32>> =
            (0..n).map(|i| vec![i as f32, -(i as f32)]).collect();
        let mut streamed = RandomReshuffle::new(n, 7);
        let mut direct = RandomReshuffle::new(n, 7);
        let mut flat = Vec::new();
        let mut orders = Vec::new();
        for epoch in 0..3 {
            stream_static_epoch(&mut streamed, epoch, &vs, &mut flat, 8);
            let got = streamed.epoch_order(epoch).to_vec();
            assert_eq!(got, direct.epoch_order(epoch).to_vec());
            orders.push(got);
        }
        assert_ne!(orders[0], orders[1], "epoch 1 must reshuffle");
        assert_ne!(orders[1], orders[2], "epoch 2 must reshuffle");
    }

    #[test]
    #[should_panic(expected = "ragged vector set")]
    fn stream_static_epoch_rejects_ragged_rows() {
        // A ragged `vs` used to silently corrupt the gathered buffer in
        // release builds (d was derived from row 0 alone).
        let vs = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        let mut p = Sequential::new(2);
        let mut flat = Vec::new();
        stream_static_epoch(&mut p, 0, &vs, &mut flat, 1);
    }

    #[test]
    #[should_panic(expected = "2-unit order for 3 vectors")]
    fn stream_static_epoch_rejects_short_orders() {
        // An order shorter than `vs` used to be a debug-only assert —
        // release callers truncated the epoch instead of failing.
        let vs = vec![vec![0.0f32]; 3];
        let mut p = FixedOrder::new(vec![1, 0], "grab-retrain");
        let mut flat = Vec::new();
        stream_static_epoch(&mut p, 0, &vs, &mut flat, 1);
    }

    #[test]
    fn onestep_freezes_after_first_epoch() {
        let cfg = TrainConfig::default();
        let inner = super::grab_from_cfg(&cfg, 8, 2);
        let mut p = OneStepGraB::new(inner);
        let _e0 = p.epoch_order(0).to_vec();
        assert!(p.wants_grads());
        for pos in 0..8 {
            p.observe(pos, &[pos as f32, -(pos as f32)]);
        }
        p.epoch_end();
        assert!(!p.wants_grads());
        let e1 = p.epoch_order(1).to_vec();
        let e2 = p.epoch_order(2).to_vec();
        assert_eq!(e1, e2);
        assert_permutation(&e1).unwrap();
    }
}
