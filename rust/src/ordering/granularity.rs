//! Ordering granularity (paper §"On the granularity of example ordering").
//!
//! When per-example gradients are unavailable, the workaround is to fix
//! the data *within* groups and reorder the groups as coarse-grained
//! examples. That divides the effective n by the group size and, since
//! herding's statistical gain is O(n^{-1/3}), shrinks GraB's advantage —
//! which `grab exp granularity` measures. [`GroupedOrder`] wraps any inner
//! policy defined over n/gs groups: it expands the group permutation to an
//! example permutation (into a reused buffer, no per-call allocation) and
//! feeds the inner policy one *mean* gradient per group as a 1-row block.

use std::ops::Range;

use crate::ordering::{GradBlock, OrderPolicy};
use crate::tensor;

/// Coarse-granularity wrapper: orders groups of examples through an
/// inner policy and expands back to an example-level permutation.
pub struct GroupedOrder {
    inner: Box<dyn OrderPolicy>,
    /// Static partition: `members[g]` = dataset indices of group g.
    members: Vec<Vec<usize>>,
    n: usize,
    d: usize,
    /// Mean-gradient accumulator for the group currently streaming.
    acc: Vec<f32>,
    acc_count: usize,
    /// Group visit order for the current epoch (copy of inner's
    /// permutation, refreshed by [`OrderPolicy::epoch_order`]).
    group_order: Vec<usize>,
    /// Expanded example-level order handed to the trainer.
    expanded: Vec<usize>,
    groups_observed: usize,
}

impl GroupedOrder {
    /// Partition `n` units into ceil(n/group_size) contiguous groups and
    /// wrap `inner` (which must be built over that many groups).
    pub fn new(
        n: usize,
        d: usize,
        group_size: usize,
        inner: Box<dyn OrderPolicy>,
    ) -> GroupedOrder {
        assert!(group_size >= 1);
        let members: Vec<Vec<usize>> = (0..n)
            .step_by(group_size)
            .map(|start| (start..(start + group_size).min(n)).collect())
            .collect();
        GroupedOrder {
            inner,
            members,
            n,
            d,
            acc: vec![0.0; d],
            acc_count: 0,
            group_order: Vec::new(),
            expanded: Vec::new(),
            groups_observed: 0,
        }
    }

    /// Number of groups the unit range was partitioned into.
    pub fn num_groups(&self) -> usize {
        self.members.len()
    }
}

impl OrderPolicy for GroupedOrder {
    fn name(&self) -> &'static str {
        "grouped"
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        let go = self.inner.epoch_order(epoch);
        debug_assert_eq!(go.len(), self.members.len());
        self.group_order.clear();
        self.group_order.extend_from_slice(go);
        self.expanded.clear();
        for &g in &self.group_order {
            self.expanded.extend_from_slice(&self.members[g]);
        }
        &self.expanded
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        debug_assert_eq!(block.dim(), self.d);
        debug_assert_eq!(range.len(), block.rows());
        debug_assert!(range.end <= self.n);
        for row in block.iter_rows() {
            tensor::add_into(&mut self.acc, row);
            self.acc_count += 1;
            // Group boundary: the group being visited is group_order[k]
            // where k = number of complete groups so far. The last group
            // may be ragged; detect completion by member count.
            let k = self.groups_observed;
            let expected = self.members[self.group_order[k]].len();
            if self.acc_count == expected {
                tensor::scale(&mut self.acc, 1.0 / expected as f32);
                let mean = GradBlock::new(&self.acc, self.d);
                self.inner.observe_block(k..k + 1, &mean);
                tensor::zero(&mut self.acc);
                self.acc_count = 0;
                self.groups_observed += 1;
            }
        }
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.groups_observed,
            self.members.len(),
            "GroupedOrder epoch_end before all groups observed"
        );
        self.inner.epoch_end();
        self.groups_observed = 0;
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
            + self.d * std::mem::size_of::<f32>()
            + self.n * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        self.inner.wants_grads()
    }

    fn transport_stats(
        &self,
    ) -> Option<crate::ordering::transport::TransportStats> {
        self.inner.transport_stats()
    }

    fn topology_log(&self) -> Option<&[crate::ordering::Topology]> {
        // The inner policy's shard plans are over groups, but the
        // weights/generation record is what replay needs — forward it
        // like the transport counters above.
        self.inner.topology_log()
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // The group partition and expansion are pure functions of
        // (n, group_size); only the inner policy's state matters.
        self.inner.save_state()
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.inner.restore_state(bytes)
    }
}

/// Convenience: GraB over groups of `group_size` (the paper's
/// batch-granularity fallback, with group_size = the microbatch size).
pub fn grouped_grab(n: usize, d: usize, group_size: usize)
    -> GroupedOrder {
    let groups = n.div_ceil(group_size);
    let inner = crate::ordering::GraBOrder::new(
        groups,
        d,
        Box::new(crate::balance::DeterministicBalancer),
    );
    GroupedOrder::new(n, d, group_size, Box::new(inner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, assert_permutation, gen};

    #[test]
    fn expands_groups_to_examples() {
        let mut p = grouped_grab(10, 2, 4); // groups {0-3},{4-7},{8,9}
        let order = p.epoch_order(0).to_vec();
        assert_permutation(&order).unwrap();
        // First epoch: inner identity => example order is identity.
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn produces_valid_permutations_over_epochs() {
        prop::forall("grouped permutations", 16, |rng| {
            let n = 1 + rng.gen_range(60) as usize;
            let gs = 1 + rng.gen_range(7) as usize;
            let d = 1 + rng.gen_range(8) as usize;
            let mut p = grouped_grab(n, d, gs);
            for _ in 0..3 {
                let order = p.epoch_order(0).to_vec();
                assert_permutation(&order)?;
                for (pos, _) in order.iter().enumerate() {
                    let g = gen::gauss_vec(rng, d, 1.0);
                    p.observe(pos, &g);
                }
                p.epoch_end();
            }
            Ok(())
        });
    }

    #[test]
    fn group_size_one_matches_plain_grab() {
        // gs=1 must reduce to exactly per-example GraB.
        let n = 32;
        let d = 4;
        let mut rng = crate::util::rng::Rng::new(0);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| gen::gauss_vec(&mut rng, d, 1.0)).collect();
        let mut grouped = grouped_grab(n, d, 1);
        let mut plain = crate::ordering::GraBOrder::new(
            n, d, Box::new(crate::balance::DeterministicBalancer));
        for _ in 0..3 {
            let go = grouped.epoch_order(0).to_vec();
            let po = plain.epoch_order(0).to_vec();
            assert_eq!(go, po);
            for pos in 0..n {
                grouped.observe(pos, &grads[go[pos]]);
                plain.observe(pos, &grads[po[pos]]);
            }
            grouped.epoch_end();
            plain.epoch_end();
        }
    }

    #[test]
    fn block_streaming_spans_group_boundaries() {
        // Blocks that straddle group boundaries must accumulate means
        // exactly like per-example streaming.
        let n = 24;
        let gs = 4;
        let d = 3;
        let mut rng = crate::util::rng::Rng::new(7);
        let flat: Vec<f32> =
            (0..n * d).map(|_| rng.gauss() as f32).collect();
        let mut per_row = grouped_grab(n, d, gs);
        let mut blocked = grouped_grab(n, d, gs);
        for _ in 0..2 {
            let a = per_row.epoch_order(0).to_vec();
            let b = blocked.epoch_order(0).to_vec();
            assert_eq!(a, b);
            for pos in 0..n {
                per_row.observe(pos, &flat[pos * d..(pos + 1) * d]);
            }
            // Odd-sized blocks (5 rows) straddle the 4-wide groups.
            let mut pos = 0;
            while pos < n {
                let end = (pos + 5).min(n);
                blocked.observe_block(
                    pos..end,
                    &GradBlock::new(&flat[pos * d..end * d], d),
                );
                pos = end;
            }
            per_row.epoch_end();
            blocked.epoch_end();
        }
        assert_eq!(
            per_row.epoch_order(0).to_vec(),
            blocked.epoch_order(0).to_vec()
        );
    }

    #[test]
    fn members_stay_adjacent() {
        // Units of one group remain consecutive in every epoch's order.
        let n = 24;
        let gs = 4;
        let d = 3;
        let mut p = grouped_grab(n, d, gs);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..3 {
            let order = p.epoch_order(0).to_vec();
            for chunk in order.chunks(gs) {
                let g0 = chunk[0] / gs;
                assert!(chunk.iter().all(|&i| i / gs == g0),
                        "group split: {chunk:?}");
            }
            for pos in 0..n {
                let g = gen::gauss_vec(&mut rng, d, 1.0);
                p.observe(pos, &g);
            }
            p.epoch_end();
        }
    }
}
