//! Ordering granularity (paper §"On the granularity of example ordering").
//!
//! When per-example gradients are unavailable, the workaround is to fix
//! the data *within* groups and reorder the groups as coarse-grained
//! examples. That divides the effective n by the group size and, since
//! herding's statistical gain is O(n^{-1/3}), shrinks GraB's advantage —
//! which `grab exp granularity` measures. [`GroupedOrder`] wraps any inner
//! policy defined over n/gs groups: it expands the group permutation to an
//! example permutation and feeds the inner policy one *mean* gradient per
//! group.

use crate::ordering::OrderPolicy;
use crate::tensor;

pub struct GroupedOrder {
    inner: Box<dyn OrderPolicy>,
    /// Static partition: members[g] = dataset indices of group g.
    members: Vec<Vec<usize>>,
    group_size: usize,
    n: usize,
    d: usize,
    /// Mean-gradient accumulator for the group currently streaming.
    acc: Vec<f32>,
    acc_count: usize,
    /// Group visit order for the current epoch (inner's permutation).
    group_order: Vec<usize>,
    groups_observed: usize,
}

impl GroupedOrder {
    /// Partition `n` units into ceil(n/group_size) contiguous groups and
    /// wrap `inner` (which must be built over that many groups).
    pub fn new(
        n: usize,
        d: usize,
        group_size: usize,
        inner: Box<dyn OrderPolicy>,
    ) -> GroupedOrder {
        assert!(group_size >= 1);
        let members: Vec<Vec<usize>> = (0..n)
            .step_by(group_size)
            .map(|start| (start..(start + group_size).min(n)).collect())
            .collect();
        GroupedOrder {
            inner,
            members,
            group_size,
            n,
            d,
            acc: vec![0.0; d],
            acc_count: 0,
            group_order: Vec::new(),
            groups_observed: 0,
        }
    }

    pub fn num_groups(&self) -> usize {
        self.members.len()
    }
}

impl OrderPolicy for GroupedOrder {
    fn name(&self) -> &'static str {
        "grouped"
    }

    fn epoch_order(&mut self, epoch: usize) -> Vec<usize> {
        self.group_order = self.inner.epoch_order(epoch);
        debug_assert_eq!(self.group_order.len(), self.members.len());
        let mut out = Vec::with_capacity(self.n);
        for &g in &self.group_order {
            out.extend_from_slice(&self.members[g]);
        }
        out
    }

    fn observe(&mut self, pos: usize, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.d);
        tensor::add_into(&mut self.acc, grad);
        self.acc_count += 1;
        // Group boundary: the group being visited is group_order[k] where
        // k = number of complete groups so far. The last group may be
        // ragged; detect completion by member count.
        let k = self.groups_observed;
        let expected = self.members[self.group_order[k]].len();
        debug_assert!(pos < self.n);
        if self.acc_count == expected {
            tensor::scale(&mut self.acc, 1.0 / expected as f32);
            let acc = std::mem::replace(&mut self.acc, vec![0.0; self.d]);
            self.inner.observe(k, &acc);
            self.acc_count = 0;
            self.groups_observed += 1;
        }
    }

    fn epoch_end(&mut self) {
        assert_eq!(
            self.groups_observed,
            self.members.len(),
            "GroupedOrder epoch_end before all groups observed"
        );
        self.inner.epoch_end();
        self.groups_observed = 0;
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
            + self.d * std::mem::size_of::<f32>()
            + self.n * std::mem::size_of::<usize>()
    }

    fn wants_grads(&self) -> bool {
        self.inner.wants_grads()
    }
}

/// Convenience: GraB over groups of `group_size` (the paper's
/// batch-granularity fallback, with group_size = the microbatch size).
pub fn grouped_grab(n: usize, d: usize, group_size: usize)
    -> GroupedOrder {
    let groups = n.div_ceil(group_size);
    let inner = crate::ordering::GraBOrder::new(
        groups,
        d,
        Box::new(crate::balance::DeterministicBalancer),
    );
    GroupedOrder::new(n, d, group_size, Box::new(inner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, assert_permutation, gen};

    #[test]
    fn expands_groups_to_examples() {
        let mut p = grouped_grab(10, 2, 4); // groups {0-3},{4-7},{8,9}
        let order = p.epoch_order(0);
        assert_permutation(&order).unwrap();
        // First epoch: inner identity => example order is identity.
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn produces_valid_permutations_over_epochs() {
        prop::forall("grouped permutations", 16, |rng| {
            let n = 1 + rng.gen_range(60) as usize;
            let gs = 1 + rng.gen_range(7) as usize;
            let d = 1 + rng.gen_range(8) as usize;
            let mut p = grouped_grab(n, d, gs);
            for _ in 0..3 {
                let order = p.epoch_order(0);
                assert_permutation(&order)?;
                for (pos, _) in order.iter().enumerate() {
                    let g = gen::gauss_vec(rng, d, 1.0);
                    p.observe(pos, &g);
                }
                p.epoch_end();
            }
            Ok(())
        });
    }

    #[test]
    fn group_size_one_matches_plain_grab() {
        // gs=1 must reduce to exactly per-example GraB.
        let n = 32;
        let d = 4;
        let mut rng = crate::util::rng::Rng::new(0);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| gen::gauss_vec(&mut rng, d, 1.0)).collect();
        let mut grouped = grouped_grab(n, d, 1);
        let mut plain = crate::ordering::GraBOrder::new(
            n, d, Box::new(crate::balance::DeterministicBalancer));
        for _ in 0..3 {
            let go = grouped.epoch_order(0);
            let po = plain.epoch_order(0);
            assert_eq!(go, po);
            for pos in 0..n {
                grouped.observe(pos, &grads[go[pos]]);
                plain.observe(pos, &grads[po[pos]]);
            }
            grouped.epoch_end();
            plain.epoch_end();
        }
    }

    #[test]
    fn members_stay_adjacent() {
        // Units of one group remain consecutive in every epoch's order.
        let n = 24;
        let gs = 4;
        let d = 3;
        let mut p = grouped_grab(n, d, gs);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..3 {
            let order = p.epoch_order(0);
            for chunk in order.chunks(gs) {
                let g0 = chunk[0] / gs;
                assert!(chunk.iter().all(|&i| i / gs == g0),
                        "group split: {chunk:?}");
            }
            for pos in 0..n {
                let g = gen::gauss_vec(&mut rng, d, 1.0);
                p.observe(pos, &g);
            }
            p.epoch_end();
        }
    }
}
