//! Streaming online ordering over a bounded sliding reservoir.
//!
//! GraB's framing is explicitly *online* — Algorithm 4 balances
//! gradients as they stream by — yet a trainer built around
//! [`OrderPolicy`] sweeps a fixed dataset in whole epochs.
//! [`StreamOrder`] closes that gap: it pair-balances over a bounded
//! reservoir of *live* examples whose membership changes mid-run.
//! External units (dataset row ids, request ids, …) are admitted and
//! retired through [`StreamOrder::admit`] / [`StreamOrder::retire`];
//! the events queue up and are applied at the next *window boundary*
//! (the streaming analogue of an epoch boundary), where
//! [`ReservoirPlan::advance`] re-plans the unit set — the set-level
//! generalization of the elastic [`Topology`](super::Topology)
//! machinery, which re-plans unit *ranges* per epoch.
//!
//! # Reservoir model
//!
//! Live units occupy contiguous *slots* `0..n`; the inner balancer
//! (a [`PairBalance`], or a [`ShardedOrder`] for the distributed
//! variant) only ever sees slots. The boundary relabeling is
//! *slot-stable*: survivors keep their slot, admits back-fill the
//! lowest freed slots (inheriting the departed unit's position in the
//! already-constructed next order), overflow admits append new slots,
//! and only a net shrink compacts slots downward. The payoff is that a
//! **count-neutral** boundary — every admit matched by a retire or
//! eviction — leaves the inner balancer completely untouched, so the
//! balancing stream (and hence channel/TCP bit-equality, determinism
//! contract 9) is independent of membership churn. When the count does
//! change, the unsharded balancer is rebuilt over the new slot range
//! and re-seeded with the surviving order (appended slots at the
//! back); the sharded balancer re-links at the new size and restarts
//! from the identity order — the documented graceful degradation,
//! since a merged order cannot be transplanted across shard layouts.
//!
//! # Carry-out
//!
//! PairBalance zeroes its signed accumulator at every boundary, so the
//! cross-window herding state lives here: after each window the
//! reservoir recomputes the survivor accumulator `Σ ε_t g_t` from the
//! balancer's per-position signs ([`PairBalance::last_epoch_signs`])
//! and its per-slot gradient cache, and every departing unit's signed
//! contribution is subtracted out — so the reported bound
//! ([`StreamStats::carry_inf`]) stays well-defined on the survivors.
//!
//! # Determinism (contract 9, `docs/determinism.md`)
//!
//! A static schedule (no admits, no retires) is bit-for-bit
//! [`PairBalance`]: the inner balancer is never touched between
//! windows. A *frozen* admit/retire schedule replays bit-for-bit —
//! [`ReservoirPlan::advance`] and [`DriftPlan`] are pure in their
//! inputs, and nothing on the boundary path reads a clock or an
//! address.

use std::fmt;
use std::ops::Range;

use crate::herding::herding_bound;
use crate::ordering::topology::{ReservoirPlan, ReservoirStep};
use crate::ordering::{
    transport, GradBlock, OrderPolicy, PairBalance, ShardedOrder, Topology,
};
use crate::tensor::norm_inf;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// A failed [`StreamOrder::admit`] / [`StreamOrder::retire`] call. The
/// reservoir state is unchanged on every error path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamError {
    /// The admitted unit's gradient dimension does not match the
    /// reservoir's.
    DimMismatch {
        /// The offending unit id.
        unit: u64,
        /// The dimension the caller declared.
        got: usize,
        /// The reservoir's fixed dimension.
        want: usize,
    },
    /// Admit of a unit that is already live in the reservoir.
    AlreadyLive(u64),
    /// The unit already has an admit or retire queued for the next
    /// boundary (re-admitting a retiring unit within one window is
    /// rejected as ambiguous).
    AlreadyPending(u64),
    /// Retire of a unit that is not live (never admitted, already
    /// departed, or still pending admission).
    NotLive(u64),
    /// More admits queued in one window than the reservoir's capacity
    /// — applying them would evict same-boundary admits, which the
    /// FIFO eviction rule forbids.
    WindowOverflow {
        /// The reservoir capacity the admit queue collided with.
        capacity: usize,
    },
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StreamError::DimMismatch { unit, got, want } => write!(
                f,
                "unit {unit} has gradient dimension {got}, reservoir \
                 expects {want}"
            ),
            StreamError::AlreadyLive(u) => {
                write!(f, "unit {u} is already live in the reservoir")
            }
            StreamError::AlreadyPending(u) => write!(
                f,
                "unit {u} already has a membership event queued for the \
                 next window boundary"
            ),
            StreamError::NotLive(u) => {
                write!(f, "unit {u} is not live in the reservoir")
            }
            StreamError::WindowOverflow { capacity } => write!(
                f,
                "more than {capacity} admits queued in one window \
                 (reservoir capacity)"
            ),
        }
    }
}

impl std::error::Error for StreamError {}

/// Lifetime counters and per-window diagnostics of a [`StreamOrder`],
/// surfaced through the daemon's `/metrics` and the `exp stream` CSV.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StreamStats {
    /// Windows completed (boundaries crossed).
    pub windows: u64,
    /// Units admitted across all boundaries.
    pub admits: u64,
    /// Units explicitly retired.
    pub retires: u64,
    /// Units evicted by FIFO overflow.
    pub evictions: u64,
    /// Boundaries whose membership change resized the reservoir and
    /// forced a balancer rebuild (sharded: a re-link).
    pub replans: u64,
    /// Herding bound `max_k ‖Σ_{t<k} (g_t − ḡ)‖∞` of the most recently
    /// completed window, over the cached gradients in visit order.
    pub last_window_inf: f32,
    /// `‖Σ ε_t g_t‖∞` over the *survivors* of the last boundary —
    /// the signed accumulator after departing units carried their
    /// contribution out. Unsharded reservoirs only (worker signs never
    /// leave the shards); 0 for sharded.
    pub carry_inf: f32,
}

/// The inner balancer a [`StreamOrder`] delegates slot ordering to.
enum Backend {
    /// Single-process pair balancing.
    Pair(PairBalance),
    /// Distributed pair balancing over shard transports. `relink`
    /// rebuilds the coordinator when a boundary resizes the reservoir;
    /// `None` forbids resizing (fixed daemon-leased links).
    Sharded {
        inner: ShardedOrder,
        relink: Option<StreamRelink>,
    },
}

/// Rebuilds a sharded backend at a new reservoir size: called with
/// `(n, generation)` at every resizing boundary and must return a
/// coordinator over exactly `n` units. The fresh coordinator starts
/// from the identity order — a merged order cannot be transplanted
/// across shard layouts (see the module docs on graceful degradation).
pub type StreamRelink =
    Box<dyn FnMut(usize, u64) -> crate::Result<ShardedOrder> + Send>;

/// Streaming pair-balancing policy over a bounded sliding reservoir —
/// see the module docs for the model. Implements [`OrderPolicy`] so
/// one *window* runs exactly like one epoch (`epoch_order` →
/// `observe_block`… → `epoch_end`); queued [`StreamOrder::admit`] /
/// [`StreamOrder::retire`] events are applied inside `epoch_end`.
pub struct StreamOrder {
    d: usize,
    capacity: usize,
    backend: Backend,
    /// The live membership (slot → unit).
    plan: ReservoirPlan,
    /// Every boundary's plan, in order — the membership analogue of
    /// the elastic coordinator's topology log: together with the run
    /// seed it makes a streamed run replayable.
    log: Vec<ReservoirPlan>,
    pending_admits: Vec<u64>,
    pending_retires: Vec<u64>,
    /// Last observed gradient of each slot's unit (window-fresh).
    grads: Vec<Vec<f32>>,
    /// Signed survivor accumulator `Σ ε_t g_t` (unsharded only).
    s_live: Vec<f32>,
    /// The order being followed this window, captured at
    /// `epoch_order` so `observe_block` can cache rows by slot.
    order_cache: Vec<usize>,
    /// Windows completed so far (== the next window's epoch index).
    windows: usize,
    stats: StreamStats,
    /// Gather scratch reused across [`StreamOrder::run_window`] calls.
    scratch: Vec<f32>,
}

impl StreamOrder {
    /// An empty reservoir of `capacity` slots over gradient dimension
    /// `d`; fill it with [`StreamOrder::admit`] before the first
    /// window.
    pub fn new(capacity: usize, d: usize) -> StreamOrder {
        StreamOrder::with_units(capacity, d, &[])
    }

    /// The static trainer configuration: the reservoir *is* the
    /// dataset — units `0..n` fill `n` slots of an `n`-capacity
    /// reservoir, one window per epoch. With no membership events this
    /// is bit-for-bit [`PairBalance`] (contract 9's static half).
    pub fn prefilled(n: usize, d: usize) -> StreamOrder {
        let units: Vec<u64> = (0..n as u64).collect();
        StreamOrder::with_units(n.max(1), d, &units)
    }

    /// A reservoir of `capacity` slots pre-filled with `units`
    /// (distinct, at most `capacity` of them).
    pub fn with_units(
        capacity: usize,
        d: usize,
        units: &[u64],
    ) -> StreamOrder {
        assert!(capacity >= 1, "reservoir capacity must be positive");
        assert!(d >= 1, "gradient dimension must be positive");
        assert!(
            units.len() <= capacity,
            "initial fill ({}) exceeds reservoir capacity ({capacity})",
            units.len()
        );
        let plan = ReservoirPlan::initial(units);
        let n = plan.len();
        StreamOrder {
            d,
            capacity,
            backend: Backend::Pair(PairBalance::new(n, d)),
            log: vec![plan.clone()],
            plan,
            pending_admits: Vec::new(),
            pending_retires: Vec::new(),
            grads: vec![vec![0.0; d]; n],
            s_live: vec![0.0; d],
            order_cache: Vec::new(),
            windows: 0,
            stats: StreamStats::default(),
            scratch: Vec::new(),
        }
    }

    /// A sharded reservoir delegating to a pre-built coordinator
    /// (`inner` must span exactly `units.len()` units). `relink`
    /// rebuilds the coordinator at resizing boundaries; pass `None`
    /// to forbid resizing — count-neutral boundaries then still work
    /// over fixed links (the daemon's leased-socket configuration),
    /// but a resizing boundary panics.
    pub fn sharded(
        capacity: usize,
        d: usize,
        units: &[u64],
        inner: ShardedOrder,
        relink: Option<StreamRelink>,
    ) -> StreamOrder {
        let mut s = StreamOrder::with_units(capacity, d, units);
        s.backend = Backend::Sharded { inner, relink };
        s
    }

    /// A sharded reservoir over in-process channel transports with
    /// `shards` equal-weight workers of queue depth `depth`, re-linked
    /// automatically at resizing boundaries.
    pub fn sharded_channel(
        capacity: usize,
        d: usize,
        units: &[u64],
        shards: usize,
        depth: usize,
    ) -> StreamOrder {
        assert!(shards >= 1, "need at least one shard");
        let link = move |n: usize, generation: u64| {
            let topology =
                Topology::plan(n, generation, &vec![1u64; shards]);
            let links =
                transport::spawn_channel_shards(&topology.sizes, d, depth);
            Ok(ShardedOrder::from_links(
                n, d, topology, links, "channel", None,
            ))
        };
        let mut relink: StreamRelink = Box::new(link);
        let inner = relink(units.len(), 0)
            .expect("channel shard spawn cannot fail");
        StreamOrder::sharded(capacity, d, units, inner, Some(relink))
    }

    /// A sharded reservoir over loopback TCP with `shards`
    /// equal-weight workers, re-linked (fresh loopback pool + fresh
    /// connections) at resizing boundaries.
    pub fn sharded_tcp_loopback(
        capacity: usize,
        d: usize,
        units: &[u64],
        shards: usize,
    ) -> crate::Result<StreamOrder> {
        assert!(shards >= 1, "need at least one shard");
        let link = move |n: usize,
                         generation: u64|
              -> crate::Result<ShardedOrder> {
            let topology =
                Topology::plan(n, generation, &vec![1u64; shards]);
            let addr = transport::tcp::spawn_loopback(shards)?;
            let links = transport::tcp::connect_shards(
                addr,
                &topology.sizes,
                d,
                generation,
                transport::tcp::default_read_timeout(),
            )?;
            Ok(ShardedOrder::from_links(
                n, d, topology, links, "tcp", None,
            ))
        };
        let mut relink: StreamRelink = Box::new(link);
        let inner = relink(units.len(), 0)?;
        Ok(StreamOrder::sharded(capacity, d, units, inner, Some(relink)))
    }

    /// Queue `unit` (gradient dimension `d`) for admission at the next
    /// window boundary. The reservoir is unchanged until then.
    pub fn admit(&mut self, unit: u64, d: usize) -> Result<(), StreamError> {
        if d != self.d {
            return Err(StreamError::DimMismatch {
                unit,
                got: d,
                want: self.d,
            });
        }
        if self.plan.slot_of(unit).is_some() {
            return Err(StreamError::AlreadyLive(unit));
        }
        if self.pending_admits.contains(&unit)
            || self.pending_retires.contains(&unit)
        {
            return Err(StreamError::AlreadyPending(unit));
        }
        if self.pending_admits.len() == self.capacity {
            return Err(StreamError::WindowOverflow {
                capacity: self.capacity,
            });
        }
        self.pending_admits.push(unit);
        Ok(())
    }

    /// Queue `unit` for retirement at the next window boundary.
    pub fn retire(&mut self, unit: u64) -> Result<(), StreamError> {
        if self.plan.slot_of(unit).is_none() {
            return Err(StreamError::NotLive(unit));
        }
        if self.pending_retires.contains(&unit) {
            return Err(StreamError::AlreadyPending(unit));
        }
        self.pending_retires.push(unit);
        Ok(())
    }

    /// The live unit ids, by slot.
    pub fn live_units(&self) -> &[u64] {
        &self.plan.units
    }

    /// Number of live units (the inner balancer's `n`).
    pub fn len(&self) -> usize {
        self.plan.len()
    }

    /// Whether the reservoir is empty.
    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    /// The reservoir's slot capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Windows completed so far — also the epoch index of the *next*
    /// window.
    pub fn windows(&self) -> usize {
        self.windows
    }

    /// Lifetime counters and last-window diagnostics.
    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// The current membership plan.
    pub fn current_plan(&self) -> &ReservoirPlan {
        &self.plan
    }

    /// Every boundary's membership plan, oldest first (entry 0 is the
    /// initial fill) — with the run seed this replays the whole
    /// streamed run (contract 9).
    pub fn plan_log(&self) -> &[ReservoirPlan] {
        &self.log
    }

    /// Run one complete window: gather each live unit's gradient
    /// through `grads(unit, out)` in visit order, stream the blocks
    /// through the balancer, and cross the boundary (applying queued
    /// membership events). Returns the ordering-overhead seconds, like
    /// [`stream_static_epoch`](super::stream_static_epoch) — the
    /// gather itself is untimed.
    pub fn run_window(
        &mut self,
        grads: &mut dyn FnMut(u64, &mut [f32]),
        block: usize,
    ) -> f64 {
        assert!(block > 0, "block must be positive");
        let n = self.plan.len();
        let d = self.d;
        let epoch = self.windows;
        let order: Vec<usize> = self.epoch_order(epoch).to_vec();
        let mut flat = std::mem::take(&mut self.scratch);
        flat.clear();
        flat.resize(n * d, 0.0);
        for (pos, &slot) in order.iter().enumerate() {
            let unit = self.plan.units[slot];
            grads(unit, &mut flat[pos * d..(pos + 1) * d]);
        }
        let sw = Stopwatch::start();
        let mut pos = 0;
        while pos < n {
            let rows = block.min(n - pos);
            let b = GradBlock::new(&flat[pos * d..(pos + rows) * d], d);
            self.observe_block(pos..pos + rows, &b);
            pos += rows;
        }
        self.epoch_end();
        let secs = sw.secs();
        self.scratch = flat;
        secs
    }

    /// Run one window driven by a [`DriftPlan`]: queue the plan's
    /// events for this window index, then [`StreamOrder::run_window`]
    /// with the plan's drifting gradient generator. `next_unit` is the
    /// monotone fresh-unit counter, advanced by the admits.
    pub fn drive_window(
        &mut self,
        drift: &DriftPlan,
        next_unit: &mut u64,
        block: usize,
    ) -> f64 {
        let live = self.plan.units.clone();
        let ev = drift.events(self.windows, &live, next_unit);
        for &u in &ev.admits {
            self.admit(u, self.d)
                .expect("drift plan admitted an invalid unit");
        }
        for &u in &ev.retires {
            self.retire(u).expect("drift plan retired an invalid unit");
        }
        let window = self.windows;
        self.run_window(&mut |unit, out| drift.grad(unit, window, out), block)
    }

    /// Cross the window boundary: apply queued events, carry departing
    /// contributions out of the survivor accumulator, relabel the
    /// per-slot caches, and rebuild the balancer if the count changed.
    /// `signs_by_slot` are the completed window's per-slot signs
    /// (zeros when unknown).
    fn apply_boundary(&mut self, signs_by_slot: &[i8]) {
        let admits = std::mem::take(&mut self.pending_admits);
        let retires = std::mem::take(&mut self.pending_retires);
        let step = self.plan.advance(&admits, &retires, self.capacity);
        self.stats.admits += step.plan.admitted.len() as u64;
        self.stats.retires += step.plan.retired.len() as u64;
        self.stats.evictions += step.plan.evicted.len() as u64;
        if step.changed {
            self.carry_out_departed(&step, signs_by_slot);
            self.remap_caches(&step);
            if step.resized {
                self.stats.replans += 1;
                self.rebuild_backend(&step);
            }
        }
        self.plan = step.plan;
        self.log.push(self.plan.clone());
        self.stats.carry_inf = norm_inf(&self.s_live);
    }

    /// Subtract every departing unit's signed contribution from the
    /// survivor accumulator (unsharded only — worker signs never leave
    /// the shards).
    fn carry_out_departed(&mut self, step: &ReservoirStep, signs: &[i8]) {
        if !matches!(self.backend, Backend::Pair(_)) {
            return;
        }
        for unit in step.plan.retired.iter().chain(&step.plan.evicted) {
            let Some(old_slot) = self.plan.slot_of(*unit) else {
                continue;
            };
            let sign = f32::from(signs.get(old_slot).copied().unwrap_or(0));
            if sign == 0.0 {
                continue;
            }
            for (acc, &g) in
                self.s_live.iter_mut().zip(&self.grads[old_slot])
            {
                *acc -= sign * g;
            }
        }
    }

    /// Relabel the per-slot gradient cache to the new slots; admitted
    /// units start cold (zero cache).
    fn remap_caches(&mut self, step: &ReservoirStep) {
        let new_n = step.plan.len();
        let mut grads = vec![vec![0.0f32; self.d]; new_n];
        for (old_slot, &m) in step.slot_map.iter().enumerate() {
            let Some(new_slot) = m else { continue };
            // A back-filled slot maps Some but carries a *new* unit —
            // only relabel the cache when the unit actually survived.
            if step.plan.units[new_slot] == self.plan.units[old_slot] {
                std::mem::swap(
                    &mut grads[new_slot],
                    &mut self.grads[old_slot],
                );
            }
        }
        self.grads = grads;
    }

    /// Rebuild the balancer over the resized slot range. Unsharded:
    /// a fresh `PairBalance` (same kernel tier) re-seeded with the
    /// surviving order, appended slots at the back. Sharded: a fresh
    /// re-link at the new size — the order resets to identity
    /// (documented graceful degradation).
    fn rebuild_backend(&mut self, step: &ReservoirStep) {
        let new_n = step.plan.len();
        match &mut self.backend {
            Backend::Pair(p) => {
                let mut order = Vec::with_capacity(new_n);
                for &old_slot in p.epoch_order(0) {
                    if let Some(new_slot) = step.slot_map[old_slot] {
                        order.push(new_slot);
                    }
                }
                order.extend_from_slice(&step.appended);
                let mut fresh =
                    PairBalance::with_kernel(new_n, self.d, p.kernel());
                let ok = fresh.restore_order(&order);
                assert!(ok, "remapped survivor order must be a permutation");
                *p = fresh;
            }
            Backend::Sharded { inner, relink } => {
                let relink = relink.as_mut().unwrap_or_else(|| {
                    panic!(
                        "reservoir resized to {new_n} units over fixed \
                         shard links (admit/retire counts must match \
                         per window when no relink is configured)"
                    )
                });
                *inner = relink(new_n, step.plan.generation)
                    .expect("stream reservoir re-link failed");
            }
        }
    }
}

impl OrderPolicy for StreamOrder {
    fn name(&self) -> &'static str {
        match self.backend {
            Backend::Pair(_) => "stream",
            Backend::Sharded { .. } => "stream-cd",
        }
    }

    fn epoch_order(&mut self, epoch: usize) -> &[usize] {
        let inner: &mut dyn OrderPolicy = match &mut self.backend {
            Backend::Pair(p) => p,
            Backend::Sharded { inner, .. } => inner,
        };
        let order = inner.epoch_order(epoch);
        self.order_cache.clear();
        self.order_cache.extend_from_slice(order);
        &self.order_cache
    }

    fn observe_block(&mut self, range: Range<usize>, block: &GradBlock) {
        assert_eq!(
            self.order_cache.len(),
            self.plan.len(),
            "observe_block before epoch_order on a StreamOrder window"
        );
        for (i, row) in block.iter_rows().enumerate() {
            let slot = self.order_cache[range.start + i];
            self.grads[slot].copy_from_slice(row);
        }
        match &mut self.backend {
            Backend::Pair(p) => p.observe_block(range, block),
            Backend::Sharded { inner, .. } => {
                inner.observe_block(range, block)
            }
        }
    }

    fn epoch_end(&mut self) {
        let n = self.plan.len();
        let have_order = self.order_cache.len() == n && n > 0;
        match &mut self.backend {
            Backend::Pair(p) => p.epoch_end(),
            Backend::Sharded { inner, .. } => inner.epoch_end(),
        }
        self.windows += 1;
        self.stats.windows += 1;
        let mut signs_by_slot = vec![0i8; n];
        if have_order {
            if let Backend::Pair(p) = &self.backend {
                let signs = p.last_epoch_signs();
                // Recompute the survivor accumulator Σ ε_t g_t for the
                // completed window in visit order.
                self.s_live.iter_mut().for_each(|v| *v = 0.0);
                for (pos, &slot) in self.order_cache.iter().enumerate() {
                    signs_by_slot[slot] = signs[pos];
                    let sign = f32::from(signs[pos]);
                    if sign == 0.0 {
                        continue;
                    }
                    for (acc, &g) in
                        self.s_live.iter_mut().zip(&self.grads[slot])
                    {
                        *acc += sign * g;
                    }
                }
            }
            let (inf, _two) =
                herding_bound(&self.grads, &self.order_cache);
            self.stats.last_window_inf = inf;
        }
        self.apply_boundary(&signs_by_slot);
        self.order_cache.clear();
    }

    fn state_bytes(&self) -> usize {
        let inner = match &self.backend {
            Backend::Pair(p) => OrderPolicy::state_bytes(p),
            Backend::Sharded { inner, .. } => inner.state_bytes(),
        };
        // Membership (unit + seq per slot) + per-slot gradient cache +
        // the survivor accumulator.
        inner
            + self.plan.len() * 2 * std::mem::size_of::<u64>()
            + self.plan.len() * self.d * std::mem::size_of::<f32>()
            + self.d * std::mem::size_of::<f32>()
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn transport_stats(&self) -> Option<transport::TransportStats> {
        match &self.backend {
            Backend::Pair(_) => None,
            Backend::Sharded { inner, .. } => inner.transport_stats(),
        }
    }

    fn topology_log(&self) -> Option<&[Topology]> {
        match &self.backend {
            Backend::Pair(_) => None,
            Backend::Sharded { inner, .. } => inner.topology_log(),
        }
    }

    fn save_state(&mut self) -> Option<Vec<u8>> {
        // Checkpointing covers the static trainer configuration only:
        // a reservoir with live membership history cannot be rebuilt
        // from config alone, so it refuses rather than lie.
        if self.plan.generation > 0
            || !self.pending_admits.is_empty()
            || !self.pending_retires.is_empty()
        {
            return None;
        }
        match &mut self.backend {
            Backend::Pair(p) => p.save_state(),
            Backend::Sharded { inner, .. } => inner.save_state(),
        }
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        if self.plan.generation > 0 {
            return Err(
                "streamed reservoir with membership events is not \
                 checkpointable"
                    .to_string(),
            );
        }
        match &mut self.backend {
            Backend::Pair(p) => p.restore_state(bytes),
            Backend::Sharded { inner, .. } => inner.restore_state(bytes),
        }
    }

    fn restore_order(&mut self, order: &[usize]) -> bool {
        self.order_cache.clear();
        match &mut self.backend {
            Backend::Pair(p) => p.restore_order(order),
            Backend::Sharded { inner, .. } => inner.restore_order(order),
        }
    }
}

/// The membership events a [`DriftPlan`] emits for one window.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StreamEvents {
    /// Fresh units to admit (monotone ids from the plan's counter).
    pub admits: Vec<u64>,
    /// Live units to retire.
    pub retires: Vec<u64>,
}

/// Seeded drift injection for streaming runs — the membership-churn
/// analogue of the fault-injection transport: distribution shift,
/// burst admits, and mass retirements, all pure functions of
/// `(seed, window, live set)` so a frozen schedule replays bit-for-bit
/// (contract 9) and every degradation scenario is reproducible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftPlan {
    /// Seed for retirement sampling and gradient generation.
    pub seed: u64,
    /// Fresh units admitted every window.
    pub admit_rate: usize,
    /// Live units retired (sampled without replacement) every window.
    pub retire_rate: usize,
    /// Every `burst_every`-th window additionally admits
    /// `burst_size` units (0 disables bursts).
    pub burst_every: usize,
    /// Extra admits on a burst window.
    pub burst_size: usize,
    /// Every `mass_retire_every`-th window (>0) retires half the live
    /// set (0 disables mass retirements).
    pub mass_retire_every: usize,
    /// Distribution shift: each unit's gradient drifts by
    /// `shift_per_window × window` along a fixed seeded direction.
    pub shift_per_window: f32,
}

impl DriftPlan {
    /// Steady churn: `admit_rate` fresh units per window, FIFO
    /// eviction does the retiring. Keeps the live count constant once
    /// the reservoir is full — the daemon's count-neutral schedule.
    pub fn steady(seed: u64, admit_rate: usize) -> DriftPlan {
        DriftPlan {
            seed,
            admit_rate,
            retire_rate: 0,
            burst_every: 0,
            burst_size: 0,
            mass_retire_every: 0,
            shift_per_window: 0.0,
        }
    }

    /// Steady churn with explicit random retirements.
    pub fn churn(
        seed: u64,
        admit_rate: usize,
        retire_rate: usize,
    ) -> DriftPlan {
        DriftPlan {
            retire_rate,
            ..DriftPlan::steady(seed, admit_rate)
        }
    }

    /// Steady churn with periodic admit bursts.
    pub fn bursty(
        seed: u64,
        admit_rate: usize,
        burst_every: usize,
        burst_size: usize,
    ) -> DriftPlan {
        DriftPlan {
            burst_every,
            burst_size,
            ..DriftPlan::steady(seed, admit_rate)
        }
    }

    /// The membership events for window `window` given the live set.
    /// `next_unit` is the monotone fresh-unit counter (advanced by the
    /// admits). Pure in `(self, window, live, *next_unit)`.
    pub fn events(
        &self,
        window: usize,
        live: &[u64],
        next_unit: &mut u64,
    ) -> StreamEvents {
        let mut admits = Vec::new();
        let mut n_admit = self.admit_rate;
        if self.burst_every > 0
            && window % self.burst_every == self.burst_every - 1
        {
            n_admit += self.burst_size;
        }
        for _ in 0..n_admit {
            admits.push(*next_unit);
            *next_unit += 1;
        }
        let mut retires = Vec::new();
        if self.retire_rate > 0 && !live.is_empty() {
            let mut rng = Rng::new(
                self.seed
                    ^ (window as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ 0xD51F_7A11,
            );
            let mut pool: Vec<u64> = live.to_vec();
            for _ in 0..self.retire_rate.min(pool.len()) {
                let i = rng.gen_index(pool.len());
                retires.push(pool.swap_remove(i));
            }
        }
        if self.mass_retire_every > 0
            && window > 0
            && window % self.mass_retire_every == 0
        {
            // Mass retirement: drop the first half of the live set
            // (slot order) that isn't already leaving.
            let target = live.len() / 2;
            for &u in live {
                if retires.len() >= target {
                    break;
                }
                if !retires.contains(&u) {
                    retires.push(u);
                }
            }
        }
        StreamEvents { admits, retires }
    }

    /// Fill `out` with `unit`'s gradient at window `window`: a seeded
    /// per-unit base in `[-1, 1)` plus `shift_per_window × window`
    /// along a fixed seeded drift direction. Pure in its inputs.
    pub fn grad(&self, unit: u64, window: usize, out: &mut [f32]) {
        let mut rng = Rng::new(
            self.seed
                ^ unit.wrapping_mul(0xA076_1D64_78BD_642F)
                ^ 0x57AB_11E5,
        );
        for v in out.iter_mut() {
            *v = rng.f32() * 2.0 - 1.0;
        }
        if self.shift_per_window != 0.0 && window > 0 {
            let mut dir = Rng::new(self.seed ^ 0xD21F_0D1F);
            let scale = self.shift_per_window * window as f32;
            for v in out.iter_mut() {
                *v += scale * (dir.f32() * 2.0 - 1.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::stream_static_epoch;
    use crate::util::prop::{assert_permutation, gen};

    /// Drive `s` through one window of `vs` (slot-indexed) and return
    /// the visit order it used.
    fn feed_window(
        s: &mut StreamOrder,
        vs: &[Vec<f32>],
        block: usize,
    ) -> Vec<usize> {
        let epoch = s.windows();
        let order = s.epoch_order(epoch).to_vec();
        let d = vs[0].len();
        let mut flat = Vec::new();
        for &slot in &order {
            flat.extend_from_slice(&vs[slot]);
        }
        let mut pos = 0;
        let n = order.len();
        while pos < n {
            let rows = block.min(n - pos);
            let b = GradBlock::new(&flat[pos * d..(pos + rows) * d], d);
            s.observe_block(pos..pos + rows, &b);
            pos += rows;
        }
        s.epoch_end();
        order
    }

    #[test]
    fn static_schedule_is_pair_balance_bit_for_bit() {
        // Contract 9, static half: no membership events → the inner
        // balancer is never touched between windows, so every window's
        // order matches PairBalance exactly.
        let mut rng = Rng::new(901);
        let n = 64;
        let d = 8;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut pair = PairBalance::new(n, d);
        let mut stream = StreamOrder::prefilled(n, d);
        let mut flat = Vec::new();
        for epoch in 0..6 {
            let want =
                { pair.epoch_order(epoch).to_vec() };
            let got = feed_window(&mut stream, &vs, 16);
            assert_eq!(got, want, "window {epoch} diverged");
            stream_static_epoch(&mut pair, epoch, &vs, &mut flat, 16);
        }
        assert_eq!(stream.stats().windows, 6);
        assert_eq!(stream.stats().replans, 0);
        assert_eq!(stream.plan_log().len(), 7);
    }

    #[test]
    fn count_neutral_churn_keeps_the_balancer_untouched() {
        // Retire one + admit one per boundary: the admit back-fills
        // the freed slot, the count never changes, and the balancer is
        // never rebuilt — the orders stay identical to a pure
        // PairBalance run over the same slot gradients.
        let mut rng = Rng::new(902);
        let n = 32;
        let d = 4;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut pair = PairBalance::new(n, d);
        let mut stream = StreamOrder::prefilled(n, d);
        let mut next_unit = n as u64;
        let mut flat = Vec::new();
        for epoch in 0..5 {
            let oldest = stream.live_units()[stream
                .current_plan()
                .admit_seq
                .iter()
                .enumerate()
                .min_by_key(|(_, &s)| s)
                .unwrap()
                .0];
            stream.retire(oldest).unwrap();
            stream.admit(next_unit, d).unwrap();
            next_unit += 1;
            let got = feed_window(&mut stream, &vs, 8);
            let want = pair.epoch_order(epoch).to_vec();
            assert_eq!(got, want, "window {epoch} diverged under churn");
            stream_static_epoch(&mut pair, epoch, &vs, &mut flat, 8);
        }
        assert_eq!(stream.len(), n);
        assert_eq!(stream.stats().replans, 0);
        assert_eq!(stream.stats().retires, 5);
        assert_eq!(stream.stats().admits, 5);
    }

    #[test]
    fn admit_retire_lifecycle_and_errors() {
        let mut s = StreamOrder::with_units(4, 2, &[10, 11, 12]);
        assert_eq!(
            s.admit(10, 2),
            Err(StreamError::AlreadyLive(10))
        );
        assert_eq!(
            s.admit(20, 3),
            Err(StreamError::DimMismatch { unit: 20, got: 3, want: 2 })
        );
        assert_eq!(s.retire(99), Err(StreamError::NotLive(99)));
        s.admit(20, 2).unwrap();
        assert_eq!(s.admit(20, 2), Err(StreamError::AlreadyPending(20)));
        assert_eq!(s.retire(20), Err(StreamError::NotLive(20)));
        s.retire(11).unwrap();
        assert_eq!(s.retire(11), Err(StreamError::AlreadyPending(11)));
        assert_eq!(
            s.admit(11, 2),
            Err(StreamError::AlreadyPending(11))
        );
        // Events apply only at the boundary.
        assert_eq!(s.live_units(), &[10, 11, 12]);
        let vs = vec![vec![1.0, 0.0]; 3];
        feed_window(&mut s, &vs, 2);
        // 11 retired; 20 back-filled its slot; count neutral.
        assert_eq!(s.live_units(), &[10, 20, 12]);
        assert_eq!(s.stats().replans, 0);
    }

    #[test]
    fn admit_queue_is_bounded_by_capacity() {
        let mut s = StreamOrder::new(2, 1);
        s.admit(0, 1).unwrap();
        s.admit(1, 1).unwrap();
        assert_eq!(
            s.admit(2, 1),
            Err(StreamError::WindowOverflow { capacity: 2 })
        );
    }

    #[test]
    fn fifo_eviction_when_full() {
        let mut s = StreamOrder::with_units(3, 1, &[5, 6, 7]);
        s.admit(8, 1).unwrap();
        let vs = vec![vec![1.0]; 3];
        feed_window(&mut s, &vs, 1);
        // 5 is the oldest admission → evicted; 8 back-fills its slot.
        assert_eq!(s.live_units(), &[8, 6, 7]);
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.current_plan().evicted, vec![5]);
    }

    #[test]
    fn resize_remaps_the_surviving_order() {
        // Shrink by one: the balancer rebuilds over the compacted
        // slots, re-seeded with the survivors in their old-order
        // positions — and every subsequent window stays a valid
        // permutation.
        let mut rng = Rng::new(903);
        let n = 9;
        let d = 3;
        let vs = gen::vec_set(&mut rng, n, d);
        // Reference: the order a bare PairBalance would plan for
        // window 2 after seeing the same two windows of gradients.
        let mut pair = PairBalance::new(n, d);
        let mut flat = Vec::new();
        stream_static_epoch(&mut pair, 0, &vs, &mut flat, 4);
        stream_static_epoch(&mut pair, 1, &vs, &mut flat, 4);
        let over_old = pair.epoch_order(2).to_vec();
        let mut s = StreamOrder::prefilled(n, d);
        feed_window(&mut s, &vs, 4);
        let retired_slot = 4usize; // prefilled: unit 4 lives in slot 4
        s.retire(4).unwrap();
        feed_window(&mut s, &vs, 4);
        assert_eq!(s.len(), n - 1);
        assert_eq!(s.stats().replans, 1);
        let next = s.epoch_order(2).to_vec();
        assert_eq!(next.len(), n - 1);
        assert_permutation(&next).unwrap();
        // The survivors' relative order is preserved: dropping the
        // retired slot from the reference plan and compacting slot
        // labels must give exactly the new order.
        let want: Vec<usize> = over_old
            .iter()
            .filter(|&&slot| slot != retired_slot)
            .map(|&slot| {
                if slot > retired_slot { slot - 1 } else { slot }
            })
            .collect();
        assert_eq!(next, want);
        let vs2 = gen::vec_set(&mut rng, n - 1, d);
        feed_window(&mut s, &vs2, 4);
        let after = s.epoch_order(3).to_vec();
        assert_eq!(after.len(), n - 1);
        assert_permutation(&after).unwrap();
    }

    #[test]
    fn carry_out_subtracts_departed_contributions() {
        // After a boundary that retires unit u, the survivor
        // accumulator equals Σ ε_t g_t over the window minus u's
        // signed contribution — computed independently here.
        let mut rng = Rng::new(904);
        let n = 8;
        let d = 4;
        let vs = gen::vec_set(&mut rng, n, d);
        let mut s = StreamOrder::prefilled(n, d);
        let order = {
            let o = s.epoch_order(0).to_vec();
            let d_ = d;
            let mut flat = Vec::new();
            for &slot in &o {
                flat.extend_from_slice(&vs[slot]);
            }
            s.retire(3).unwrap();
            let b = GradBlock::new(&flat, d_);
            s.observe_block(0..n, &b);
            s.epoch_end();
            o
        };
        // Reference: the same window through a bare PairBalance gives
        // the signs; sum survivors only.
        let mut pair = PairBalance::new(n, d);
        let mut flat = Vec::new();
        stream_static_epoch(&mut pair, 0, &vs, &mut flat, n);
        let signs = pair.last_epoch_signs();
        let mut want = vec![0.0f32; d];
        for (pos, &slot) in order.iter().enumerate() {
            if slot == 3 {
                continue; // unit 3 == slot 3 in a prefilled reservoir
            }
            for (w, &g) in want.iter_mut().zip(&vs[slot]) {
                *w += f32::from(signs[pos]) * g;
            }
        }
        assert!(
            (s.stats().carry_inf - norm_inf(&want)).abs() < 1e-6,
            "carry_inf {} != reference {}",
            s.stats().carry_inf,
            norm_inf(&want)
        );
    }

    #[test]
    fn drift_plan_is_pure_and_replays() {
        let plan = DriftPlan {
            seed: 77,
            admit_rate: 2,
            retire_rate: 1,
            burst_every: 3,
            burst_size: 4,
            mass_retire_every: 5,
            shift_per_window: 0.1,
        };
        let live: Vec<u64> = (0..10).collect();
        let mut c1 = 10u64;
        let mut c2 = 10u64;
        let e1 = plan.events(4, &live, &mut c1);
        let e2 = plan.events(4, &live, &mut c2);
        assert_eq!(e1, e2);
        assert_eq!(c1, c2);
        let mut g1 = vec![0.0f32; 6];
        let mut g2 = vec![0.0f32; 6];
        plan.grad(3, 7, &mut g1);
        plan.grad(3, 7, &mut g2);
        assert_eq!(g1, g2);
        let mut g3 = vec![0.0f32; 6];
        plan.grad(3, 8, &mut g3);
        assert_ne!(g1, g3, "shifted windows must drift the gradient");
    }

    #[test]
    fn driven_windows_replay_bit_for_bit() {
        // Contract 9, frozen-schedule half (unsharded): two reservoirs
        // driven by the same DriftPlan produce identical orders,
        // plans, and stats at every window.
        let drift = DriftPlan {
            seed: 41,
            admit_rate: 3,
            retire_rate: 2,
            burst_every: 4,
            burst_size: 5,
            mass_retire_every: 6,
            shift_per_window: 0.05,
        };
        let units: Vec<u64> = (0..20).collect();
        let mut a = StreamOrder::with_units(24, 6, &units);
        let mut b = StreamOrder::with_units(24, 6, &units);
        let mut ca = units.len() as u64;
        let mut cb = units.len() as u64;
        for w in 0..12 {
            a.drive_window(&drift, &mut ca, 7);
            b.drive_window(&drift, &mut cb, 7);
            assert_eq!(
                a.live_units(),
                b.live_units(),
                "window {w} membership diverged"
            );
            assert_eq!(a.stats(), b.stats(), "window {w} stats diverged");
        }
        let wa = a.windows();
        assert_eq!(a.epoch_order(wa), b.epoch_order(wa));
        assert!(a.stats().last_window_inf.is_finite());
        assert!(a.stats().carry_inf.is_finite());
        assert!(a.stats().evictions > 0 || a.stats().retires > 0);
    }

    #[test]
    fn burst_admits_and_mass_retirements_degrade_gracefully() {
        // Heavy churn: every window stays a valid permutation of the
        // live count and every reported bound stays finite.
        let drift = DriftPlan {
            seed: 5150,
            admit_rate: 1,
            retire_rate: 0,
            burst_every: 3,
            burst_size: 9,
            mass_retire_every: 4,
            shift_per_window: 0.5,
        };
        let units: Vec<u64> = (0..8).collect();
        let mut s = StreamOrder::with_units(16, 4, &units);
        let mut next = units.len() as u64;
        for w in 0..16 {
            s.drive_window(&drift, &mut next, 4);
            let n = s.len();
            assert!(n >= 1, "window {w} emptied the reservoir");
            let win = s.windows();
            let order = s.epoch_order(win).to_vec();
            assert_eq!(order.len(), n);
            assert_permutation(&order).unwrap();
            assert!(s.stats().last_window_inf.is_finite());
            assert!(s.stats().carry_inf.is_finite());
        }
        assert!(s.stats().evictions > 0, "bursts must overflow FIFO");
        assert!(s.stats().retires > 0, "mass retirements must fire");
        assert!(s.stats().replans > 0, "churn must resize at least once");
        // The plan log replays the whole membership history.
        assert_eq!(s.plan_log().len(), 17);
        assert_eq!(
            s.plan_log().last().unwrap().units,
            s.live_units()
        );
    }

    #[test]
    fn sharded_channel_matches_unsharded_on_count_neutral_churn() {
        // Count-neutral churn never touches the inner coordinators, so
        // the sharded reservoir over channel transports must follow
        // the same orders as CD-GraB would — and admits/evictions work
        // over the fixed links without a re-link.
        let drift = DriftPlan::steady(11, 2);
        let units: Vec<u64> = (0..24).collect();
        let mut s =
            StreamOrder::sharded_channel(24, 4, &units, 3, 2);
        let mut next = units.len() as u64;
        for _ in 0..4 {
            s.drive_window(&drift, &mut next, 6);
            assert_eq!(s.len(), 24);
        }
        assert_eq!(s.stats().replans, 0);
        assert_eq!(s.stats().evictions, 8);
        assert_eq!(s.name(), "stream-cd");
        assert!(s.transport_stats().is_some());
        let w = s.windows();
        let order = s.epoch_order(w).to_vec();
        assert_eq!(order.len(), 24);
        assert_permutation(&order).unwrap();
    }

    #[test]
    fn sharded_resize_relinks_and_recovers() {
        let units: Vec<u64> = (0..12).collect();
        let mut s =
            StreamOrder::sharded_channel(16, 3, &units, 2, 2);
        let vs: Vec<Vec<f32>> = (0..12)
            .map(|i| vec![i as f32, 1.0, -1.0])
            .collect();
        feed_window(&mut s, &vs, 4);
        s.admit(100, 3).unwrap();
        s.admit(101, 3).unwrap();
        feed_window(&mut s, &vs, 4);
        assert_eq!(s.len(), 14, "admits must grow the reservoir");
        assert_eq!(s.stats().replans, 1);
        let vs2: Vec<Vec<f32>> =
            (0..14).map(|i| vec![-(i as f32), 0.5, 2.0]).collect();
        feed_window(&mut s, &vs2, 4);
        let w = s.windows();
        let order = s.epoch_order(w).to_vec();
        assert_eq!(order.len(), 14);
        assert_permutation(&order).unwrap();
    }

    #[test]
    #[should_panic(expected = "over fixed shard links")]
    fn sharded_resize_without_relink_panics() {
        let units: Vec<u64> = (0..6).collect();
        let topology = Topology::plan(6, 0, &[1, 1]);
        let links =
            transport::spawn_channel_shards(&topology.sizes, 2, 2);
        let inner = ShardedOrder::from_links(
            6, 2, topology, links, "channel", None,
        );
        let mut s = StreamOrder::sharded(8, 2, &units, inner, None);
        s.retire(0).unwrap();
        let vs = vec![vec![1.0, -1.0]; 6];
        feed_window(&mut s, &vs, 2);
    }

    #[test]
    fn prefilled_static_save_restore_roundtrips() {
        // Contract 8 still holds for the trainer's static stream
        // configuration; a reservoir with membership history refuses.
        let mut rng = Rng::new(905);
        let vs = gen::vec_set(&mut rng, 10, 3);
        let mut s = StreamOrder::prefilled(10, 3);
        feed_window(&mut s, &vs, 5);
        let state = s.save_state().expect("static stream must checkpoint");
        let mut fresh = StreamOrder::prefilled(10, 3);
        fresh.restore_state(&state).unwrap();
        assert_eq!(
            s.epoch_order(1).to_vec(),
            fresh.epoch_order(1).to_vec()
        );
        let mut churned = StreamOrder::prefilled(10, 3);
        churned.retire(0).unwrap();
        feed_window(&mut churned, &vs, 5);
        assert!(
            churned.save_state().is_none(),
            "membership history must refuse to checkpoint"
        );
    }
}
