//! Message-level payload codecs for the shard wire protocol.
//!
//! The frame layer ([`crate::util::ser`]) moves opaque checksummed
//! payloads; this module defines what is *in* them — the four payload
//! shapes of the CD-GraB order exchange (little-endian throughout):
//!
//! | frame kind | payload |
//! |---|---|
//! | `Hello`    | `u32 local_n`, `u32 d`, `u32 generation` |
//! | `Ack`      | empty |
//! | `Block`    | `u32 rows`, `u32 d`, then `rows × d` f32 bit patterns |
//! | `EpochEnd` | empty |
//! | `Report`   | `u32 len`, `u64 state_bytes`, then `len` `u32` unit ids |
//! | `Seed`     | `u32 len`, then `len` `u32` unit ids (checkpoint resume) |
//! | `Register` | `u32 capacity`, `u32 generation`, `u32 name_len`, name bytes |
//! | `Lease`    | `u32 worker_id`, `u32 generation` |
//!
//! Floats travel as raw IEEE-754 bit patterns (`f32::to_bits`), so
//! NaN payloads, signed zeros, infinities, and subnormals round-trip
//! bit-identically — the transport-equivalence contract requires the
//! worker to see *exactly* the bytes the coordinator gathered.
//! Every decoder validates internal consistency (declared counts vs.
//! payload length, report entries in range) and returns a typed
//! [`WireError`] on any mismatch; decoders never panic and never
//! partially fill their output.

use crate::util::ser::{
    u32_from_usize, u64_from_usize, usize_from_u32, usize_from_u64,
    WireError, MAX_FRAME_PAYLOAD,
};

/// Handshake parameters announced by the coordinator when opening one
/// shard link: the shard's local unit count, the gradient dimension,
/// and the coordinator's topology generation (0 for a run's first
/// plan; an elastic coordinator bumps it on every re-split, so a
/// worker server can tell a re-handshake after shard migration from a
/// duplicate connection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    /// Number of ordering units owned by this shard.
    pub local_n: u32,
    /// Gradient dimension `d`.
    pub d: u32,
    /// Topology generation this link belongs to (see
    /// [`crate::ordering::topology::Topology::generation`]).
    pub generation: u32,
}

/// Encode a [`Hello`] payload.
pub fn encode_hello(hello: Hello, out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&hello.local_n.to_le_bytes());
    out.extend_from_slice(&hello.d.to_le_bytes());
    out.extend_from_slice(&hello.generation.to_le_bytes());
}

/// Decode a [`Hello`] payload.
pub fn decode_hello(payload: &[u8]) -> Result<Hello, WireError> {
    if payload.len() != 12 {
        return Err(WireError::Malformed(format!(
            "hello payload is {} bytes, expected 12",
            payload.len()
        )));
    }
    Ok(Hello {
        local_n: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
        d: u32::from_le_bytes(payload[4..8].try_into().unwrap()),
        generation: u32::from_le_bytes(payload[8..12].try_into().unwrap()),
    })
}

/// Encode a gathered `[rows × d]` block payload from its row-major
/// float data (`data.len() == rows * d`).
pub fn encode_block(data: &[f32], d: usize, out: &mut Vec<u8>) {
    assert!(d > 0, "block dimension must be positive");
    assert_eq!(data.len() % d, 0, "block data not a whole number of rows");
    let rows = data.len() / d;
    out.clear();
    out.reserve(8 + data.len() * 4);
    let rows32 = u32_from_usize(rows).expect("block rows over wire limit");
    let d32 = u32_from_usize(d).expect("block dimension over wire limit");
    out.extend_from_slice(&rows32.to_le_bytes());
    out.extend_from_slice(&d32.to_le_bytes());
    for &x in data {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Decode a block payload into `out` (cleared first), validating the
/// declared row count and dimension against the payload length and the
/// link's handshake dimension `expect_d`. Returns the row count.
pub fn decode_block(
    payload: &[u8],
    expect_d: usize,
    out: &mut Vec<f32>,
) -> Result<usize, WireError> {
    if payload.len() < 8 {
        return Err(WireError::Malformed(format!(
            "block payload is {} bytes, header needs 8",
            payload.len()
        )));
    }
    let rows =
        usize_from_u32(u32::from_le_bytes(payload[0..4].try_into().unwrap()));
    let d =
        usize_from_u32(u32::from_le_bytes(payload[4..8].try_into().unwrap()));
    if d != expect_d {
        return Err(WireError::Malformed(format!(
            "block dimension {d} does not match the link's {expect_d}"
        )));
    }
    // Guard the multiplication: a hostile row count must not overflow
    // or demand more than a frame can legally carry.
    let floats = rows
        .checked_mul(d)
        .filter(|&f| f <= MAX_FRAME_PAYLOAD / 4)
        .ok_or_else(|| {
            WireError::Malformed(format!(
                "block of {rows} x {d} rows exceeds the frame cap"
            ))
        })?;
    if payload.len() != 8 + floats * 4 {
        return Err(WireError::Malformed(format!(
            "block declares {rows} x {d} rows ({} bytes) but payload \
             carries {}",
            8 + floats * 4,
            payload.len()
        )));
    }
    out.clear();
    out.reserve(floats);
    for chunk in payload[8..].chunks_exact(4) {
        out.push(f32::from_bits(u32::from_le_bytes(
            chunk.try_into().unwrap(),
        )));
    }
    Ok(rows)
}

/// Encode an epoch-order report payload (`order` entries must fit u32).
pub fn encode_report(
    order: &[usize],
    state_bytes: usize,
    out: &mut Vec<u8>,
) {
    let len =
        u32_from_usize(order.len()).expect("order length over wire limit");
    out.clear();
    out.reserve(12 + order.len() * 4);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&u64_from_usize(state_bytes).to_le_bytes());
    for &unit in order {
        let unit = u32_from_usize(unit).expect("unit id over wire limit");
        out.extend_from_slice(&unit.to_le_bytes());
    }
}

/// Decode an epoch-order report, validating the declared length against
/// the payload and the order itself as a **permutation** of the shard's
/// `0..local_n` units (length `local_n`, every id in range, no
/// duplicates) — a malformed peer must produce a typed error, never a
/// non-permutation silently entering the coordinator's merge.
pub fn decode_report(
    payload: &[u8],
    local_n: usize,
) -> Result<(Vec<usize>, usize), WireError> {
    if payload.len() < 12 {
        return Err(WireError::Malformed(format!(
            "report payload is {} bytes, header needs 12",
            payload.len()
        )));
    }
    let len =
        usize_from_u32(u32::from_le_bytes(payload[0..4].try_into().unwrap()));
    let state_bytes = usize_from_u64(u64::from_le_bytes(
        payload[4..12].try_into().unwrap(),
    ))?;
    if len != local_n {
        return Err(WireError::Malformed(format!(
            "report carries {len} units, shard owns {local_n}"
        )));
    }
    if payload.len() != 12 + len * 4 {
        return Err(WireError::Malformed(format!(
            "report declares {len} units ({} bytes) but payload \
             carries {}",
            12 + len * 4,
            payload.len()
        )));
    }
    let mut order = Vec::with_capacity(len);
    let mut seen = vec![false; local_n];
    for chunk in payload[12..].chunks_exact(4) {
        let unit =
            usize_from_u32(u32::from_le_bytes(chunk.try_into().unwrap()));
        if unit >= local_n {
            return Err(WireError::Malformed(format!(
                "report unit id {unit} out of range for shard of \
                 {local_n}"
            )));
        }
        if seen[unit] {
            return Err(WireError::Malformed(format!(
                "report repeats unit id {unit}: not a permutation of \
                 0..{local_n}"
            )));
        }
        seen[unit] = true;
        order.push(unit);
    }
    Ok((order, state_bytes))
}

/// Encode a checkpoint-resume seed payload: the shard's restored next
/// local order (`order` entries must fit u32).
pub fn encode_seed(order: &[usize], out: &mut Vec<u8>) {
    let len =
        u32_from_usize(order.len()).expect("order length over wire limit");
    out.clear();
    out.reserve(4 + order.len() * 4);
    out.extend_from_slice(&len.to_le_bytes());
    for &unit in order {
        let unit = u32_from_usize(unit).expect("unit id over wire limit");
        out.extend_from_slice(&unit.to_le_bytes());
    }
}

/// Decode a seed payload, validating it as a **permutation** of the
/// shard's `0..local_n` units — same discipline as [`decode_report`]: a
/// malformed resume seed must produce a typed error, never silently
/// corrupt the worker balancer's order.
pub fn decode_seed(
    payload: &[u8],
    local_n: usize,
) -> Result<Vec<usize>, WireError> {
    if payload.len() < 4 {
        return Err(WireError::Malformed(format!(
            "seed payload is {} bytes, header needs 4",
            payload.len()
        )));
    }
    let len =
        usize_from_u32(u32::from_le_bytes(payload[0..4].try_into().unwrap()));
    if len != local_n {
        return Err(WireError::Malformed(format!(
            "seed carries {len} units, shard owns {local_n}"
        )));
    }
    if payload.len() != 4 + len * 4 {
        return Err(WireError::Malformed(format!(
            "seed declares {len} units ({} bytes) but payload carries {}",
            4 + len * 4,
            payload.len()
        )));
    }
    let mut order = Vec::with_capacity(len);
    let mut seen = vec![false; local_n];
    for chunk in payload[4..].chunks_exact(4) {
        let unit =
            usize_from_u32(u32::from_le_bytes(chunk.try_into().unwrap()));
        if unit >= local_n {
            return Err(WireError::Malformed(format!(
                "seed unit id {unit} out of range for shard of {local_n}"
            )));
        }
        if seen[unit] {
            return Err(WireError::Malformed(format!(
                "seed repeats unit id {unit}: not a permutation of \
                 0..{local_n}"
            )));
        }
        seen[unit] = true;
        order.push(unit);
    }
    Ok(order)
}

/// Longest worker name accepted in a [`Register`] payload. Names are
/// labels for `/metrics` and logs, not identities; the cap keeps a
/// hostile registration from carrying megabytes of "name".
pub const MAX_WORKER_NAME: usize = 256;

/// A worker's registration with the order-service daemon: the worker
/// dialed in and announces how many concurrent shard leases it will
/// accept, the registry generation it last saw (0 on a fresh dial; a
/// nonzero stale generation lets the daemon refuse a worker that
/// wandered in from a previous daemon incarnation), and a display
/// name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Register {
    /// Max concurrent shard leases this worker accepts (>= 1).
    pub capacity: u32,
    /// Registry generation the worker last saw (0 = fresh).
    pub generation: u32,
    /// Display name for logs and `/metrics` (UTF-8, may be empty).
    pub name: String,
}

/// Encode a [`Register`] payload.
pub fn encode_register(reg: &Register, out: &mut Vec<u8>) {
    assert!(
        reg.name.len() <= MAX_WORKER_NAME,
        "worker name over wire limit"
    );
    out.clear();
    out.reserve(12 + reg.name.len());
    out.extend_from_slice(&reg.capacity.to_le_bytes());
    out.extend_from_slice(&reg.generation.to_le_bytes());
    let name_len =
        u32_from_usize(reg.name.len()).expect("worker name over wire limit");
    out.extend_from_slice(&name_len.to_le_bytes());
    out.extend_from_slice(reg.name.as_bytes());
}

/// Decode a [`Register`] payload, validating the declared name length
/// against the payload, the name-length cap, UTF-8 validity, and a
/// positive capacity.
pub fn decode_register(payload: &[u8]) -> Result<Register, WireError> {
    if payload.len() < 12 {
        return Err(WireError::Malformed(format!(
            "register payload is {} bytes, header needs 12",
            payload.len()
        )));
    }
    let capacity =
        u32::from_le_bytes(payload[0..4].try_into().unwrap());
    let generation =
        u32::from_le_bytes(payload[4..8].try_into().unwrap());
    let name_len =
        usize_from_u32(u32::from_le_bytes(payload[8..12].try_into().unwrap()));
    if name_len > MAX_WORKER_NAME {
        return Err(WireError::Malformed(format!(
            "worker name of {name_len} bytes exceeds the \
             {MAX_WORKER_NAME}-byte cap"
        )));
    }
    if payload.len() != 12 + name_len {
        return Err(WireError::Malformed(format!(
            "register declares a {name_len}-byte name ({} bytes) but \
             payload carries {}",
            12 + name_len,
            payload.len()
        )));
    }
    if capacity == 0 {
        return Err(WireError::Malformed(
            "register capacity must be >= 1".to_string(),
        ));
    }
    let name = std::str::from_utf8(&payload[12..])
        .map_err(|_| {
            WireError::Malformed(
                "worker name is not valid UTF-8".to_string(),
            )
        })?
        .to_string();
    Ok(Register { capacity, generation, name })
}

/// The daemon's acceptance of a [`Register`]: the worker's assigned id
/// (unique within this daemon incarnation) and the registry generation
/// the worker now belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lease {
    /// Registry-assigned worker id.
    pub worker_id: u32,
    /// Registry generation of this daemon incarnation.
    pub generation: u32,
}

/// Encode a [`Lease`] payload.
pub fn encode_lease(lease: Lease, out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&lease.worker_id.to_le_bytes());
    out.extend_from_slice(&lease.generation.to_le_bytes());
}

/// Decode a [`Lease`] payload.
pub fn decode_lease(payload: &[u8]) -> Result<Lease, WireError> {
    if payload.len() != 8 {
        return Err(WireError::Malformed(format!(
            "lease payload is {} bytes, expected 8",
            payload.len()
        )));
    }
    Ok(Lease {
        worker_id: u32::from_le_bytes(payload[0..4].try_into().unwrap()),
        generation: u32::from_le_bytes(
            payload[4..8].try_into().unwrap(),
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::ser::{decode_frame, encode_frame, FrameKind};

    /// Draw a float whose bit pattern exercises the full IEEE-754 zoo:
    /// ordinary values plus NaNs (payload bits included), ±inf, signed
    /// zeros, and subnormals.
    fn weird_f32(rng: &mut crate::util::rng::Rng) -> f32 {
        match rng.gen_range(8) {
            0 => f32::from_bits(0x7fc0_0001), // NaN with payload
            1 => f32::from_bits(0xffc1_2345), // negative NaN, payload
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            4 => -0.0,
            5 => {
                // subnormal
                let low = u32::try_from(rng.gen_range(0x10)).unwrap();
                f32::from_bits(1 + low)
            }
            6 => f32::MIN_POSITIVE / 2.0,
            _ => rng.gauss() as f32,
        }
    }

    #[test]
    fn hello_roundtrip() {
        let mut buf = Vec::new();
        let h = Hello { local_n: 1000, d: 7850, generation: 3 };
        encode_hello(h, &mut buf);
        assert_eq!(buf.len(), 12);
        assert_eq!(decode_hello(&buf).unwrap(), h);
        assert!(decode_hello(&buf[..8]).is_err());
        assert!(decode_hello(&buf[..7]).is_err());
    }

    #[test]
    fn register_and_lease_roundtrip() {
        let mut buf = Vec::new();
        let reg = Register {
            capacity: 4,
            generation: 0,
            name: "worker-α".to_string(),
        };
        encode_register(&reg, &mut buf);
        assert_eq!(decode_register(&buf).unwrap(), reg);
        // Truncated header / body, zero capacity, over-cap and
        // non-UTF-8 names: all typed errors, never panics.
        assert!(decode_register(&buf[..8]).is_err());
        assert!(decode_register(&buf[..buf.len() - 1]).is_err());
        let mut zero = Vec::new();
        encode_register(
            &Register {
                capacity: 1,
                generation: 0,
                name: String::new(),
            },
            &mut zero,
        );
        zero[0..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_register(&zero).is_err());
        let mut oversized = Vec::new();
        oversized.extend_from_slice(&1u32.to_le_bytes());
        oversized.extend_from_slice(&0u32.to_le_bytes());
        oversized
            .extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(decode_register(&oversized).is_err());
        let mut bad_utf8 = Vec::new();
        encode_register(
            &Register {
                capacity: 1,
                generation: 0,
                name: "ab".to_string(),
            },
            &mut bad_utf8,
        );
        bad_utf8[12] = 0xff;
        bad_utf8[13] = 0xfe;
        assert!(decode_register(&bad_utf8).is_err());

        let lease = Lease { worker_id: 7, generation: 3 };
        encode_lease(lease, &mut buf);
        assert_eq!(buf.len(), 8);
        assert_eq!(decode_lease(&buf).unwrap(), lease);
        assert!(decode_lease(&buf[..5]).is_err());
    }

    #[test]
    fn block_roundtrip_is_bit_identical_over_weird_floats() {
        // Satellite property test: random n/d/rows with NaN / ±inf /
        // subnormal payloads encode→decode bit-identically, and frames
        // are stable across re-encoding.
        prop::forall("wire block roundtrip", 64, |rng| {
            let d = 1 + rng.gen_index(32);
            let rows = rng.gen_index(17);
            let data: Vec<f32> =
                (0..rows * d).map(|_| weird_f32(rng)).collect();
            let mut payload = Vec::new();
            encode_block(&data, d, &mut payload);
            let mut decoded = Vec::new();
            let got_rows = decode_block(&payload, d, &mut decoded)
                .map_err(|e| e.to_string())?;
            if got_rows != rows {
                return Err(format!("rows {got_rows} != {rows}"));
            }
            // Bit-level equality (== would treat NaN != NaN).
            let bits = |v: &[f32]| -> Vec<u32> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            if bits(&decoded) != bits(&data) {
                return Err("payload bits changed in transit".into());
            }
            // Re-encoding the decoded block reproduces the same frame
            // byte-for-byte (stable frames).
            let mut payload2 = Vec::new();
            encode_block(&decoded, d, &mut payload2);
            if payload2 != payload {
                return Err("re-encoded payload differs".into());
            }
            let mut f1 = Vec::new();
            let mut f2 = Vec::new();
            encode_frame(FrameKind::Block, &payload, &mut f1);
            encode_frame(FrameKind::Block, &payload2, &mut f2);
            if f1 != f2 {
                return Err("re-encoded frame differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn report_roundtrip_over_random_orders() {
        prop::forall("wire report roundtrip", 32, |rng| {
            let n = 1 + rng.gen_index(200);
            let order = rng.permutation(n);
            let state = rng.gen_index(1 << 20);
            let mut payload = Vec::new();
            encode_report(&order, state, &mut payload);
            let (got, got_state) = decode_report(&payload, n)
                .map_err(|e| e.to_string())?;
            if got != order || got_state != state {
                return Err("report changed in transit".into());
            }
            // Stable across re-encoding.
            let mut payload2 = Vec::new();
            encode_report(&got, got_state, &mut payload2);
            if payload2 != payload {
                return Err("re-encoded report differs".into());
            }
            Ok(())
        });
    }

    #[test]
    fn seed_roundtrip_and_rejects_non_permutations() {
        prop::forall("wire seed roundtrip", 32, |rng| {
            let n = 1 + rng.gen_index(200);
            let order = rng.permutation(n);
            let mut payload = Vec::new();
            encode_seed(&order, &mut payload);
            let got = decode_seed(&payload, n).map_err(|e| e.to_string())?;
            if got != order {
                return Err("seed changed in transit".into());
            }
            Ok(())
        });
        let order = vec![2usize, 0, 1];
        let mut payload = Vec::new();
        encode_seed(&order, &mut payload);
        // Wrong shard size, truncation, out-of-range, duplicate.
        assert!(decode_seed(&payload, 4).is_err());
        assert!(decode_seed(&payload[..payload.len() - 2], 3).is_err());
        assert!(decode_seed(&payload[..2], 3).is_err());
        let last = payload.len() - 4;
        let mut bad = payload.clone();
        bad[last..].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            decode_seed(&bad, 3),
            Err(WireError::Malformed(_))
        ));
        let mut bad = payload.clone();
        bad[last..].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(
            decode_seed(&bad, 3),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn block_decode_rejects_inconsistent_headers() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let mut payload = Vec::new();
        encode_block(&data, 2, &mut payload);
        let mut out = vec![0.5f32; 3]; // pre-filled to detect partial writes

        // Wrong link dimension.
        assert!(matches!(
            decode_block(&payload, 3, &mut out),
            Err(WireError::Malformed(_))
        ));
        assert_eq!(out, vec![0.5f32; 3], "failed decode must not write");

        // Oversized row count: declared rows far beyond the payload.
        let mut bad = payload.clone();
        bad[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_block(&bad, 2, &mut out),
            Err(WireError::Malformed(_))
        ));

        // Row count that overflows rows * d.
        let mut bad = payload.clone();
        bad[0..4].copy_from_slice(&(u32::MAX / 2).to_le_bytes());
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(
            decode_block(&bad, usize_from_u32(u32::MAX), &mut out).is_err()
        );

        // Truncated body.
        assert!(matches!(
            decode_block(&payload[..payload.len() - 1], 2, &mut out),
            Err(WireError::Malformed(_))
        ));
        assert!(decode_block(&payload[..4], 2, &mut out).is_err());
    }

    #[test]
    fn report_decode_rejects_bad_lengths_and_out_of_range_units() {
        let order = vec![2usize, 0, 1];
        let mut payload = Vec::new();
        encode_report(&order, 64, &mut payload);

        // Length disagrees with the shard size.
        assert!(matches!(
            decode_report(&payload, 4),
            Err(WireError::Malformed(_))
        ));
        // Truncated.
        assert!(decode_report(&payload[..payload.len() - 2], 3).is_err());
        assert!(decode_report(&payload[..8], 3).is_err());
        // Out-of-range unit id.
        let mut bad = payload.clone();
        let last = bad.len() - 4;
        bad[last..].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            decode_report(&bad, 3),
            Err(WireError::Malformed(_))
        ));
        // Duplicate unit id: in range, right length, but not a
        // permutation — must not reach the coordinator's merge.
        let mut bad = payload.clone();
        bad[last..].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(
            decode_report(&bad, 3),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn framed_block_survives_the_full_frame_layer() {
        // End-to-end through encode_frame/decode_frame, the path the
        // TCP transport actually takes.
        let data = [f32::NAN, -0.0, 1.5e-40, f32::INFINITY];
        let mut payload = Vec::new();
        encode_block(&data, 4, &mut payload);
        let mut frame = Vec::new();
        encode_frame(FrameKind::Block, &payload, &mut frame);
        let (kind, body, _) = decode_frame(&frame).unwrap();
        assert_eq!(kind, FrameKind::Block);
        let mut out = Vec::new();
        assert_eq!(decode_block(body, 4, &mut out).unwrap(), 1);
        let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want);
    }
}
