//! Shard order-exchange transports — how a CD-GraB coordinator talks to
//! its W shard balancers.
//!
//! PR 2's async backend hard-wired one mechanism: an in-process mpsc
//! block queue per shard plus a report channel back. This module
//! extracts that conversation into the [`ShardTransport`] trait — the
//! coordinator-side endpoint of one shard's link, speaking exactly the
//! messages the block queues already defined:
//!
//! * **block** — a gathered `[rows × d]` scratch block of the shard's
//!   next local gradients ([`ShardTransport::send_block`]);
//! * **epoch end** — the boundary signal ([`ShardTransport::end_epoch`]);
//! * **report** — the shard's next local epoch order, received back at
//!   the boundary ([`ShardTransport::recv_report`]).
//!
//! Two backends implement it:
//!
//! * [`ChannelTransport`] — the PR 2 worker thread behind a bounded
//!   mpsc block queue, now behind the trait (the default);
//! * [`tcp::TcpTransport`] — the same conversation serialized into
//!   checksummed little-endian frames ([`crate::util::ser`]) over a TCP
//!   socket, with the shard balancer running either on an in-process
//!   loopback worker or in a separate OS process
//!   (`grab exp cdgrab --listen`).
//!
//! The coordinator ([`crate::ordering::ShardedOrder`]) is transport-
//! agnostic: its round-robin merge, position→shard routing, and
//! epoch-boundary drain barrier never see which carrier moved the bytes.
//! Every transport is required to be **bit-equal**: for the same
//! gradient stream, every backend produces identical epoch orders
//! (contract 5 in `docs/determinism.md`, property-tested in
//! `tests/transport.rs`).

pub mod codec;
#[cfg(any(test, feature = "fault-injection"))]
pub mod fault;
pub mod tcp;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::ordering::queue::{
    block_queue_sized, BlockReceiver, BlockSender, ScratchBlock, ShardMsg,
};
use crate::ordering::{OrderPolicy, PairBalance};
use crate::tensor::{self, Kernel};
use crate::util::ser::{FrameReadError, WireError};

/// What a shard worker sends back at each epoch boundary.
pub struct EpochReport {
    /// The shard's next local epoch order (a permutation of the shard's
    /// `0..local_n` units).
    pub order: Vec<usize>,
    /// The shard balancer's current `state_bytes`.
    pub state_bytes: usize,
}

/// A transport-level failure on one shard link. Mid-epoch failures are
/// recorded and surfaced at the epoch boundary (mirroring worker-panic
/// propagation), never mid-stream.
#[derive(Debug)]
pub enum TransportError {
    /// The peer closed the link before the epoch completed.
    Disconnected(String),
    /// The peer sent bytes that do not decode as a valid message.
    Wire(WireError),
    /// OS-level socket failure.
    Io(std::io::Error),
    /// The peer rejected or botched the connection handshake.
    Handshake(String),
    /// The peer produced no bytes within the link's configured read
    /// timeout — a link failure the elastic coordinator can act on at
    /// the epoch boundary, distinct from a clean disconnect (the socket
    /// may still be open, just silent).
    Timeout {
        /// How long the coordinator waited before giving up.
        after: std::time::Duration,
    },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Disconnected(who) => {
                write!(f, "shard peer disconnected: {who}")
            }
            TransportError::Wire(e) => write!(f, "wire error: {e}"),
            TransportError::Io(e) => write!(f, "socket error: {e}"),
            TransportError::Handshake(why) => {
                write!(f, "handshake failed: {why}")
            }
            TransportError::Timeout { after } => write!(
                f,
                "shard peer silent for {:.1}s (read timeout)",
                after.as_secs_f64()
            ),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

impl From<FrameReadError> for TransportError {
    fn from(e: FrameReadError) -> TransportError {
        match e {
            FrameReadError::Io(e)
                if e.kind() == std::io::ErrorKind::UnexpectedEof =>
            {
                TransportError::Disconnected("eof mid-frame".to_string())
            }
            FrameReadError::Io(e) => TransportError::Io(e),
            FrameReadError::Wire(w) => TransportError::Wire(w),
        }
    }
}

/// Counters of one shard link, comparable across transports: `stalls`
/// counts backpressure waits (queue-full acquires for the channel
/// backend, 0 for TCP where the kernel socket buffer is the
/// backpressure), `tx_bytes`/`rx_bytes` count payload bytes moved to and
/// from the worker (framed wire bytes for TCP, gathered gradient/report
/// bytes for the in-process channel).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkStats {
    /// Backpressure events while handing blocks to the worker.
    pub stalls: u64,
    /// Bytes shipped coordinator → worker.
    pub tx_bytes: u64,
    /// Bytes received worker → coordinator (epoch reports).
    pub rx_bytes: u64,
}

impl LinkStats {
    /// Element-wise sum of two stat snapshots.
    pub fn merged(self, other: LinkStats) -> LinkStats {
        LinkStats {
            stalls: self.stalls + other.stalls,
            tx_bytes: self.tx_bytes + other.tx_bytes,
            rx_bytes: self.rx_bytes + other.rx_bytes,
        }
    }
}

/// Aggregated per-shard link counters, as reported by the coordinator
/// (`ShardedOrder::transport_stats` /
/// `OrderPolicy::transport_stats`). Synchronous backends report one
/// all-zero entry per shard so sync/async/tcp runs emit comparable
/// columns.
#[derive(Clone, Debug, Default)]
pub struct TransportStats {
    /// Short transport name ("inline", "channel", "tcp").
    pub transport: &'static str,
    /// One counter snapshot per shard link, in shard order (the
    /// *current* topology's links).
    pub per_shard: Vec<LinkStats>,
    /// Aggregate counters of links retired by elastic re-plans (their
    /// per-shard breakdown no longer maps onto the current topology).
    /// Zero for static runs; folded into [`TransportStats::total`] so
    /// the cumulative columns stay monotone across re-plans.
    pub retired: LinkStats,
}

impl TransportStats {
    /// Sum of the per-shard counters plus any retired-link counters —
    /// cumulative over the whole run, including links replaced by
    /// elastic re-plans.
    pub fn total(&self) -> LinkStats {
        self.per_shard
            .iter()
            .fold(self.retired, |acc, s| acc.merged(*s))
    }
}

/// Coordinator-side endpoint of one shard's order-exchange link.
///
/// The coordinator drives each link through a fixed per-epoch script:
/// repeated `acquire` → gather → `send_block`, then one `end_epoch`
/// followed by one `recv_report` at the boundary. Implementations must
/// preserve message order per link (the bit-equality contract rides on
/// it) and must turn peer failure into `None`/`false`/`Err` returns —
/// never a panic mid-epoch, so the coordinator can finish routing the
/// epoch's remaining rows and surface the failure at the boundary.
pub trait ShardTransport: Send {
    /// Take a reusable scratch buffer for the next gather. This is the
    /// backpressure point: it may block until the link can accept
    /// another block. `None` means the peer is gone.
    fn acquire(&mut self) -> Option<ScratchBlock>;

    /// Ship a gathered block (obtained from [`ShardTransport::acquire`])
    /// to the shard balancer. Returns `false` if the peer is gone.
    fn send_block(&mut self, block: ScratchBlock) -> bool;

    /// Signal the epoch boundary. Returns `false` if the peer is gone.
    fn end_epoch(&mut self) -> bool;

    /// Block for the shard's epoch-end report. Called exactly once per
    /// `end_epoch`, at the coordinator's drain barrier. An `Err` means
    /// the peer failed mid-epoch; in-process backends may instead
    /// re-raise the worker's panic payload directly (both surface at the
    /// boundary).
    fn recv_report(&mut self) -> Result<EpochReport, TransportError>;

    /// Snapshot of this link's counters.
    fn stats(&self) -> LinkStats;

    /// Bytes of reusable buffer memory held by this link on the
    /// coordinator side (circulating scratch pools, frame buffers) —
    /// counted into the coordinator's `state_bytes` so Table 1 memory
    /// numbers stay comparable across transports.
    fn buffer_bytes(&self) -> usize {
        0
    }

    /// Checkpoint resume: overwrite the shard balancer's next local
    /// order with a restored permutation of its `0..local_n` units.
    /// Only legal between epochs (before any block of the next epoch);
    /// the per-link message ordering guarantee makes the seed land
    /// before subsequent blocks. Returns `false` if the peer is gone or
    /// the transport cannot seed. Default: unsupported.
    fn seed_order(&mut self, _order: &[usize]) -> bool {
        false
    }

    /// Test hook: make the peer fail on its next dequeue. Default: no-op
    /// (transports without an injectable failure mode).
    #[cfg(test)]
    fn poison(&mut self) {}
}

/// How an elastic coordinator opens a fresh set of shard links after a
/// topology re-plan: called with the new shard sizes and the bumped
/// topology generation, it must return one live link per size (a fresh
/// `Hello` per TCP link — the shard-migration re-handshake) or a typed
/// error. Captured state (worker addresses, queue depth) lives inside
/// the closure, so [`crate::ordering::ShardedOrder`] stays
/// transport-agnostic.
pub type Relink = Box<
    dyn FnMut(
            &[usize],
            u64,
        )
            -> Result<Vec<Box<dyn ShardTransport>>, TransportError>
        + Send,
>;

/// Parse a `--connect` value into a worker-server address list: comma-
/// separated, whitespace-trimmed, empties dropped (`"h1:70, h2:70"` →
/// `["h1:70", "h2:70"]`). Shared by the trainer's policy builder and
/// `exp cdgrab` so the accepted syntax cannot diverge.
pub fn parse_connect_addrs(s: &str) -> Vec<String> {
    s.split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect()
}

// ---------------------------------------------------------------------------
// Channel transport (in-process worker thread; PR 2's async backend)
// ---------------------------------------------------------------------------

/// The default transport: the shard balancer runs on an in-process
/// worker thread behind a bounded mpsc block queue
/// ([`crate::ordering::queue`]), with epoch reports returned on a second
/// channel. A worker panic is re-raised (with its original payload) by
/// [`ShardTransport::recv_report`] at the epoch boundary.
pub struct ChannelTransport {
    queue: Option<BlockSender>,
    reports: Receiver<EpochReport>,
    handle: Option<JoinHandle<()>>,
    rx_bytes: u64,
}

impl ChannelTransport {
    /// Spawn one shard worker over `local_n` units of dimension `d`
    /// behind a `depth`-bounded block queue, and return the
    /// coordinator-side endpoint.
    pub fn spawn(local_n: usize, d: usize, depth: usize) -> ChannelTransport {
        ChannelTransport::spawn_sized(local_n, d, depth, 0)
    }

    /// [`ChannelTransport::spawn`] with each pooled scratch buffer
    /// pre-allocated for `row_hint` rows — the per-shard pool sizing
    /// hook for weighted topologies, where the largest-weight shard
    /// gathers the biggest blocks (see
    /// [`crate::ordering::queue::block_queue_sized`]).
    pub fn spawn_sized(
        local_n: usize,
        d: usize,
        depth: usize,
        row_hint: usize,
    ) -> ChannelTransport {
        ChannelTransport::spawn_with_kernel(
            local_n,
            d,
            depth,
            row_hint,
            tensor::default_kernel(),
        )
    }

    /// [`ChannelTransport::spawn_sized`] with an explicit kernel tier
    /// for the worker's balancer (determinism contract 7). The kernel
    /// is snapshotted on the *caller's* thread, so the worker is
    /// pinned to it regardless of later
    /// [`crate::tensor::set_default_kernel`] calls.
    pub fn spawn_with_kernel(
        local_n: usize,
        d: usize,
        depth: usize,
        row_hint: usize,
        kernel: Kernel,
    ) -> ChannelTransport {
        let balancer = PairBalance::with_kernel(local_n, d, kernel);
        let (sender, receiver) = block_queue_sized(d, depth, row_hint);
        let (report_tx, report_rx) = channel();
        let handle = std::thread::spawn(move || {
            channel_worker_loop(receiver, balancer, report_tx);
        });
        ChannelTransport {
            queue: Some(sender),
            reports: report_rx,
            handle: Some(handle),
            rx_bytes: 0,
        }
    }

    /// Join the dead worker and re-raise its panic payload; called when
    /// the boundary drain finds the report channel disconnected.
    fn propagate_failure(&mut self) -> ! {
        if let Some(handle) = self.handle.take() {
            match handle.join() {
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(()) => panic!(
                    "shard worker exited before the epoch ended"
                ),
            }
        }
        panic!("shard worker failed and was already joined");
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        // Closing the queue ends the worker's recv loop; a panic payload
        // at this point was either already surfaced by recv_report or
        // the coordinator itself is unwinding, so the join result is
        // dropped.
        self.queue = None;
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl ShardTransport for ChannelTransport {
    fn acquire(&mut self) -> Option<ScratchBlock> {
        self.queue.as_mut()?.acquire()
    }

    fn send_block(&mut self, block: ScratchBlock) -> bool {
        match self.queue.as_mut() {
            Some(q) => q.send(block),
            None => false,
        }
    }

    fn end_epoch(&mut self) -> bool {
        match &self.queue {
            Some(q) => q.end_epoch(),
            None => false,
        }
    }

    fn recv_report(&mut self) -> Result<EpochReport, TransportError> {
        match self.reports.recv() {
            Ok(report) => {
                self.rx_bytes += (report.order.len()
                    * std::mem::size_of::<usize>())
                    as u64;
                Ok(report)
            }
            Err(_) => self.propagate_failure(),
        }
    }

    fn stats(&self) -> LinkStats {
        let (stalls, tx_bytes) = self
            .queue
            .as_ref()
            .map(|q| (q.stalls(), q.bytes_sent()))
            .unwrap_or((0, 0));
        LinkStats { stalls, tx_bytes, rx_bytes: self.rx_bytes }
    }

    fn buffer_bytes(&self) -> usize {
        // The circulating scratch pool (depth × high-water block size)
        // is this transport's dominant reusable allocation.
        self.queue.as_ref().map(|q| q.pool_bytes()).unwrap_or(0)
    }

    fn seed_order(&mut self, order: &[usize]) -> bool {
        match &self.queue {
            Some(q) => q.seed(order.to_vec()),
            None => false,
        }
    }

    #[cfg(test)]
    fn poison(&mut self) {
        if let Some(q) = &self.queue {
            q.poison();
        }
    }
}

/// A channel shard worker's thread body: balance queued blocks at the
/// shard's running local position, finalize + report at each epoch
/// boundary, exit when the coordinator closes the queue.
fn channel_worker_loop(
    receiver: BlockReceiver,
    mut balancer: PairBalance,
    reports: Sender<EpochReport>,
) {
    let mut cursor = 0usize;
    while let Some(msg) = receiver.recv() {
        match msg {
            ShardMsg::Block(scratch) => {
                let rows = scratch.rows();
                if rows > 0 {
                    // Mirror the TCP worker's row-budget validation: a
                    // link that replays blocks (or a buggy gather) must
                    // surface at the epoch boundary, not corrupt the
                    // balancer through its internal assertions.
                    assert!(
                        cursor + rows <= balancer.len(),
                        "shard worker epoch overflow: {rows} rows \
                         after {cursor} of {}",
                        balancer.len()
                    );
                    balancer.observe_block(
                        cursor..cursor + rows,
                        &scratch.as_grad_block(),
                    );
                    cursor += rows;
                }
                receiver.recycle(scratch);
            }
            ShardMsg::EpochEnd => {
                // A short epoch (dropped rows) must fail loudly — a
                // silently partial balance would merge a wrong order.
                assert!(
                    cursor == balancer.len(),
                    "shard worker epoch ended after {cursor} of {} \
                     rows",
                    balancer.len()
                );
                balancer.epoch_end();
                cursor = 0;
                let report = EpochReport {
                    order: balancer.epoch_order(0).to_vec(),
                    state_bytes: balancer.state_bytes(),
                };
                if reports.send(report).is_err() {
                    return; // coordinator gone
                }
            }
            ShardMsg::Seed(order) => {
                // Checkpoint resume: only legal between epochs. A
                // mid-epoch seed is a coordinator bug, caught like the
                // other budget violations.
                assert!(
                    cursor == 0,
                    "shard worker seeded mid-epoch at row {cursor}"
                );
                assert!(
                    balancer.restore_order(&order),
                    "seed order is not a permutation of the shard's \
                     {} local units",
                    balancer.len()
                );
            }
            #[cfg(test)]
            ShardMsg::Poison => panic!("poisoned shard worker"),
        }
    }
}

/// Nominal trainer microbatch used to pre-size per-shard scratch
/// pools: shard `w` of a weighted topology receives about
/// `NOMINAL_BLOCK_ROWS * sizes[w] / n` rows per observed block, so its
/// pooled buffers start at that capacity (see
/// [`crate::ordering::queue::block_queue_sized`]).
const NOMINAL_BLOCK_ROWS: usize = 64;

/// Spawn `sizes.len()` channel-transport shard workers (one per shard
/// size, dimension `d`, queue depth `depth`). Each shard's scratch
/// pool is pre-sized for its share of a nominal microbatch, so uneven
/// (weighted) topologies reach gather steady state without the
/// largest-weight shard reallocating mid-epoch.
pub fn spawn_channel_shards(
    sizes: &[usize],
    d: usize,
    depth: usize,
) -> Vec<Box<dyn ShardTransport>> {
    spawn_channel_shards_with_kernel(
        sizes,
        d,
        depth,
        tensor::default_kernel(),
    )
}

/// [`spawn_channel_shards`] with an explicit kernel tier for every
/// worker's balancer (determinism contract 7).
pub fn spawn_channel_shards_with_kernel(
    sizes: &[usize],
    d: usize,
    depth: usize,
    kernel: Kernel,
) -> Vec<Box<dyn ShardTransport>> {
    let n: usize = sizes.iter().sum();
    sizes
        .iter()
        .map(|&size| {
            let hint = if n == 0 {
                0
            } else {
                ((NOMINAL_BLOCK_ROWS * size).div_ceil(n)).min(size)
            };
            Box::new(ChannelTransport::spawn_with_kernel(
                size, d, depth, hint, kernel,
            )) as Box<dyn ShardTransport>
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::GradBlock;

    fn drive_epoch(
        link: &mut dyn ShardTransport,
        vs: &[Vec<f32>],
    ) -> EpochReport {
        let mut scratch = link.acquire().expect("live link");
        for v in vs {
            scratch.push_row(v);
        }
        assert!(link.send_block(scratch));
        assert!(link.end_epoch());
        link.recv_report().expect("report")
    }

    #[test]
    fn channel_transport_round_trips_an_epoch() {
        let d = 3;
        let vs: Vec<Vec<f32>> = vec![
            vec![1.0, 0.0, 0.0],
            vec![-1.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, -1.0, 0.0],
        ];
        let mut link = ChannelTransport::spawn(4, d, 2);
        let report = drive_epoch(&mut link, &vs);
        assert_eq!(report.order.len(), 4);
        let mut sorted = report.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        assert!(report.state_bytes > 0);
        let stats = link.stats();
        assert_eq!(stats.tx_bytes, (4 * d * 4) as u64);
        assert_eq!(stats.rx_bytes,
                   (4 * std::mem::size_of::<usize>()) as u64);
    }

    #[test]
    fn channel_transport_matches_inline_pair_balance() {
        // The trait wrapper must not change the bit-equality story:
        // driving the worker through ShardTransport produces the same
        // local order as an inline PairBalance over the same stream.
        let d = 4;
        let n = 10;
        let mut rng = crate::util::rng::Rng::new(11);
        let vs = crate::util::prop::gen::vec_set(&mut rng, n, d);
        let mut link = ChannelTransport::spawn(n, d, 2);
        let mut inline = PairBalance::new(n, d);
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..3 {
            let visit: Vec<Vec<f32>> =
                order.iter().map(|&u| vs[u].clone()).collect();
            let report = drive_epoch(&mut link, &visit);
            let mut flat = Vec::new();
            for v in &visit {
                flat.extend_from_slice(v);
            }
            let _ = inline.epoch_order(0);
            inline.observe_block(0..n, &GradBlock::new(&flat, d));
            inline.epoch_end();
            assert_eq!(report.order, inline.epoch_order(0).to_vec());
            order = report.order;
        }
    }

    #[test]
    fn poisoned_channel_worker_reraises_at_recv_report() {
        let mut link = ChannelTransport::spawn(4, 2, 2);
        link.poison();
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = link.recv_report();
            }),
        )
        .expect_err("worker panic must re-raise");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(msg.contains("poisoned shard worker"), "{msg}");
    }

    #[test]
    fn connect_addr_lists_parse_and_trim() {
        assert_eq!(
            parse_connect_addrs("h1:70, h2:71 ,,h3:72"),
            vec!["h1:70", "h2:71", "h3:72"]
        );
        assert_eq!(parse_connect_addrs("one:1"), vec!["one:1"]);
        assert!(parse_connect_addrs(" , ").is_empty());
    }

    #[test]
    fn link_stats_merge_elementwise() {
        let a = LinkStats { stalls: 1, tx_bytes: 10, rx_bytes: 2 };
        let b = LinkStats { stalls: 2, tx_bytes: 5, rx_bytes: 0 };
        assert_eq!(
            a.merged(b),
            LinkStats { stalls: 3, tx_bytes: 15, rx_bytes: 2 }
        );
        let agg = TransportStats {
            transport: "channel",
            per_shard: vec![a, b],
            retired: LinkStats::default(),
        };
        assert_eq!(agg.total(), a.merged(b));
        // Retired-link counters (elastic re-plans) fold into the total.
        let agg = TransportStats {
            transport: "channel",
            per_shard: vec![a],
            retired: b,
        };
        assert_eq!(agg.total(), a.merged(b));
    }
}
