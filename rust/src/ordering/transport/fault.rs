//! Seeded fault injection for shard transports — the chaos layer
//! behind the `fault-injection` feature (also compiled for unit
//! tests).
//!
//! [`FaultTransport`] wraps any [`ShardTransport`] and perturbs the
//! link according to a deterministic, seeded [`FaultPlan`]: dropped
//! blocks (the rows silently vanish), duplicated blocks (the worker
//! sees an epoch-overflowing replay), delayed deliveries, and mid-epoch
//! disconnects. The coordinator contract under every fault is the one
//! the healthy transports already guarantee: the failure surfaces as a
//! **typed error at the epoch boundary** (or, for the in-process
//! channel transport, the worker's own panic payload) — never a hang
//! and never a partially merged order. `tests/transport.rs` asserts
//! exactly that under the CI `chaos` job's timeout guard, and the
//! elastic coordinator's shard-loss re-planning is exercised by
//! injecting disconnects into its links.
//!
//! Faults are injected on the coordinator→worker path only; the plan is
//! a pure function of its seed, so every chaos failure reproduces from
//! the printed seed.

use super::{EpochReport, LinkStats, ShardTransport, TransportError};
use crate::ordering::queue::ScratchBlock;
use crate::util::rng::Rng;

/// A deterministic fault schedule for one shard link. Block indices
/// count `send_block` calls on this link from 0, across epochs.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Blocks whose rows are silently dropped (an empty block is
    /// forwarded in their place so pooled buffers keep circulating).
    pub drop_blocks: Vec<usize>,
    /// Blocks delivered twice (the duplicate is a fresh copy).
    pub dup_blocks: Vec<usize>,
    /// `(block index, delay in milliseconds)` sleeps before delivery.
    pub delay_blocks: Vec<(usize, u64)>,
    /// Kill the link just before this `send_block` call (mid-epoch
    /// disconnect: the inner transport is dropped, every later call
    /// fails, and `recv_report` returns a typed `Disconnected`).
    pub disconnect_before: Option<usize>,
}

impl FaultPlan {
    /// The empty plan (a transparent wrapper).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A plan that injects exactly one silent block drop.
    pub fn drop_block(at: usize) -> FaultPlan {
        FaultPlan { drop_blocks: vec![at], ..FaultPlan::default() }
    }

    /// A plan that delivers one block twice.
    pub fn duplicate_block(at: usize) -> FaultPlan {
        FaultPlan { dup_blocks: vec![at], ..FaultPlan::default() }
    }

    /// A plan that kills the link just before its `at`-th block send.
    pub fn disconnect_before(at: usize) -> FaultPlan {
        FaultPlan {
            disconnect_before: Some(at),
            ..FaultPlan::default()
        }
    }

    /// A seeded random plan over a link expected to carry about
    /// `expected_blocks` sends: one drop, one duplicate, and one short
    /// delay at independently drawn indices (no disconnect — inject
    /// that explicitly where the test wants it). Pure in `seed`.
    pub fn seeded(seed: u64, expected_blocks: usize) -> FaultPlan {
        let span = expected_blocks.max(1) as u64;
        let mut rng = Rng::new(seed ^ 0xFA17);
        FaultPlan {
            drop_blocks: vec![rng.gen_range(span) as usize],
            dup_blocks: vec![rng.gen_range(span) as usize],
            delay_blocks: vec![(
                rng.gen_range(span) as usize,
                1 + rng.gen_range(3),
            )],
            disconnect_before: None,
        }
    }
}

/// A [`ShardTransport`] wrapper that injects the faults of a
/// [`FaultPlan`] into the coordinator→worker path. See the module docs
/// for the contract every fault must still satisfy.
pub struct FaultTransport {
    inner: Option<Box<dyn ShardTransport>>,
    plan: FaultPlan,
    blocks_seen: usize,
    injected: Vec<String>,
    /// Cached stats snapshot so counters survive an injected
    /// disconnect (the inner link is dropped on injection).
    last_stats: LinkStats,
}

impl FaultTransport {
    /// Wrap `inner` under `plan`.
    pub fn new(
        inner: Box<dyn ShardTransport>,
        plan: FaultPlan,
    ) -> FaultTransport {
        FaultTransport {
            inner: Some(inner),
            plan,
            blocks_seen: 0,
            injected: Vec::new(),
            last_stats: LinkStats::default(),
        }
    }

    /// Human-readable log of the faults injected so far (test
    /// assertions: the planned faults actually fired).
    pub fn injected(&self) -> &[String] {
        &self.injected
    }
}

impl ShardTransport for FaultTransport {
    fn acquire(&mut self) -> Option<ScratchBlock> {
        self.inner.as_mut()?.acquire()
    }

    fn send_block(&mut self, block: ScratchBlock) -> bool {
        let k = self.blocks_seen;
        self.blocks_seen += 1;
        if self.plan.disconnect_before == Some(k) {
            if let Some(inner) = self.inner.take() {
                self.last_stats = inner.stats();
            }
            self.injected
                .push(format!("disconnect before block {k}"));
            return false;
        }
        let Some(inner) = self.inner.as_mut() else {
            return false;
        };
        if let Some(&(_, ms)) = self
            .plan
            .delay_blocks
            .iter()
            .find(|&&(at, _)| at == k)
        {
            self.injected.push(format!("delay {ms}ms at block {k}"));
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        // Drop BEFORE duplicating: a drop and a dup colliding on the
        // same index must still lose the rows (an empty original plus
        // a full copy would cancel out and no fault would surface).
        let mut block = block;
        if self.plan.drop_blocks.contains(&k) {
            self.injected.push(format!(
                "drop block {k} ({} rows)",
                block.rows()
            ));
            block.clear(); // forward empty: rows vanish, buffer circulates
        }
        let duplicate = if self.plan.dup_blocks.contains(&k) {
            let mut copy = ScratchBlock::new(block.dim());
            for row in block.as_grad_block().iter_rows() {
                copy.push_row(row);
            }
            self.injected.push(format!("duplicate block {k}"));
            Some(copy)
        } else {
            None
        };
        let mut ok = inner.send_block(block);
        if let Some(copy) = duplicate {
            ok = inner.send_block(copy) && ok;
        }
        ok
    }

    fn end_epoch(&mut self) -> bool {
        match self.inner.as_mut() {
            Some(inner) => inner.end_epoch(),
            None => false,
        }
    }

    fn recv_report(&mut self) -> Result<EpochReport, TransportError> {
        match self.inner.as_mut() {
            Some(inner) => inner.recv_report(),
            None => Err(TransportError::Disconnected(
                "injected fault: link killed mid-epoch".to_string(),
            )),
        }
    }

    fn stats(&self) -> LinkStats {
        match self.inner.as_ref() {
            Some(inner) => inner.stats(),
            None => self.last_stats,
        }
    }

    fn buffer_bytes(&self) -> usize {
        self.inner.as_ref().map(|i| i.buffer_bytes()).unwrap_or(0)
    }

    fn seed_order(&mut self, order: &[usize]) -> bool {
        // Seeding happens between epochs, outside the fault window the
        // plan models (block sends), so it is forwarded unperturbed.
        match self.inner.as_mut() {
            Some(inner) => inner.seed_order(order),
            None => false,
        }
    }

    #[cfg(test)]
    fn poison(&mut self) {
        if let Some(inner) = self.inner.as_mut() {
            inner.poison();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::transport::ChannelTransport;

    fn link(n: usize, d: usize, plan: FaultPlan) -> FaultTransport {
        FaultTransport::new(
            Box::new(ChannelTransport::spawn(n, d, 2)),
            plan,
        )
    }

    #[test]
    fn transparent_plan_round_trips() {
        let mut l = link(2, 2, FaultPlan::none());
        let mut b = l.acquire().unwrap();
        b.push_row(&[1.0, -1.0]);
        b.push_row(&[-1.0, 1.0]);
        assert!(l.send_block(b));
        assert!(l.end_epoch());
        let report = l.recv_report().unwrap();
        assert_eq!(report.order.len(), 2);
        assert!(l.injected().is_empty());
    }

    #[test]
    fn injected_disconnect_yields_typed_error_not_hang() {
        let mut l = link(2, 2, FaultPlan::disconnect_before(0));
        let mut b = l.acquire().unwrap();
        b.push_row(&[1.0, -1.0]);
        assert!(!l.send_block(b), "killed link must refuse the send");
        assert!(l.acquire().is_none());
        assert!(!l.end_epoch());
        let err = l.recv_report().expect_err("typed disconnect");
        assert!(matches!(err, TransportError::Disconnected(_)), "{err}");
        assert_eq!(l.injected().len(), 1);
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(9, 40);
        let b = FaultPlan::seeded(9, 40);
        assert_eq!(a.drop_blocks, b.drop_blocks);
        assert_eq!(a.dup_blocks, b.dup_blocks);
        assert_eq!(a.delay_blocks, b.delay_blocks);
        let c = FaultPlan::seeded(10, 40);
        assert!(
            a.drop_blocks != c.drop_blocks
                || a.dup_blocks != c.dup_blocks
                || a.delay_blocks != c.delay_blocks,
            "different seeds should differ somewhere"
        );
    }
}
