//! TCP shard transport — CD-GraB's order exchange over real sockets.
//!
//! Each shard balancer becomes a **worker**: a peer that accepts one
//! TCP connection per shard, runs [`crate::ordering::PairBalance`] over
//! the blocks it receives, and answers every `EpochEnd` with the
//! shard's next local order. Workers run either
//!
//! * **in-process over loopback** ([`spawn_loopback`]) — the listener
//!   and one thread per accepted connection live in this process; used
//!   by tests, benches, and the default `--transport tcp` mode; or
//! * **in a separate OS process** ([`run_worker_server`]) — started
//!   with `grab exp cdgrab --listen ADDR`; a coordinator started with
//!   `--connect ADDR` dials it once per shard.
//!
//! Per-connection protocol (frames per `util::ser`, payloads per
//! [`super::codec`]):
//!
//! ```text
//! coordinator                         worker
//!   Hello {local_n, d,  ───────────▶
//!          generation}
//!                       ◀───────────  Ack
//!   Block [rows × d]    ───────────▶            (repeat per microbatch)
//!   EpochEnd            ───────────▶
//!                       ◀───────────  Report {order, state_bytes}
//!   (socket close = shutdown)
//! ```
//!
//! Backpressure is the kernel socket buffer (a full buffer blocks the
//! coordinator's `write_all`), so [`ShardTransport::acquire`] never
//! stalls on a TCP link and its `stalls` counter stays 0 — wire bytes
//! are the comparable cost metric instead. A peer failure (reset, EOF,
//! malformed frame) marks the link dead; the coordinator surfaces it at
//! the epoch boundary exactly like a worker panic.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::codec::{
    decode_block, decode_hello, decode_lease, decode_report,
    decode_seed, encode_block, encode_hello, encode_register,
    encode_report, encode_seed, Hello, Register,
};
use super::{EpochReport, LinkStats, ShardTransport, TransportError};
use crate::ordering::queue::ScratchBlock;
use crate::ordering::{GradBlock, OrderPolicy, PairBalance};
use crate::util::ser::{
    read_frame, write_frame, FrameKind, FrameReadError, WireError,
    FRAME_HEADER_LEN, MAX_FRAME_PAYLOAD,
};

/// Default upper bound (seconds) on waiting for any single frame from a
/// peer. Generous — a healthy worker answers an `EpochEnd` in
/// microseconds — but finite, so a hung socket turns into a typed
/// boundary error instead of stalling the run (and CI) forever.
/// Overridable per run with `--read-timeout` (the order-service
/// daemon's registration heartbeats want seconds, not minutes).
pub const DEFAULT_READ_TIMEOUT_SECS: u64 = 120;

/// [`DEFAULT_READ_TIMEOUT_SECS`] as a [`Duration`].
pub fn default_read_timeout() -> Duration {
    Duration::from_secs(DEFAULT_READ_TIMEOUT_SECS)
}

/// Coordinator-side endpoint of one shard link over TCP. Created by
/// [`connect`]; implements [`ShardTransport`] with the same observable
/// behavior as the in-process channel backend.
pub struct TcpTransport {
    stream: TcpStream,
    /// Free gather buffers; recycled synchronously after each send, so
    /// acquisition never blocks (socket writes are the backpressure).
    pool: Vec<ScratchBlock>,
    payload_buf: Vec<u8>,
    frame_buf: Vec<u8>,
    read_buf: Vec<u8>,
    d: usize,
    local_n: usize,
    read_timeout: Duration,
    tx_bytes: u64,
    rx_bytes: u64,
    dead: Option<String>,
}

/// Open one shard link: dial `addr`, handshake
/// `Hello{local_n, d, generation}` / `Ack`, and return the transport.
/// `generation` is the coordinator's topology generation (0 for a
/// static run; an elastic coordinator bumps it on every re-split, and
/// the fresh Hello *is* the shard-migration re-handshake). Fails with
/// a typed error — leaving no half-open link behind — on connection
/// refusal, handshake rejection, or protocol mismatch.
pub fn connect<A: ToSocketAddrs>(
    addr: A,
    local_n: usize,
    d: usize,
    generation: u64,
    read_timeout: Duration,
) -> Result<TcpTransport, TransportError> {
    let stream = TcpStream::connect(addr)?;
    from_stream(stream, local_n, d, generation, read_timeout)
}

/// [`connect`] over an already-open stream — the order-service daemon's
/// path, where the *worker* dialed in and registered
/// ([`run_registered_worker`]) and the coordinator performs the same
/// `Hello`/`Ack` handshake over the held registration socket when the
/// worker is leased to a job.
pub fn from_stream(
    stream: TcpStream,
    local_n: usize,
    d: usize,
    generation: u64,
    read_timeout: Duration,
) -> Result<TcpTransport, TransportError> {
    assert!(d > 0, "tcp shard link needs a positive dimension");
    assert!(
        local_n <= u32::MAX as usize && d <= u32::MAX as usize,
        "shard size / dimension over wire limit"
    );
    assert!(
        read_timeout > Duration::ZERO,
        "a zero read timeout would block forever"
    );
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(read_timeout))?;
    let mut t = TcpTransport {
        stream,
        pool: vec![ScratchBlock::new(d)],
        payload_buf: Vec::new(),
        frame_buf: Vec::new(),
        read_buf: Vec::new(),
        d,
        local_n,
        read_timeout,
        tx_bytes: 0,
        rx_bytes: 0,
        dead: None,
    };
    encode_hello(
        Hello {
            local_n: local_n as u32,
            d: d as u32,
            generation: generation.min(u32::MAX as u64) as u32,
        },
        &mut t.payload_buf,
    );
    let hello = std::mem::take(&mut t.payload_buf);
    t.write(FrameKind::Hello, &hello).map_err(|e| {
        TransportError::Handshake(format!("sending hello: {e}"))
    })?;
    t.payload_buf = hello;
    match read_frame(&mut t.stream, &mut t.read_buf) {
        Ok(FrameKind::Ack) => {}
        Ok(other) => {
            return Err(TransportError::Handshake(format!(
                "expected ack, peer sent {other:?}"
            )))
        }
        Err(e) => {
            return Err(TransportError::Handshake(format!(
                "reading ack: {e}"
            )))
        }
    }
    t.rx_bytes += t.read_buf.len() as u64;
    Ok(t)
}

impl TcpTransport {
    fn write(
        &mut self,
        kind: FrameKind,
        payload: &[u8],
    ) -> std::io::Result<()> {
        write_frame(&mut self.stream, kind, payload, &mut self.frame_buf)?;
        self.tx_bytes += self.frame_buf.len() as u64;
        Ok(())
    }
}

impl ShardTransport for TcpTransport {
    fn acquire(&mut self) -> Option<ScratchBlock> {
        if self.dead.is_some() {
            return None;
        }
        Some(match self.pool.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => ScratchBlock::new(self.d),
        })
    }

    fn send_block(&mut self, block: ScratchBlock) -> bool {
        if self.dead.is_some() {
            return false;
        }
        // A gather too large for one frame must become a typed
        // boundary failure, not an encode_frame assert mid-epoch. (The
        // trainer's microbatch × d blocks sit far below the 256 MiB
        // cap; this guards pathological configs.)
        let payload_len = 8 + block.rows() * block.dim() * 4;
        if payload_len > MAX_FRAME_PAYLOAD {
            self.dead = Some(format!(
                "gathered block of {payload_len} bytes exceeds the \
                 {MAX_FRAME_PAYLOAD}-byte frame cap"
            ));
            self.pool.push(block);
            return false;
        }
        let mut payload = std::mem::take(&mut self.payload_buf);
        encode_block(block.as_grad_block().data(), self.d, &mut payload);
        let ok = match self.write(FrameKind::Block, &payload) {
            Ok(()) => true,
            Err(e) => {
                self.dead = Some(format!("block send failed: {e}"));
                false
            }
        };
        self.payload_buf = payload;
        self.pool.push(block);
        ok
    }

    fn end_epoch(&mut self) -> bool {
        if self.dead.is_some() {
            return false;
        }
        match self.write(FrameKind::EpochEnd, &[]) {
            Ok(()) => true,
            Err(e) => {
                self.dead = Some(format!("epoch-end send failed: {e}"));
                false
            }
        }
    }

    fn recv_report(&mut self) -> Result<EpochReport, TransportError> {
        if let Some(why) = &self.dead {
            return Err(TransportError::Disconnected(why.clone()));
        }
        let kind = match read_frame(&mut self.stream, &mut self.read_buf)
        {
            Ok(k) => k,
            Err(e) => {
                // A read-timeout expiry is a *link* failure, not a
                // generic socket error: typed so the elastic
                // coordinator's boundary re-plan can act on it like
                // any other lost shard. (SO_RCVTIMEO surfaces as
                // TimedOut or WouldBlock depending on the platform.)
                let err = match e {
                    FrameReadError::Io(ioe)
                        if matches!(
                            ioe.kind(),
                            std::io::ErrorKind::TimedOut
                                | std::io::ErrorKind::WouldBlock
                        ) =>
                    {
                        TransportError::Timeout {
                            after: self.read_timeout,
                        }
                    }
                    other => other.into(),
                };
                self.dead = Some(err.to_string());
                return Err(err);
            }
        };
        if kind != FrameKind::Report {
            let err = TransportError::Wire(WireError::Malformed(format!(
                "expected report frame, got {kind:?}"
            )));
            self.dead = Some(err.to_string());
            return Err(err);
        }
        self.rx_bytes += self.read_buf.len() as u64;
        let (order, state_bytes) = match decode_report(
            &self.read_buf[FRAME_HEADER_LEN..],
            self.local_n,
        ) {
            Ok(v) => v,
            Err(e) => {
                let err = TransportError::Wire(e);
                self.dead = Some(err.to_string());
                return Err(err);
            }
        };
        Ok(EpochReport { order, state_bytes })
    }

    fn stats(&self) -> LinkStats {
        LinkStats {
            stalls: 0,
            tx_bytes: self.tx_bytes,
            rx_bytes: self.rx_bytes,
        }
    }

    fn buffer_bytes(&self) -> usize {
        self.pool.iter().map(|b| b.capacity_bytes()).sum::<usize>()
            + self.payload_buf.capacity()
            + self.frame_buf.capacity()
            + self.read_buf.capacity()
    }

    fn seed_order(&mut self, order: &[usize]) -> bool {
        if self.dead.is_some() || order.len() != self.local_n {
            return false;
        }
        let mut payload = std::mem::take(&mut self.payload_buf);
        encode_seed(order, &mut payload);
        let ok = match self.write(FrameKind::Seed, &payload) {
            Ok(()) => true,
            Err(e) => {
                self.dead = Some(format!("seed send failed: {e}"));
                false
            }
        };
        self.payload_buf = payload;
        // No reply frame: TCP preserves per-link order, so the seed is
        // guaranteed to be applied before any block that follows it —
        // the same argument that makes Block ordering sound.
        ok
    }
}

/// Open one TCP link per entry of `sizes` against the same worker
/// address (one connection = one shard), all at topology `generation`.
pub fn connect_shards<A: ToSocketAddrs + Copy>(
    addr: A,
    sizes: &[usize],
    d: usize,
    generation: u64,
    read_timeout: Duration,
) -> Result<Vec<Box<dyn ShardTransport>>, TransportError> {
    let mut links: Vec<Box<dyn ShardTransport>> =
        Vec::with_capacity(sizes.len());
    for &size in sizes {
        links.push(Box::new(connect(
            addr,
            size,
            d,
            generation,
            read_timeout,
        )?));
    }
    Ok(links)
}

/// Open one TCP link per entry of `sizes` against a *pool* of worker
/// servers: shard `w` first dials `addrs[w % addrs.len()]` and falls
/// through the rest of the list on connection/handshake failure, so a
/// dead server's shards land on the survivors (the elastic
/// re-handshake path after a worker-process loss). Deterministic: the
/// dial order is a pure function of the shard index and the address
/// list. Fails only when a shard cannot reach *any* server.
pub fn connect_shards_multi(
    addrs: &[String],
    sizes: &[usize],
    d: usize,
    generation: u64,
    read_timeout: Duration,
) -> Result<Vec<Box<dyn ShardTransport>>, TransportError> {
    assert!(!addrs.is_empty(), "need at least one worker address");
    let mut links: Vec<Box<dyn ShardTransport>> =
        Vec::with_capacity(sizes.len());
    for (w, &size) in sizes.iter().enumerate() {
        let mut last_err = None;
        let mut opened = false;
        for k in 0..addrs.len() {
            let addr = &addrs[(w + k) % addrs.len()];
            match connect(addr.as_str(), size, d, generation, read_timeout)
            {
                Ok(link) => {
                    links.push(Box::new(link));
                    opened = true;
                    break;
                }
                Err(e) => {
                    eprintln!(
                        "[transport] shard {w}: worker {addr} \
                         unreachable ({e}); trying the next server"
                    );
                    last_err = Some(e);
                }
            }
        }
        if !opened {
            return Err(last_err.unwrap_or_else(|| {
                TransportError::Handshake(
                    "no worker address accepted the link".to_string(),
                )
            }));
        }
    }
    Ok(links)
}

/// Serve one accepted worker connection to completion: handshake, then
/// balance blocks and answer epoch-end frames until the coordinator
/// closes the socket. Every protocol violation returns a typed error
/// (the handler never panics on wire input).
pub fn serve_connection(
    mut stream: TcpStream,
) -> Result<(), TransportError> {
    stream.set_nodelay(true)?;
    let mut buf = Vec::new();
    let mut rows_buf: Vec<f32> = Vec::new();
    let mut report_payload = Vec::new();
    let mut scratch = Vec::new();

    // Handshake: the first frame must be a Hello.
    match read_frame(&mut stream, &mut buf) {
        Ok(FrameKind::Hello) => {}
        Ok(other) => {
            return Err(TransportError::Handshake(format!(
                "expected hello, got {other:?}"
            )))
        }
        Err(e) => return Err(e.into()),
    }
    let hello = decode_hello(&buf[FRAME_HEADER_LEN..])?;
    if hello.d == 0 {
        return Err(TransportError::Handshake(
            "hello declares dimension 0".to_string(),
        ));
    }
    let d = hello.d as usize;
    let local_n = hello.local_n as usize;
    let mut balancer = PairBalance::new(local_n, d);
    let mut cursor = 0usize;
    write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)?;

    loop {
        match read_frame(&mut stream, &mut buf) {
            Ok(FrameKind::Block) => {
                let rows = decode_block(
                    &buf[FRAME_HEADER_LEN..],
                    d,
                    &mut rows_buf,
                )?;
                // Validate the epoch's row budget here — the balancer's
                // own bounds checks are assertions, and wire input must
                // produce typed errors, never worker panics.
                if cursor + rows > local_n {
                    return Err(TransportError::Wire(
                        WireError::Malformed(format!(
                            "epoch overflow: {rows} rows after \
                             {cursor} of {local_n}"
                        )),
                    ));
                }
                if rows > 0 {
                    balancer.observe_block(
                        cursor..cursor + rows,
                        &GradBlock::new(&rows_buf, d),
                    );
                    cursor += rows;
                }
            }
            Ok(FrameKind::EpochEnd) => {
                if cursor != local_n {
                    return Err(TransportError::Wire(
                        WireError::Malformed(format!(
                            "epoch end after {cursor} of {local_n} \
                             rows"
                        )),
                    ));
                }
                balancer.epoch_end();
                cursor = 0;
                encode_report(
                    balancer.epoch_order(0),
                    balancer.state_bytes(),
                    &mut report_payload,
                );
                write_frame(
                    &mut stream,
                    FrameKind::Report,
                    &report_payload,
                    &mut scratch,
                )?;
            }
            Ok(FrameKind::Seed) => {
                // Checkpoint resume: overwrite the balancer's next
                // local order. Only legal between epochs — a mid-epoch
                // seed is a protocol violation, answered with a typed
                // error like every other invalid wire input.
                if cursor != 0 {
                    return Err(TransportError::Wire(
                        WireError::Malformed(format!(
                            "seed frame mid-epoch after {cursor} of \
                             {local_n} rows"
                        )),
                    ));
                }
                let order =
                    decode_seed(&buf[FRAME_HEADER_LEN..], local_n)?;
                // decode_seed validated the permutation; a false here
                // would mean the balancer disagrees on local_n.
                assert!(balancer.restore_order(&order));
            }
            Ok(other) => {
                return Err(TransportError::Wire(WireError::Malformed(
                    format!("unexpected frame {other:?} on shard link"),
                )))
            }
            // Coordinator closed the link: clean worker shutdown.
            Err(FrameReadError::Io(e))
                if e.kind() == std::io::ErrorKind::UnexpectedEof =>
            {
                return Ok(())
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Spawn an in-process loopback worker pool: bind an ephemeral
/// 127.0.0.1 port, accept exactly `conns` connections (one per shard),
/// serve each on its own thread, and exit once every link closes.
/// Returns the address to [`connect`] to.
pub fn spawn_loopback(conns: usize) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        let mut handles = Vec::with_capacity(conns);
        for _ in 0..conns {
            match listener.accept() {
                Ok((stream, _)) => {
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = serve_connection(stream) {
                            eprintln!(
                                "[transport] loopback worker: {e}"
                            );
                        }
                    }));
                }
                Err(e) => {
                    eprintln!("[transport] loopback accept: {e}");
                    break;
                }
            }
        }
        for h in handles {
            let _ = h.join();
        }
    });
    Ok(addr)
}

/// Run a blocking shard-worker server (`grab exp cdgrab --listen`):
/// accept connections forever — or exactly `max_conns` when given, for
/// scripted runs that should exit once a known coordinator is done —
/// and serve each shard link on its own thread.
pub fn run_worker_server(
    addr: &str,
    max_conns: Option<usize>,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "[transport] shard worker listening on {}",
        listener.local_addr()?
    );
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut served = 0usize;
    let mut accept_errors = 0u32;
    loop {
        if let Some(cap) = max_conns {
            if served >= cap {
                break;
            }
        }
        // Reap finished links so the serve-forever mode does not
        // accumulate one JoinHandle per connection ever served.
        handles.retain(|h| !h.is_finished());
        // Transient accept failures (ECONNABORTED from a connection
        // reset pre-accept, momentary EMFILE) must not kill a server
        // with live shard links; only a persistently failing listener
        // is fatal.
        let (stream, peer) = match listener.accept() {
            Ok(conn) => {
                accept_errors = 0;
                conn
            }
            Err(e) => {
                accept_errors += 1;
                eprintln!("[transport] accept failed: {e}");
                anyhow::ensure!(
                    accept_errors < 32,
                    "listener failing persistently: {e}"
                );
                continue;
            }
        };
        served += 1;
        eprintln!("[transport] shard link {served} from {peer}");
        handles.push(std::thread::spawn(move || {
            if let Err(e) = serve_connection(stream) {
                eprintln!("[transport] worker link from {peer}: {e}");
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Dial an order-service daemon, register, and return the held socket
/// once the daemon answers with a `Lease`. The registration handshake
/// is bounded by `read_timeout`; the wait for job traffic afterwards
/// is not (a registered worker may sit idle between jobs for as long
/// as the daemon keeps it).
pub fn register_with_daemon(
    addr: &str,
    name: &str,
    read_timeout: Duration,
) -> Result<(TcpStream, u32, u32), TransportError> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(read_timeout))?;
    let mut payload = Vec::new();
    encode_register(
        &Register {
            capacity: 1,
            generation: 0,
            name: name.to_string(),
        },
        &mut payload,
    );
    let mut scratch = Vec::new();
    write_frame(&mut stream, FrameKind::Register, &payload, &mut scratch)
        .map_err(|e| {
            TransportError::Handshake(format!("sending register: {e}"))
        })?;
    let mut buf = Vec::new();
    match read_frame(&mut stream, &mut buf) {
        Ok(FrameKind::Lease) => {}
        Ok(other) => {
            return Err(TransportError::Handshake(format!(
                "expected lease, daemon sent {other:?}"
            )))
        }
        Err(e) => {
            return Err(TransportError::Handshake(format!(
                "reading lease: {e}"
            )))
        }
    }
    let lease = decode_lease(&buf[FRAME_HEADER_LEN..])?;
    stream.set_read_timeout(None)?;
    Ok((stream, lease.worker_id, lease.generation))
}

/// Run a registered shard worker (`grab exp cdgrab --register ADDR`):
/// dial the order-service daemon at `addr`, register, and serve the
/// ordinary `Hello` shard session the daemon runs over the held socket
/// whenever this worker is leased to a job. One registration serves
/// one job session — the daemon closes the socket at the job boundary
/// and the worker re-registers, so a drained worker never detaches
/// mid-epoch (docs/determinism.md contracts 5/6 are per-session).
///
/// Exits `Ok` once the daemon goes away *after* a successful
/// registration (the drain/shutdown path); fails only when the first
/// registration cannot be established.
pub fn run_registered_worker(
    addr: &str,
    read_timeout: Duration,
) -> anyhow::Result<()> {
    let name = format!("worker-{}", std::process::id());
    let mut registered_before = false;
    let mut failures = 0u32;
    loop {
        let stream =
            match register_with_daemon(addr, &name, read_timeout) {
                Ok((stream, id, generation)) => {
                    failures = 0;
                    registered_before = true;
                    eprintln!(
                        "[service] registered as {name} \
                         (worker {id}, registry generation {generation})"
                    );
                    stream
                }
                Err(e) => {
                    if registered_before {
                        eprintln!(
                            "[service] daemon gone ({e}); worker done"
                        );
                        return Ok(());
                    }
                    failures += 1;
                    anyhow::ensure!(
                        failures < 5,
                        "could not register with {addr}: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(300));
                    continue;
                }
            };
        match serve_connection(stream) {
            // Clean close (drain, job boundary, or daemon shutdown):
            // try to re-register; a refused dial ends the worker above.
            Ok(()) | Err(TransportError::Disconnected(_)) => {
                eprintln!("[service] session closed; re-registering");
            }
            Err(e) => {
                eprintln!("[service] session failed ({e}); re-registering");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    // Every test here opens a real loopback socket, which Miri cannot
    // model — hence the `cfg_attr(miri, ignore)` gates. The frame
    // codec these links speak is covered under Miri by the codec unit
    // suite.

    #[test]
    #[cfg_attr(miri, ignore)]
    fn tcp_link_round_trips_an_epoch() {
        let addr = spawn_loopback(1).unwrap();
        let d = 2;
        let mut link = connect(addr, 4, d, 0, default_read_timeout()).unwrap();
        let mut scratch = link.acquire().unwrap();
        for row in [[1.0f32, 0.0], [-1.0, 0.0], [0.0, 2.0], [0.0, -2.0]] {
            scratch.push_row(&row);
        }
        assert!(link.send_block(scratch));
        assert!(link.end_epoch());
        let report = link.recv_report().unwrap();
        let mut sorted = report.order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        let stats = link.stats();
        assert_eq!(stats.stalls, 0);
        assert!(stats.tx_bytes > 0 && stats.rx_bytes > 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn connect_rejects_a_peer_that_closes_immediately() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream); // slam the door before the handshake
        });
        let err = connect(addr, 4, 2, 0, default_read_timeout()).expect_err("handshake must fail");
        assert!(matches!(err, TransportError::Handshake(_)), "{err}");
        h.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn connect_rejects_a_peer_speaking_garbage() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            // Drain the hello, then answer with bytes that are not a
            // valid frame.
            let mut sink = [0u8; 64];
            let _ = stream.read(&mut sink);
            let _ = stream.write_all(b"definitely not a frame header");
        });
        let err = connect(addr, 4, 2, 0, default_read_timeout()).expect_err("handshake must fail");
        assert!(matches!(err, TransportError::Handshake(_)), "{err}");
        h.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn worker_rejects_wrong_first_frame_without_panicking() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            serve_connection(stream)
        });
        let mut client = TcpStream::connect(addr).unwrap();
        let mut scratch = Vec::new();
        // EpochEnd before any handshake: a protocol violation.
        write_frame(&mut client, FrameKind::EpochEnd, &[], &mut scratch)
            .unwrap();
        let err = server.join().unwrap().expect_err("must reject");
        assert!(matches!(err, TransportError::Handshake(_)), "{err}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn worker_rejects_short_and_overfull_epochs_without_panicking() {
        // Premature EpochEnd and over-budget Blocks are semantically
        // invalid wire input: the worker must answer with a typed
        // error, not hit the balancer's assertions.
        for overfull in [false, true] {
            let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            let addr = listener.local_addr().unwrap();
            let server = std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                serve_connection(stream)
            });
            let mut client = TcpStream::connect(addr).unwrap();
            let mut payload = Vec::new();
            let mut scratch = Vec::new();
            encode_hello(
                Hello { local_n: 2, d: 1, generation: 0 },
                &mut payload,
            );
            write_frame(
                &mut client, FrameKind::Hello, &payload, &mut scratch,
            )
            .unwrap();
            let mut buf = Vec::new();
            assert_eq!(
                read_frame(&mut client, &mut buf).unwrap(),
                FrameKind::Ack
            );
            if overfull {
                // 3 rows into a 2-unit shard.
                encode_block(&[1.0, 2.0, 3.0], 1, &mut payload);
                write_frame(
                    &mut client,
                    FrameKind::Block,
                    &payload,
                    &mut scratch,
                )
                .unwrap();
            } else {
                // Epoch boundary before any rows.
                write_frame(
                    &mut client, FrameKind::EpochEnd, &[], &mut scratch,
                )
                .unwrap();
            }
            let err = server
                .join()
                .expect("worker must not panic")
                .expect_err("invalid epoch traffic must be rejected");
            assert!(
                matches!(err, TransportError::Wire(_)),
                "overfull={overfull}: {err}"
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn mid_epoch_disconnect_is_reported_not_panicked() {
        // A worker that dies after accepting blocks: the link's sends
        // start failing (or the report read hits EOF), and the error is
        // a typed TransportError either way — the coordinator layer
        // turns it into an epoch-boundary panic.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            // Handshake properly, then vanish mid-epoch.
            assert_eq!(
                read_frame(&mut stream, &mut buf).unwrap(),
                FrameKind::Hello
            );
            let mut scratch = Vec::new();
            write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
                .unwrap();
            let _ = read_frame(&mut stream, &mut buf); // first block
            drop(stream);
        });
        let mut link = connect(addr, 8, 2, 0, default_read_timeout()).unwrap();
        let mut scratch = link.acquire().unwrap();
        scratch.push_row(&[1.0, -1.0]);
        let _ = link.send_block(scratch);
        // Depending on timing the failure lands on a later send or on
        // the report read; both must yield Err, never panic.
        let _ = link.end_epoch();
        let err = link.recv_report().expect_err("dead peer");
        let msg = err.to_string();
        assert!(!msg.is_empty());
        h.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn silent_peer_times_out_with_a_typed_link_failure() {
        // A worker that handshakes and then goes silent (wedged, not
        // dead: the socket stays open) must surface as
        // TransportError::Timeout after the configured read timeout —
        // the regression for the hardcoded 120 s READ_TIMEOUT that
        // made a wedged worker stall CI for two minutes per link.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            assert_eq!(
                read_frame(&mut stream, &mut buf).unwrap(),
                FrameKind::Hello
            );
            let mut scratch = Vec::new();
            write_frame(&mut stream, FrameKind::Ack, &[], &mut scratch)
                .unwrap();
            // Never answer anything again; hold the socket open until
            // the coordinator hangs up.
            while read_frame(&mut stream, &mut buf).is_ok() {}
        });
        let timeout = Duration::from_millis(100);
        let mut link = connect(addr, 2, 2, 0, timeout).unwrap();
        let mut scratch = link.acquire().unwrap();
        scratch.push_row(&[1.0, -1.0]);
        scratch.push_row(&[-1.0, 1.0]);
        assert!(link.send_block(scratch));
        assert!(link.end_epoch());
        let err = link.recv_report().expect_err("silent peer must time out");
        match err {
            TransportError::Timeout { after } => {
                assert_eq!(after, timeout)
            }
            other => panic!("expected Timeout, got {other}"),
        }
        // The link is dead from here on: a second receive reports the
        // recorded failure instead of waiting again.
        assert!(matches!(
            link.recv_report(),
            Err(TransportError::Disconnected(_))
        ));
        drop(link);
        h.join().unwrap();
    }
}
