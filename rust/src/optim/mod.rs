//! Optimizer substrate: momentum SGD with weight decay (the paper's
//! optimizer for every task) plus learning-rate schedules, including the
//! `ReduceLROnPlateau` recipe used for WikiText-2.

use crate::tensor;

/// Momentum SGD with (decoupled-from-momentum, PyTorch-style coupled)
/// L2 weight decay: v ← μ·v + (g + wd·w);  w ← w − lr·v.
pub struct MomentumSgd {
    /// Momentum coefficient μ.
    pub momentum: f32,
    /// Coupled L2 weight-decay coefficient.
    pub weight_decay: f32,
    velocity: Vec<f32>,
}

impl MomentumSgd {
    /// An optimizer over `dim` parameters with zeroed momentum.
    pub fn new(dim: usize, momentum: f64, weight_decay: f64) -> MomentumSgd {
        MomentumSgd {
            momentum: momentum as f32,
            weight_decay: weight_decay as f32,
            velocity: vec![0.0; dim],
        }
    }

    /// Apply one step with gradient `grad` at learning rate `lr`.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.velocity.len());
        let mu = self.momentum;
        let wd = self.weight_decay;
        let lr = lr as f32;
        for i in 0..params.len() {
            let g = grad[i] + wd * params[i];
            self.velocity[i] = mu * self.velocity[i] + g;
            params[i] -= lr * self.velocity[i];
        }
    }

    /// Zero the momentum buffer.
    pub fn reset(&mut self) {
        tensor::zero(&mut self.velocity);
    }

    /// Momentum buffer (for checkpointing).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore the momentum buffer from a checkpoint.
    pub fn set_velocity(&mut self, v: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(v.len() == self.velocity.len(),
                        "velocity length mismatch");
        self.velocity.copy_from_slice(v);
        Ok(())
    }
}

/// Learning-rate schedule state machine, driven by per-epoch train loss.
pub enum Scheduler {
    /// Fixed learning rate.
    Constant {
        /// The constant learning rate.
        lr: f64,
    },
    /// Multiply lr by `factor` when the best seen loss fails to improve by
    /// more than `threshold` for `patience` consecutive epochs (mode=min,
    /// matching the paper's PyTorch config for WikiText-2).
    ReduceOnPlateau {
        /// Current learning rate.
        lr: f64,
        /// Multiplier applied on decay.
        factor: f64,
        /// Non-improving epochs tolerated before a decay.
        patience: usize,
        /// Minimum improvement that counts as progress.
        threshold: f64,
        /// Best train loss seen so far.
        best: f64,
        /// Consecutive non-improving epochs.
        bad_epochs: usize,
    },
}

impl Scheduler {
    /// A constant-LR schedule.
    pub fn constant(lr: f64) -> Scheduler {
        Scheduler::Constant { lr }
    }

    /// A ReduceLROnPlateau schedule (mode=min) starting at `lr`.
    pub fn reduce_on_plateau(
        lr: f64,
        factor: f64,
        patience: usize,
        threshold: f64,
    ) -> Scheduler {
        Scheduler::ReduceOnPlateau {
            lr,
            factor,
            patience,
            threshold,
            best: f64::INFINITY,
            bad_epochs: 0,
        }
    }

    /// Current learning rate.
    pub fn lr(&self) -> f64 {
        match self {
            Scheduler::Constant { lr } => *lr,
            Scheduler::ReduceOnPlateau { lr, .. } => *lr,
        }
    }

    /// Snapshot the schedule's mutable state as
    /// `(lr, best, bad_epochs)` for checkpointing. Constant schedules
    /// report their fixed lr with inert `best`/`bad_epochs`.
    pub fn state(&self) -> (f64, f64, usize) {
        match self {
            Scheduler::Constant { lr } => (*lr, f64::INFINITY, 0),
            Scheduler::ReduceOnPlateau { lr, best, bad_epochs, .. } => {
                (*lr, *best, *bad_epochs)
            }
        }
    }

    /// Restore state captured by [`Scheduler::state`]. The schedule
    /// *shape* (factor/patience/threshold) comes from config; only the
    /// run-position fields are overwritten, so a resumed plateau
    /// schedule continues its decay history exactly.
    pub fn restore_state(&mut self, lr: f64, best: f64, bad_epochs: usize) {
        match self {
            Scheduler::Constant { lr: cur } => *cur = lr,
            Scheduler::ReduceOnPlateau {
                lr: cur,
                best: b,
                bad_epochs: bad,
                ..
            } => {
                *cur = lr;
                *b = best;
                *bad = bad_epochs;
            }
        }
    }

    /// Report the epoch's train loss; may decay the LR.
    pub fn epoch_feedback(&mut self, loss: f64) {
        if let Scheduler::ReduceOnPlateau {
            lr,
            factor,
            patience,
            threshold,
            best,
            bad_epochs,
        } = self
        {
            if loss < *best - *threshold {
                *best = loss;
                *bad_epochs = 0;
            } else {
                *bad_epochs += 1;
                if *bad_epochs > *patience {
                    *lr *= *factor;
                    *bad_epochs = 0;
                }
            }
        }
    }
}

/// Clip `grad` to global l2 norm `max_norm` in place (no-op if
/// `max_norm <= 0` or the norm is already within bounds). Returns the
/// pre-clip norm.
pub fn clip_global_norm(grad: &mut [f32], max_norm: f64) -> f64 {
    let norm = tensor::norm2(grad) as f64;
    if max_norm > 0.0 && norm > max_norm {
        tensor::scale(grad, (max_norm / norm) as f32);
    }
    norm
}

/// Gradient accumulator: averages `accum_steps * micro_grads` into one
/// optimizer-step gradient (the paper's GCC workaround, Listing 1).
pub struct GradAccumulator {
    acc: Vec<f32>,
    count: usize,
    target: usize,
}

impl GradAccumulator {
    /// An accumulator that means `target` gradients of size `dim`.
    pub fn new(dim: usize, target: usize) -> GradAccumulator {
        assert!(target > 0);
        GradAccumulator { acc: vec![0.0; dim], count: 0, target }
    }

    /// Add one per-example gradient; returns `Some(mean_grad)` when the
    /// accumulation window is full (caller steps the optimizer), after
    /// which the accumulator resets.
    pub fn push(&mut self, grad: &[f32]) -> Option<&[f32]> {
        tensor::add_into(&mut self.acc, grad);
        self.count += 1;
        if self.count == self.target {
            let inv = 1.0 / self.count as f32;
            tensor::scale(&mut self.acc, inv);
            self.count = 0;
            Some(&self.acc)
        } else {
            None
        }
    }

    /// After consuming the window returned by [`GradAccumulator::push`],
    /// zero the buffer.
    pub fn clear(&mut self) {
        tensor::zero(&mut self.acc);
        self.count = 0;
    }

    /// Flush a partial window at epoch end (returns None if empty).
    pub fn flush(&mut self) -> Option<&[f32]> {
        if self.count == 0 {
            return None;
        }
        let inv = 1.0 / self.count as f32;
        tensor::scale(&mut self.acc, inv);
        self.count = 0;
        Some(&self.acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_descends_quadratic() {
        // f(w) = 0.5 ||w||^2, grad = w: momentum SGD must converge to 0.
        let mut opt = MomentumSgd::new(4, 0.9, 0.0);
        let mut w = vec![1.0f32, -2.0, 3.0, -4.0];
        for _ in 0..200 {
            let g = w.clone();
            opt.step(&mut w, &g, 0.05);
        }
        assert!(tensor::norm2(&w) < 1e-3, "w={w:?}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = MomentumSgd::new(1, 0.0, 0.1);
        let mut w = vec![1.0f32];
        // Zero gradient: only decay acts.
        for _ in 0..10 {
            opt.step(&mut w, &[0.0], 0.1);
        }
        assert!(w[0] < 1.0 && w[0] > 0.0);
    }

    #[test]
    fn momentum_accelerates() {
        // With the same lr, momentum reaches lower loss faster on a
        // quadratic than plain SGD over few steps.
        let run = |mu: f64| {
            let mut opt = MomentumSgd::new(1, mu, 0.0);
            let mut w = vec![10.0f32];
            for _ in 0..20 {
                let g = vec![0.2 * w[0]];
                opt.step(&mut w, &g, 0.1);
            }
            w[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn plateau_scheduler_decays_after_patience() {
        let mut s = Scheduler::reduce_on_plateau(1.0, 0.1, 2, 0.01);
        assert_eq!(s.lr(), 1.0);
        s.epoch_feedback(5.0); // best = 5
        s.epoch_feedback(5.0); // bad 1
        s.epoch_feedback(5.0); // bad 2
        assert_eq!(s.lr(), 1.0);
        s.epoch_feedback(5.0); // bad 3 > patience -> decay
        assert!((s.lr() - 0.1).abs() < 1e-12);
        s.epoch_feedback(1.0); // improvement resets
        s.epoch_feedback(0.5);
        assert!((s.lr() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn accumulator_means_window() {
        let mut acc = GradAccumulator::new(2, 2);
        assert!(acc.push(&[1.0, 0.0]).is_none());
        {
            let g = acc.push(&[3.0, 2.0]).expect("window full");
            assert_eq!(g, &[2.0, 1.0]);
        }
        acc.clear();
        assert!(acc.push(&[5.0, 5.0]).is_none());
        let g = acc.flush().unwrap().to_vec();
        assert_eq!(g, vec![5.0, 5.0]);
    }

    #[test]
    fn clip_scales_only_above_threshold() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_global_norm(&mut g, 10.0);
        assert_eq!(g, vec![3.0, 4.0]);
        assert!((pre - 5.0).abs() < 1e-6);
        clip_global_norm(&mut g, 1.0);
        assert!((tensor::norm2(&g) - 1.0).abs() < 1e-5);
        let mut h = vec![3.0f32, 4.0];
        clip_global_norm(&mut h, 0.0); // off
        assert_eq!(h, vec![3.0, 4.0]);
    }

    #[test]
    fn accumulator_flush_empty_is_none() {
        let mut acc = GradAccumulator::new(2, 3);
        assert!(acc.flush().is_none());
    }

    #[test]
    fn scheduler_state_roundtrip_continues_decay_history() {
        // Drive a plateau schedule mid-way, snapshot, rebuild a fresh
        // schedule from "config", restore, and check both copies decay
        // in lockstep from there (the checkpoint/resume contract).
        let mut live = Scheduler::reduce_on_plateau(1.0, 0.1, 2, 0.01);
        live.epoch_feedback(5.0);
        live.epoch_feedback(5.0); // bad 1
        let (lr, best, bad) = live.state();
        let mut resumed = Scheduler::reduce_on_plateau(1.0, 0.1, 2, 0.01);
        resumed.restore_state(lr, best, bad);
        for loss in [5.0, 5.0, 5.0, 1.0, 1.0] {
            live.epoch_feedback(loss);
            resumed.epoch_feedback(loss);
            assert_eq!(live.lr().to_bits(), resumed.lr().to_bits());
        }
        // A constant schedule round-trips too.
        let c = Scheduler::constant(0.25);
        let (lr, best, bad) = c.state();
        let mut c2 = Scheduler::constant(0.0);
        c2.restore_state(lr, best, bad);
        assert_eq!(c2.lr(), 0.25);
    }
}
