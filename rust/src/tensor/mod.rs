//! Dense f32 vector math used on the L3 hot path.
//!
//! The GraB inner loop is two fused reductions (`dot`) plus a signed update
//! (`axpy`) per example; everything here is written allocation-free over
//! caller-provided slices. `dot`/`axpy` use 8-lane manual unrolling so LLVM
//! reliably vectorizes them (measured in benches/balance_hot.rs; see
//! EXPERIMENTS.md §Perf for the before/after of naive vs unrolled).

/// Dot product with 8-way unrolled accumulators.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            acc[lane] += a[off + lane] * b[off + lane];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    acc.iter().sum::<f32>() + tail
}

/// Naive scalar dot (kept for the perf ablation in benches/balance_hot.rs).
pub fn dot_naive(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += alpha * x`, 8-way unrolled.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            y[off + lane] += alpha * x[off + lane];
        }
    }
    for i in chunks * 8..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `out = a - b` (centered gradient), allocation-free.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Fused GraB decision statistic: returns `<s, g - m>` in one pass without
/// materializing the centered vector. Equivalent to
/// `dot(s, c)` with `c = g - m`, but with a single read of each operand.
pub fn dot_centered(s: &[f32], g: &[f32], m: &[f32]) -> f32 {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    // chunks_exact + fixed-size destructuring removes bounds checks and
    // lets LLVM keep 8 independent FMA accumulators (§Perf iteration 3).
    let mut acc = [0.0f32; 8];
    let (sc, st) = s.split_at(s.len() - s.len() % 8);
    let (gc, gt) = g.split_at(sc.len());
    let (mc, mt) = m.split_at(sc.len());
    for ((sv, gv), mv) in sc
        .chunks_exact(8)
        .zip(gc.chunks_exact(8))
        .zip(mc.chunks_exact(8))
    {
        for lane in 0..8 {
            acc[lane] += sv[lane] * (gv[lane] - mv[lane]);
        }
    }
    let mut tail = 0.0f32;
    for i in 0..st.len() {
        tail += st[i] * (gt[i] - mt[i]);
    }
    acc.iter().sum::<f32>() + tail
}

/// Fused signed update: `s += eps * (g - m)` in one pass.
pub fn axpy_centered(eps: f32, g: &[f32], m: &[f32], s: &mut [f32]) {
    assert_eq!(s.len(), g.len());
    assert_eq!(s.len(), m.len());
    let chunks = s.len() / 8;
    for i in 0..chunks {
        let off = i * 8;
        for lane in 0..8 {
            s[off + lane] += eps * (g[off + lane] - m[off + lane]);
        }
    }
    for i in chunks * 8..s.len() {
        s[i] += eps * (g[i] - m[i]);
    }
}

/// Fully fused GraB observe update: in ONE pass over the operands,
/// `s += eps * (g - m)` and `fresh += inv_n * g`. Saves a full re-read of
/// `g` vs doing the two updates separately (see EXPERIMENTS.md §Perf).
pub fn grab_update(
    eps: f32,
    inv_n: f32,
    g: &[f32],
    m: &[f32],
    s: &mut [f32],
    fresh: &mut [f32],
) {
    assert_eq!(g.len(), m.len());
    assert_eq!(g.len(), s.len());
    assert_eq!(g.len(), fresh.len());
    let split = g.len() - g.len() % 8;
    let (gc, gt) = g.split_at(split);
    let (mc, mt) = m.split_at(split);
    let (sc, st) = s.split_at_mut(split);
    let (fc, ft) = fresh.split_at_mut(split);
    for (((gv, mv), sv), fv) in gc
        .chunks_exact(8)
        .zip(mc.chunks_exact(8))
        .zip(sc.chunks_exact_mut(8))
        .zip(fc.chunks_exact_mut(8))
    {
        for lane in 0..8 {
            let gl = gv[lane];
            sv[lane] += eps * (gl - mv[lane]);
            fv[lane] += inv_n * gl;
        }
    }
    for i in 0..gt.len() {
        let gl = gt[i];
        st[i] += eps * (gl - mt[i]);
        ft[i] += inv_n * gl;
    }
}

/// ℓ2 norm.
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// ℓ∞ norm.
pub fn norm_inf(a: &[f32]) -> f32 {
    a.iter().fold(0.0f32, |m, x| m.max(x.abs()))
}

/// Elementwise add into accumulator.
pub fn add_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Scale in place.
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// Fill with zeros.
pub fn zero(a: &mut [f32]) {
    a.iter_mut().for_each(|v| *v = 0.0);
}

/// Mean of a set of equal-length vectors into `out`.
pub fn mean_into(vs: &[Vec<f32>], out: &mut [f32]) {
    zero(out);
    if vs.is_empty() {
        return;
    }
    for v in vs {
        add_into(out, v);
    }
    scale(out, 1.0 / vs.len() as f32);
}

/// Running maxima of prefix-sum norms (ℓ∞ and ℓ2) over vectors visited in
/// `order` — the herding objective of Eq. (3). Single pass, one scratch sum.
pub fn prefix_bounds(
    vs: &[Vec<f32>],
    center: &[f32],
    order: &[usize],
) -> (f32, f32) {
    let d = center.len();
    let mut sum = vec![0.0f32; d];
    let mut max_inf = 0.0f32;
    let mut max_l2 = 0.0f32;
    for &i in order {
        for j in 0..d {
            sum[j] += vs[i][j] - center[j];
        }
        max_inf = max_inf.max(norm_inf(&sum));
        max_l2 = max_l2.max(norm2(&sum));
    }
    (max_inf, max_l2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rvec(rng: &mut Rng, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.gauss() as f32).collect()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(1);
        for d in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let a = rvec(&mut rng, d);
            let b = rvec(&mut rng, d);
            let fast = dot(&a, &b);
            let naive = dot_naive(&a, &b);
            assert!(
                (fast - naive).abs() <= 1e-3 * (1.0 + naive.abs()),
                "d={d}: {fast} vs {naive}"
            );
        }
    }

    #[test]
    fn axpy_matches_reference() {
        let mut rng = Rng::new(2);
        for d in [1usize, 8, 13, 256] {
            let x = rvec(&mut rng, d);
            let mut y = rvec(&mut rng, d);
            let mut want = y.clone();
            axpy(0.5, &x, &mut y);
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += 0.5 * xv;
            }
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fused_centered_ops_match_two_step() {
        let mut rng = Rng::new(3);
        let d = 777;
        let s = rvec(&mut rng, d);
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut c = vec![0.0f32; d];
        sub_into(&g, &m, &mut c);
        let two_step = dot(&s, &c);
        let fused = dot_centered(&s, &g, &m);
        assert!((two_step - fused).abs() < 1e-3);

        let mut s1 = s.clone();
        let mut s2 = s.clone();
        axpy(-1.0, &c, &mut s1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn grab_update_matches_two_step() {
        let mut rng = Rng::new(9);
        let d = 333;
        let g = rvec(&mut rng, d);
        let m = rvec(&mut rng, d);
        let mut s1 = rvec(&mut rng, d);
        let mut f1 = rvec(&mut rng, d);
        let mut s2 = s1.clone();
        let mut f2 = f1.clone();
        grab_update(-1.0, 0.25, &g, &m, &mut s1, &mut f1);
        axpy_centered(-1.0, &g, &m, &mut s2);
        axpy(0.25, &g, &mut f2);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-6);
        }
        for (a, b) in f1.iter().zip(&f2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn norms() {
        let v = [3.0f32, -4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-6);
        assert!((norm_inf(&v) - 4.0).abs() < 1e-6);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn mean_into_works() {
        let vs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let mut out = vec![0.0f32; 2];
        mean_into(&vs, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn prefix_bounds_simple() {
        // Two opposite vectors, centered at zero: prefix max is the first.
        let vs = vec![vec![1.0f32, 0.0], vec![-1.0, 0.0]];
        let center = vec![0.0f32, 0.0];
        let (inf, l2) = prefix_bounds(&vs, &center, &[0, 1]);
        assert!((inf - 1.0).abs() < 1e-6);
        assert!((l2 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn prefix_bounds_order_matters() {
        // [1,1,-1,-1] ordering vs interleaved [1,-1,1,-1].
        let vs: Vec<Vec<f32>> =
            vec![vec![1.0], vec![1.0], vec![-1.0], vec![-1.0]];
        let c = vec![0.0f32];
        let (bad, _) = prefix_bounds(&vs, &c, &[0, 1, 2, 3]);
        let (good, _) = prefix_bounds(&vs, &c, &[0, 2, 1, 3]);
        assert!(bad > good);
        assert!((bad - 2.0).abs() < 1e-6);
        assert!((good - 1.0).abs() < 1e-6);
    }
}
